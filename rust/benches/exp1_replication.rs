//! Experiment 1 (paper §IV-A, Fig. 4 top): replication times per region.
//!
//! "11133 file uploads with an average compressed size of 9.06 Kb are
//! submitted into an already formed PeersDB cluster comprising 31 regular
//! peers (distributed across regions) and one root peer (region
//! asia-east2). The focus here is on general replication metrics."
//!
//! Regenerates the figure's series: per-region mean/p95/max replication
//! time of individual contributions across all nodes of that region.
//!
//! Scale with PEERSDB_BENCH_SCALE (1.0 = the paper's full 11133 files).

use peersdb::modeling::datagen;
use peersdb::peersdb::{NodeConfig, NodeEvent};
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::{Region, ALL};
use peersdb::util::bench::{print_environment, scaled, timed, Table};
use peersdb::util::stats::Summary;
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;
use std::collections::BTreeMap;

const PEERS: usize = 32; // 31 regular + 1 root
const FILES_FULL: usize = 11133;
const ROWS_PER_FILE: usize = 120; // ≈9 KB gzip, the corpus average
const SUBMIT_RATE_PER_S: f64 = 60.0;

fn main() {
    print_environment("PROTOTYPE: HARDWARE & SOFTWARE SPECIFICATIONS (Table I analogue)");
    let files = scaled(FILES_FULL);
    println!(
        "experiment 1: {files} uploads (≈9 KB gzip each) into a formed {PEERS}-peer cluster\n"
    );

    // The paper's deployment: root in asia-east2, the rest rotated
    // across the six regions.
    let cfg = || NodeConfig {
        auto_validate: false,
        // Provider announcements for 11k files add DHT noise the paper's
        // kubo nodes also produced; keep them on.
        announce_providers: true,
        ..NodeConfig::default()
    };
    // Pods co-locate on the six GKE machines (one per region, Table I);
    // the root shares the asia-east2 machine with ~5 peers — the source
    // of the paper's root-region CPU-strain artifact.
    let specs: Vec<PeerSpec> = (0..PEERS)
        .map(|i| {
            let region = if i == 0 { Region::AsiaEast2 } else { ALL[i % ALL.len()] };
            PeerSpec {
                region,
                start_at: Nanos(Duration::from_millis(250).0 * i as u64),
                cfg: cfg(),
                machine: Some(ALL.iter().position(|r| *r == region).unwrap()),
                ..Default::default()
            }
        })
        .collect();
    let mut cluster = harness::build_cluster(0xE1, NetModel::default(), specs);
    // Form the cluster fully before the load (the paper's precondition).
    cluster.run_for(Duration::from_secs(30));
    let formed = (0..PEERS).filter(|i| cluster.node(*i).is_bootstrapped()).count();
    println!("cluster formed: {formed}/{PEERS} peers bootstrapped\n");

    // Submit the corpus at a steady rate from round-robin peers.
    let mut rng = Rng::new(0xDA7A);
    let gap = Duration::from_secs_f64(1.0 / SUBMIT_RATE_PER_S);
    let (_, wall) = timed(|| {
        for i in 0..files {
            let wl = (i % 6) as u32;
            let (file, _) = datagen::generate_contribution(&mut rng, wl, ROWS_PER_FILE);
            let peer = 1 + (i % (PEERS - 1));
            harness::contribute(&mut cluster, peer, &file, datagen::WORKLOADS[wl as usize]);
            cluster.run_for(gap);
        }
        // Drain the tail.
        cluster.run_for(Duration::from_secs(120));
    });

    // Collect per-region replication latencies from node events.
    let mut per_region: BTreeMap<&'static str, Summary> = BTreeMap::new();
    let mut overall = Summary::new();
    let events = harness::drain_events(&mut cluster);
    for (idx, ev) in &events {
        if let NodeEvent::ContributionReplicated { created_at, completed_at, .. } = ev {
            let secs = (completed_at.0.saturating_sub(*created_at)) as f64 / 1e9;
            per_region
                .entry(cluster.region_of(*idx).name())
                .or_default()
                .push(secs);
            overall.push(secs);
        }
    }

    println!("Fig. 4 (top) — replication time of individual contributions, by region [s]:");
    let mut table = Table::new(&["region", "n", "mean", "p50", "p95", "max"]);
    for (region, s) in per_region.iter_mut() {
        table.row(&[
            region.to_string(),
            s.len().to_string(),
            format!("{:.3}", s.mean()),
            format!("{:.3}", s.p50()),
            format!("{:.3}", s.p95()),
            format!("{:.3}", s.max()),
        ]);
    }
    table.print();

    let replicated = overall.len();
    println!(
        "replication events: {replicated}; overall p50 {:.3}s p95 {:.3}s max {:.3}s",
        overall.p50(),
        overall.p95(),
        overall.max()
    );
    println!(
        "transport totals: {} msgs delivered, {:.1} MiB sent; \
         {:.1}s wall-clock for {:.0}s simulated",
        cluster.stats.msgs_delivered,
        cluster.stats.bytes_sent as f64 / 1048576.0,
        wall,
        cluster.now().as_secs_f64()
    );

    // Shape assertions from the paper: "the replication time of individual
    // contributions across all nodes stays below one second in most
    // instances".
    assert!(overall.p50() < 1.0, "median replication above 1s");
    let stores_converged = (0..PEERS).all(|i| cluster.node(i).contributions.len() == files);
    assert!(stores_converged, "stores did not converge to {files}");
    println!("exp1_replication OK");
}

//! Micro-benchmarks of the hot paths (the §Perf baseline and regression
//! guard): CID hashing, codec, blockstore, log join, DHT lookup machinery
//! and raw DES event throughput.

use peersdb::blockstore::BlockStore;
use peersdb::cid::{Cid, Codec};
use peersdb::dht::{DhtConfig, Engine as DhtEngine, Key};
use peersdb::ipfs_log::Log;
use peersdb::net::{Outbox, PeerId, Runner, WireSize};
use peersdb::peersdb::Message;
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::Region;
use peersdb::sim::Cluster;
use peersdb::util::bench::{bench_ns, print_environment};
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;

fn main() {
    print_environment("MICRO BENCHMARKS (perf-pass baseline)");
    let mut rng = Rng::new(1);

    // --- content addressing ---
    let data_9k = {
        let mut v = vec![0u8; 9 * 1024];
        rng.fill_bytes(&mut v);
        v
    };
    bench_ns("cid: sha256 of 9 KB contribution", 20_000, || {
        std::hint::black_box(Cid::of_raw(&data_9k));
    });

    // --- codec ---
    let payload = peersdb::util::Blob::from(data_9k.clone());
    bench_ns("blob: clone 9 KB payload (refcount bump)", 2_000_000, || {
        std::hint::black_box(payload.clone());
    });
    let msg = Message::Bitswap(peersdb::bitswap::Msg::Block {
        req_id: 42,
        cid: Cid::of_raw(b"x"),
        data: payload.clone(),
    });
    bench_ns("codec: encode 9 KB bitswap block msg", 50_000, || {
        std::hint::black_box(peersdb::codec::to_bytes(&msg));
    });
    let encoded = peersdb::codec::to_bytes(&msg);
    bench_ns("codec: decode 9 KB bitswap block msg", 50_000, || {
        std::hint::black_box(peersdb::codec::from_bytes::<Message>(&encoded).unwrap());
    });
    bench_ns("codec: exact wire_size (O(1) path)", 1_000_000, || {
        std::hint::black_box(WireSize::wire_size(&msg));
    });

    // --- blockstore ---
    let mut bs = BlockStore::new();
    let mut i = 0u64;
    bench_ns("blockstore: put 9 KB (dedup-miss)", 20_000, || {
        let mut d = data_9k.clone();
        d[..8].copy_from_slice(&i.to_le_bytes());
        i += 1;
        std::hint::black_box(bs.put(Codec::Raw, d));
    });
    let hot = bs.put(Codec::Raw, data_9k.clone());
    bench_ns("blockstore: get 9 KB", 2_000_000, || {
        std::hint::black_box(bs.get(&hot));
    });

    // --- ipfs log ---
    let author = PeerId::from_rng(&mut rng);
    bench_ns("ipfs_log: append (chained entry)", 50_000, {
        let mut log = Log::new();
        move || {
            std::hint::black_box(log.append(author, vec![0u8; 64]));
        }
    });
    // Join of two 1k-entry logs.
    let (mut a, mut b) = (Log::new(), Log::new());
    let author2 = PeerId::from_rng(&mut rng);
    for i in 0..1000u32 {
        a.append(author, i.to_le_bytes().to_vec());
        b.append(author2, i.to_le_bytes().to_vec());
    }
    bench_ns("ipfs_log: join 1k-entry disjoint log", 50, || {
        let mut fresh = a.clone();
        fresh.join(&b);
        std::hint::black_box(fresh.len());
    });

    // --- dht ---
    let own = PeerId::from_rng(&mut rng);
    let mut engine = DhtEngine::new(own, DhtConfig::default());
    for _ in 0..500 {
        engine.add_seed(Nanos(0), PeerId::from_rng(&mut rng));
    }
    let target = Key(rng.bytes32());
    bench_ns("dht: closest() over 500-peer table", 20_000, || {
        std::hint::black_box(engine.table.closest(&target, 20));
    });

    // --- DES event throughput ---
    struct Pinger {
        id: PeerId,
        peer: Option<PeerId>,
        n: u64,
    }
    impl Runner for Pinger {
        type Msg = u64;
        fn id(&self) -> PeerId {
            self.id
        }
        fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
            if let Some(p) = self.peer {
                out.send(p, 0);
            }
        }
        fn on_message(&mut self, _now: Nanos, from: PeerId, msg: u64, out: &mut Outbox<u64>) {
            self.n += 1;
            if msg < 2_000_000 {
                out.send(from, msg + 1);
            }
        }
        fn on_timer(&mut self, _n: Nanos, _t: u64, _o: &mut Outbox<u64>) {}
        fn processing_cost(&self, _m: &u64) -> Duration {
            Duration(0)
        }
    }
    let a_id = PeerId::from_rng(&mut rng);
    let b_id = PeerId::from_rng(&mut rng);
    let mut cluster: Cluster<Pinger> = Cluster::new(NetModel::uniform(1.0, 10_000.0, 0.0), 7);
    cluster.add_node(Pinger { id: a_id, peer: Some(b_id), n: 0 }, Region::Local, Nanos::ZERO);
    cluster.add_node(Pinger { id: b_id, peer: None, n: 0 }, Region::Local, Nanos::ZERO);
    let t0 = std::time::Instant::now();
    cluster.run_until_idle();
    let events = cluster.stats.events_processed;
    let rate = events as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  DES: {} events in {:.2}s  →  {:.2} M events/s",
        events,
        t0.elapsed().as_secs_f64(),
        rate / 1e6
    );
    println!("micro OK");
}

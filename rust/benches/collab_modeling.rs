//! Collaboration benefit (paper §II motivation): prediction error of the
//! runtime model vs the number of peers sharing performance data.
//!
//! "many distributed dataflow applications share key characteristics …
//! which presents an opportunity for collaborative approaches to
//! performance modeling" — this bench quantifies that opportunity on the
//! AOT-compiled model via PJRT: the full distribution layer feeds peer 1's
//! training set as more organizations participate.
//!
//! Requires `make artifacts`.

use peersdb::modeling::datagen::{self, TraceRow, WORKLOADS};
use peersdb::modeling::workflow;
use peersdb::peersdb::NodeConfig;
use peersdb::runtime::PerfModel;
use peersdb::sim::harness;
use peersdb::util::bench::{print_environment, Table};
use peersdb::util::time::Duration;
use peersdb::util::Rng;

const FILES_PER_PEER: usize = 4;
const ROWS_PER_FILE: usize = 50;
const EPOCHS: usize = 30;

fn main() -> anyhow::Result<()> {
    print_environment("COLLABORATIVE MODELING (M-collab)");
    let mut model = PerfModel::load("artifacts")?;
    println!("model: {} params; batch {}\n", model.param_count(), model.meta.batch);

    // Held-out evaluation rows across every workload.
    let mut test_rng = Rng::new(555);
    let test_rows: Vec<TraceRow> = (0..WORKLOADS.len() as u32)
        .flat_map(|wl| (0..50).map(|_| datagen::sample_row(&mut test_rng, wl)).collect::<Vec<_>>())
        .collect();

    let mut table = Table::new(&["peers sharing", "train rows", "RMSE (ln rt)", "MAPE %"]);
    let mut rmse_by_peers = Vec::new();
    for &sharing in &[1usize, 2, 4, 8] {
        // A cluster where `sharing` peers contribute their (single-
        // workload) traces; peer 1 then assembles whatever replicated.
        let n = sharing + 2; // root + observers
        let stagger = Duration::from_millis(300);
        let mut cluster =
            harness::paper_cluster(0xC0 + sharing as u64, n, stagger, |_| NodeConfig::default());
        cluster.run_for(Duration::from_secs(15));
        let mut rng = Rng::new(0xFEED + sharing as u64);
        for peer in 1..=sharing {
            let wl = ((peer - 1) % WORKLOADS.len()) as u32;
            for _ in 0..FILES_PER_PEER {
                let (file, _) = datagen::generate_contribution(&mut rng, wl, ROWS_PER_FILE);
                harness::contribute(&mut cluster, peer, &file, WORKLOADS[wl as usize]);
                cluster.run_for(Duration::from_millis(400));
            }
        }
        cluster.run_for(Duration::from_secs(60));
        let rows = workflow::assemble_from_node(cluster.node(1), None, &[]);
        let mut rng2 = Rng::new(1);
        let report =
            workflow::train_and_eval(&mut model, &rows, &test_rows, EPOCHS, 0.05, &mut rng2)?;
        table.row(&[
            sharing.to_string(),
            report.train_rows.to_string(),
            format!("{:.3}", report.rmse_log),
            format!("{:.1}", report.mape * 100.0),
        ]);
        rmse_by_peers.push(report.rmse_log);
    }
    table.print();

    // Shape: more sharing peers → lower error (monotone within noise).
    let first = rmse_by_peers.first().unwrap();
    let last = rmse_by_peers.last().unwrap();
    println!("RMSE improvement from 1 → 8 sharing peers: {:.2}x", first / last);
    assert!(last * 1.5 < *first, "collaboration should reduce error substantially");
    println!("collab_modeling OK");
    Ok(())
}

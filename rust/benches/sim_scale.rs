//! Self-timing DES throughput baseline over the named scenario bank.
//!
//! Runs every scenario in `peersdb::sim::bank` (the seven original
//! fault scenarios, the 100-peer multi-region scale-out, the half-open
//! asymmetric region, the adversarial eclipse, the two GC-pressure
//! repair scenarios, the defended eclipse — multi-path +
//! distance-verified lookups under the same attack — the three
//! striped-transfer scenarios: the slow-peer drag pair and the
//! provider-death reassignment run — the delayed-honest-majority
//! quorum-grace scenario, the three parity-tagged rows that
//! `tests/parity.rs` also replays over real TCP, and the 1,006-peer
//! city-scale churn scenario) in this process, measuring wall
//! time and events/second, and emits the results as `BENCH_sim.json` —
//! the machine-readable perf-trajectory artifact CI uploads on every
//! run. Each record also carries the run's `SimStats` checksum: because
//! scenario runs are deterministic, the checksum is a behavioral
//! fingerprint — comparing two artifacts tells you whether a change
//! moved *performance* (events/sec) or *behavior* (checksum), which is
//! the cross-version half of the replay-determinism guard. Records also
//! carry cluster-wide time-to-replicate (mean/max `replication_ms`
//! across every node) and the striped-transfer counters, so the
//! heterogeneous-bandwidth scenarios double as a data-distribution
//! measurement: the quality-vs-round-robin gap is read straight off the
//! drag pair's records.
//!
//! Every record also carries the timer-wheel queue telemetry
//! (`dead_events`, `peak_queue_len`) and the cluster-wide pubsub
//! counters (`pubsub_published` / `_forwarded` / `_duplicates`), so the
//! city-scale row doubles as the 1k-peer gossip-redundancy measurement
//! the ROADMAP's mesh-overlay item starts from. The city-scale row
//! additionally records the process peak-RSS high-water mark and
//! **fails the bench** (and therefore CI) if its DES throughput drops
//! below [`CITY_SCALE_EPS_FLOOR`].

use peersdb::codec::Json;
use peersdb::sim::bank;
use peersdb::sim::scenario;
use peersdb::util::bench::{print_environment, Table};

/// CI-failing throughput floor for the city-scale row, in DES events
/// per wall-clock second. Release builds on developer hardware run this
/// scenario at well over a million events/s; the floor is set an order
/// of magnitude below that so it only trips on a genuine event-queue
/// regression (e.g. the wheel degenerating to per-push sorting), not on
/// a slow CI runner.
const CITY_SCALE_EPS_FLOOR: f64 = 100_000.0;

/// Process peak-RSS high-water mark in KiB (`VmHWM` from
/// `/proc/self/status`). This is a whole-process watermark, so it is
/// only recorded on the largest scenario's row, where it approximates
/// that scenario's footprint.
#[cfg(target_os = "linux")]
fn peak_rss_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            if let Some(kb) = rest.split_whitespace().next().and_then(|v| v.parse().ok()) {
                return kb;
            }
        }
    }
    0
}

#[cfg(not(target_os = "linux"))]
fn peak_rss_kb() -> u64 {
    0
}

fn main() {
    print_environment("SIM SCALE: DES THROUGHPUT BASELINE (perf trajectory)");
    println!(
        "scenario bank: {} scenarios incl. multi-region scale-out (100 peers / 3 waves), \
         asymmetric half-open region, adversarial + defended eclipse, GC-pressure repair, \
         the striped-transfer trio (slow-peer drag pair + provider death), the \
         delayed-honest-majority quorum-grace run, and the 1,006-peer city-scale churn\n",
        bank::all().len()
    );

    let mut table = Table::new(&[
        "scenario", "peers", "events", "wall ms", "Kevents/s", "repl ms", "virtual s",
        "stats checksum",
    ]);
    let mut records: Vec<Json> = Vec::new();
    let mut total_events = 0u64;
    let mut total_wall = 0.0f64;

    for sc in bank::all() {
        let name = sc.name;
        let t0 = std::time::Instant::now();
        let (report, cluster) = match scenario::run_cluster(&sc) {
            Ok(r) => r,
            Err(e) => panic!("bank scenario '{name}' failed invariants: {e}"),
        };
        let wall = t0.elapsed().as_secs_f64();
        let events = report.stats.events_processed;
        let eps = events as f64 / wall.max(1e-9);
        let checksum = format!("{:016x}", report.stats.checksum());
        total_events += events;
        total_wall += wall;

        // Cluster-wide time-to-replicate: every node's `replication_ms`
        // samples folded into one mean/max — the data-distribution half
        // of the trajectory (the DES half is events/sec).
        let mut repl_sum = 0.0f64;
        let mut repl_n = 0usize;
        let mut repl_max = 0.0f64;
        for i in 0..cluster.len() {
            if let Some(s) = cluster.node(i).metrics.summary("replication_ms") {
                repl_sum += s.mean() * s.len() as f64;
                repl_n += s.len();
                repl_max = repl_max.max(s.max());
            }
        }
        let repl_mean = if repl_n > 0 { repl_sum / repl_n as f64 } else { 0.0 };

        // Cluster-wide pubsub counters: the duplicate fraction is the
        // flood-gossip redundancy measurement the mesh-overlay ROADMAP
        // item starts from (most telling on the 1,006-peer row).
        let mut pubsub_published = 0u64;
        let mut pubsub_forwarded = 0u64;
        let mut pubsub_duplicates = 0u64;
        for i in 0..cluster.len() {
            let (p, f, d) = cluster.node(i).pubsub_stats();
            pubsub_published += p;
            pubsub_forwarded += f;
            pubsub_duplicates += d;
        }
        let pubsub_redundancy = pubsub_duplicates as f64
            / (pubsub_forwarded + pubsub_duplicates).max(1) as f64;

        table.row(&[
            name.to_string(),
            report.peers.to_string(),
            events.to_string(),
            format!("{:.0}", wall * 1e3),
            format!("{:.0}", eps / 1e3),
            format!("{:.0}", repl_mean),
            format!("{:.0}", report.end.as_secs_f64()),
            checksum.clone(),
        ]);
        let mut record = Json::obj()
            .set("name", name)
            .set("peers", report.peers)
            .set("contributions", report.contributions)
            .set("events_processed", events)
            .set("msgs_sent", report.stats.msgs_sent)
            .set("bytes_sent", report.stats.bytes_sent)
            .set("wall_ms", wall * 1e3)
            .set("events_per_sec", eps)
            .set("replication_ms_mean", repl_mean)
            .set("replication_ms_max", repl_max)
            .set("chunks_striped", report.stats.chunks_striped)
            .set("transfer_reassignments", report.stats.transfer_reassignments)
            .set("dead_events", report.stats.dead_events)
            .set("peak_queue_len", report.stats.peak_queue_len)
            .set("pubsub_published", pubsub_published)
            .set("pubsub_forwarded", pubsub_forwarded)
            .set("pubsub_duplicates", pubsub_duplicates)
            .set("pubsub_redundancy", pubsub_redundancy)
            .set("virtual_secs", report.end.as_secs_f64())
            .set("stats_checksum", checksum);
        if name == "city-scale" {
            record = record.set("peak_rss_kb", peak_rss_kb());
            assert!(
                eps >= CITY_SCALE_EPS_FLOOR,
                "city-scale DES throughput regressed: {eps:.0} events/s \
                 < floor {CITY_SCALE_EPS_FLOOR:.0}"
            );
        }
        records.push(record);
    }
    table.print();
    println!(
        "aggregate: {} events in {:.2}s wall  →  {:.0} Kevents/s",
        total_events,
        total_wall,
        total_events as f64 / total_wall.max(1e-9) / 1e3
    );

    let doc = Json::obj()
        .set("bench", "sim_scale")
        .set("version", env!("CARGO_PKG_VERSION"))
        .set(
            "aggregate_events_per_sec",
            total_events as f64 / total_wall.max(1e-9),
        )
        .set("scenarios", Json::Arr(records));
    std::fs::write("BENCH_sim.json", doc.pretty()).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");
    println!("sim_scale OK");
}

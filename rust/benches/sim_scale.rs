//! Self-timing DES throughput baseline over the named scenario bank.
//!
//! Runs every scenario in `peersdb::sim::bank` (the seven original
//! fault scenarios, the 100-peer multi-region scale-out, the half-open
//! asymmetric region, the adversarial eclipse, the two GC-pressure
//! repair scenarios, and the defended eclipse — multi-path +
//! distance-verified lookups under the same attack) in this process,
//! measuring wall time and events/second, and emits the results as
//! `BENCH_sim.json` — the machine-readable perf-trajectory artifact CI
//! uploads on every run. Each record also carries the run's `SimStats`
//! checksum: because scenario runs are deterministic, the checksum is a
//! behavioral fingerprint — comparing two artifacts tells you whether a
//! change moved *performance* (events/sec) or *behavior* (checksum),
//! which is the cross-version half of the replay-determinism guard.

use peersdb::codec::Json;
use peersdb::sim::bank;
use peersdb::sim::scenario;
use peersdb::util::bench::{print_environment, Table};

fn main() {
    print_environment("SIM SCALE: DES THROUGHPUT BASELINE (perf trajectory)");
    println!(
        "scenario bank: {} scenarios incl. multi-region scale-out (100 peers / 3 waves), \
         asymmetric half-open region, adversarial + defended eclipse, and GC-pressure repair\n",
        bank::all().len()
    );

    let mut table = Table::new(&[
        "scenario", "peers", "events", "wall ms", "Kevents/s", "virtual s", "stats checksum",
    ]);
    let mut records: Vec<Json> = Vec::new();
    let mut total_events = 0u64;
    let mut total_wall = 0.0f64;

    for sc in bank::all() {
        let name = sc.name;
        let t0 = std::time::Instant::now();
        let report = match scenario::run(&sc) {
            Ok(r) => r,
            Err(e) => panic!("bank scenario '{name}' failed invariants: {e}"),
        };
        let wall = t0.elapsed().as_secs_f64();
        let events = report.stats.events_processed;
        let eps = events as f64 / wall.max(1e-9);
        let checksum = format!("{:016x}", report.stats.checksum());
        total_events += events;
        total_wall += wall;

        table.row(&[
            name.to_string(),
            report.peers.to_string(),
            events.to_string(),
            format!("{:.0}", wall * 1e3),
            format!("{:.0}", eps / 1e3),
            format!("{:.0}", report.end.as_secs_f64()),
            checksum.clone(),
        ]);
        records.push(
            Json::obj()
                .set("name", name)
                .set("peers", report.peers)
                .set("contributions", report.contributions)
                .set("events_processed", events)
                .set("msgs_sent", report.stats.msgs_sent)
                .set("bytes_sent", report.stats.bytes_sent)
                .set("wall_ms", wall * 1e3)
                .set("events_per_sec", eps)
                .set("virtual_secs", report.end.as_secs_f64())
                .set("stats_checksum", checksum),
        );
    }
    table.print();
    println!(
        "aggregate: {} events in {:.2}s wall  →  {:.0} Kevents/s",
        total_events,
        total_wall,
        total_events as f64 / total_wall.max(1e-9) / 1e3
    );

    let doc = Json::obj()
        .set("bench", "sim_scale")
        .set("version", env!("CARGO_PKG_VERSION"))
        .set(
            "aggregate_events_per_sec",
            total_events as f64 / total_wall.max(1e-9),
        )
        .set("scenarios", Json::Arr(records));
    std::fs::write("BENCH_sim.json", doc.pretty()).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");
    println!("sim_scale OK");
}

//! Self-timing DES throughput baseline over the named scenario bank.
//!
//! Runs every scenario in `peersdb::sim::bank` (the seven original
//! fault scenarios, the 100-peer multi-region scale-out, the half-open
//! asymmetric region, the adversarial eclipse, the two GC-pressure
//! repair scenarios, the defended eclipse — multi-path +
//! distance-verified lookups under the same attack — the three
//! striped-transfer scenarios: the slow-peer drag pair and the
//! provider-death reassignment run — the delayed-honest-majority
//! quorum-grace scenario, the three parity-tagged rows that
//! `tests/parity.rs` also replays over real TCP, the 1,006-peer
//! city-scale churn scenario plus its gossip-mesh variant, and the
//! 501-peer broadcast pair) in this process, measuring wall
//! time and events/second, and emits the results as `BENCH_sim.json` —
//! the machine-readable perf-trajectory artifact CI uploads on every
//! run. Each record also carries the run's `SimStats` checksum: because
//! scenario runs are deterministic, the checksum is a behavioral
//! fingerprint — comparing two artifacts tells you whether a change
//! moved *performance* (events/sec) or *behavior* (checksum), which is
//! the cross-version half of the replay-determinism guard. Records also
//! carry cluster-wide time-to-replicate (mean/max `replication_ms`
//! across every node) and the striped-transfer counters, so the
//! heterogeneous-bandwidth scenarios double as a data-distribution
//! measurement: the quality-vs-round-robin gap is read straight off the
//! drag pair's records.
//!
//! Every record also carries the timer-wheel queue telemetry
//! (`dead_events`, `peak_queue_len`) and the cluster-wide pubsub
//! counters (`pubsub_published` / `_forwarded` / `_delivered` /
//! `_duplicates`, plus the gossip-mesh telemetry quartet `ihave_sent` /
//! `iwant_served` / `grafts` / `prunes`). `pubsub_redundancy` is
//! duplicates per useful delivery — wasted frames each subscriber's
//! delivery costs the network — so the `city-scale` (flood) and
//! `city-scale-mesh` rows read as a controlled before/after of the
//! gossip mesh on one schedule; the bench **fails** (and therefore CI)
//! unless the mesh row sits at most half the flood row's redundancy
//! ([`MESH_REDUNDANCY_FACTOR`]) — the same bound is enforced on the
//! 501-peer broadcast pair, whose dense fabric makes flood pay its
//! full fan-in. The city rows also enforce the
//! [`CITY_SCALE_EPS_FLOOR`] DES-throughput floor, and the flood row
//! records the process peak-RSS high-water mark.

use peersdb::codec::Json;
use peersdb::sim::bank;
use peersdb::sim::scenario;
use peersdb::util::bench::{print_environment, Table};

/// CI-failing throughput floor for the city-scale row, in DES events
/// per wall-clock second. Release builds on developer hardware run this
/// scenario at well over a million events/s; the floor is set an order
/// of magnitude below that so it only trips on a genuine event-queue
/// regression (e.g. the wheel degenerating to per-push sorting), not on
/// a slow CI runner.
const CITY_SCALE_EPS_FLOOR: f64 = 100_000.0;

/// CI-failing redundancy bound: the mesh-enabled city-scale row's
/// `pubsub_redundancy` (duplicates per useful delivery) must be at most
/// `1 / MESH_REDUNDANCY_FACTOR` of the flood row's on the identical
/// schedule. The ROADMAP's gossip-mesh item targets ≥ 4×; the enforced
/// floor is 2× so a scheduler-timing wobble cannot flake CI while a
/// genuine mesh regression (e.g. every neighbor grafting everyone)
/// still trips it.
const MESH_REDUNDANCY_FACTOR: f64 = 2.0;

/// Process peak-RSS high-water mark in KiB (`VmHWM` from
/// `/proc/self/status`). This is a whole-process watermark, so it is
/// only recorded on the largest scenario's row, where it approximates
/// that scenario's footprint.
#[cfg(target_os = "linux")]
fn peak_rss_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            if let Some(kb) = rest.split_whitespace().next().and_then(|v| v.parse().ok()) {
                return kb;
            }
        }
    }
    0
}

#[cfg(not(target_os = "linux"))]
fn peak_rss_kb() -> u64 {
    0
}

fn main() {
    print_environment("SIM SCALE: DES THROUGHPUT BASELINE (perf trajectory)");
    println!(
        "scenario bank: {} scenarios incl. multi-region scale-out (100 peers / 3 waves), \
         asymmetric half-open region, adversarial + defended eclipse, GC-pressure repair, \
         the striped-transfer trio (slow-peer drag pair + provider death), the \
         delayed-honest-majority quorum-grace run, the 1,006-peer city-scale churn \
         (flood + gossip-mesh variants), and the 501-peer broadcast pair\n",
        bank::all().len()
    );

    let mut table = Table::new(&[
        "scenario", "peers", "events", "wall ms", "Kevents/s", "repl ms", "virtual s",
        "stats checksum",
    ]);
    let mut records: Vec<Json> = Vec::new();
    let mut total_events = 0u64;
    let mut total_wall = 0.0f64;
    let mut city_flood_redundancy: Option<f64> = None;
    let mut city_mesh_redundancy: Option<f64> = None;
    let mut bcast_flood_redundancy: Option<f64> = None;
    let mut bcast_mesh_redundancy: Option<f64> = None;

    for sc in bank::all() {
        let name = sc.name;
        let t0 = std::time::Instant::now();
        let (report, cluster) = match scenario::run_cluster(&sc) {
            Ok(r) => r,
            Err(e) => panic!("bank scenario '{name}' failed invariants: {e}"),
        };
        let wall = t0.elapsed().as_secs_f64();
        let events = report.stats.events_processed;
        let eps = events as f64 / wall.max(1e-9);
        let checksum = format!("{:016x}", report.stats.checksum());
        total_events += events;
        total_wall += wall;

        // Cluster-wide time-to-replicate: every node's `replication_ms`
        // samples folded into one mean/max — the data-distribution half
        // of the trajectory (the DES half is events/sec).
        let mut repl_sum = 0.0f64;
        let mut repl_n = 0usize;
        let mut repl_max = 0.0f64;
        for i in 0..cluster.len() {
            if let Some(s) = cluster.node(i).metrics.summary("replication_ms") {
                repl_sum += s.mean() * s.len() as f64;
                repl_n += s.len();
                repl_max = repl_max.max(s.max());
            }
        }
        let repl_mean = if repl_n > 0 { repl_sum / repl_n as f64 } else { 0.0 };

        // Cluster-wide pubsub counters. Redundancy = duplicates per
        // useful delivery: how many wasted `Publish` frames each
        // subscriber's copy costs the network (flood pays roughly its
        // fan-in; the mesh is chartered to collapse that by an integer
        // factor — read it off the city-scale pair).
        let mut pubsub_published = 0u64;
        let mut pubsub_forwarded = 0u64;
        let mut pubsub_delivered = 0u64;
        let mut pubsub_duplicates = 0u64;
        for i in 0..cluster.len() {
            let (p, f, d, dup) = cluster.node(i).pubsub_stats();
            pubsub_published += p;
            pubsub_forwarded += f;
            pubsub_delivered += d;
            pubsub_duplicates += dup;
        }
        let pubsub_redundancy =
            pubsub_duplicates as f64 / pubsub_delivered.max(1) as f64;

        table.row(&[
            name.to_string(),
            report.peers.to_string(),
            events.to_string(),
            format!("{:.0}", wall * 1e3),
            format!("{:.0}", eps / 1e3),
            format!("{:.0}", repl_mean),
            format!("{:.0}", report.end.as_secs_f64()),
            checksum.clone(),
        ]);
        let mut record = Json::obj()
            .set("name", name)
            .set("peers", report.peers)
            .set("contributions", report.contributions)
            .set("events_processed", events)
            .set("msgs_sent", report.stats.msgs_sent)
            .set("bytes_sent", report.stats.bytes_sent)
            .set("wall_ms", wall * 1e3)
            .set("events_per_sec", eps)
            .set("replication_ms_mean", repl_mean)
            .set("replication_ms_max", repl_max)
            .set("chunks_striped", report.stats.chunks_striped)
            .set("transfer_reassignments", report.stats.transfer_reassignments)
            .set("dead_events", report.stats.dead_events)
            .set("peak_queue_len", report.stats.peak_queue_len)
            .set("pubsub_published", pubsub_published)
            .set("pubsub_forwarded", pubsub_forwarded)
            .set("pubsub_delivered", pubsub_delivered)
            .set("pubsub_duplicates", pubsub_duplicates)
            .set("pubsub_redundancy", pubsub_redundancy)
            .set("ihave_sent", report.stats.ihave_sent)
            .set("iwant_served", report.stats.iwant_served)
            .set("grafts", report.stats.grafts)
            .set("prunes", report.stats.prunes)
            .set("virtual_secs", report.end.as_secs_f64())
            .set("stats_checksum", checksum);
        if name == "city-scale" {
            record = record.set("peak_rss_kb", peak_rss_kb());
            city_flood_redundancy = Some(pubsub_redundancy);
        }
        if name == "city-scale-mesh" {
            city_mesh_redundancy = Some(pubsub_redundancy);
        }
        if name == "flood-broadcast-churn" {
            bcast_flood_redundancy = Some(pubsub_redundancy);
        }
        if name == "mesh-broadcast-churn" {
            bcast_mesh_redundancy = Some(pubsub_redundancy);
        }
        if name.starts_with("city-scale") {
            assert!(
                eps >= CITY_SCALE_EPS_FLOOR,
                "{name} DES throughput regressed: {eps:.0} events/s \
                 < floor {CITY_SCALE_EPS_FLOOR:.0}"
            );
        }
        records.push(record);
    }

    // The before/after the mesh is chartered on: same city-scale
    // schedule, one knob, an integer-factor redundancy collapse.
    let flood = city_flood_redundancy.expect("bank lost the city-scale row");
    let mesh = city_mesh_redundancy.expect("bank lost the city-scale-mesh row");
    println!(
        "city-scale pubsub redundancy: flood {flood:.2} → mesh {mesh:.2} \
         ({:.1}× reduction, enforced ≥ {MESH_REDUNDANCY_FACTOR:.0}×)",
        flood / mesh.max(1e-9)
    );
    assert!(
        mesh * MESH_REDUNDANCY_FACTOR <= flood,
        "gossip mesh failed to collapse city-scale redundancy: \
         mesh {mesh:.2} vs flood {flood:.2} (need ≥ {MESH_REDUNDANCY_FACTOR:.0}×)"
    );
    // Same charter on the 501-peer broadcast pair, where the dense
    // fabric makes flood pay its true fan-in: the collapse there is the
    // mesh's headline number.
    let bflood = bcast_flood_redundancy.expect("bank lost the flood-broadcast-churn row");
    let bmesh = bcast_mesh_redundancy.expect("bank lost the mesh-broadcast-churn row");
    println!(
        "broadcast pubsub redundancy: flood {bflood:.2} → mesh {bmesh:.2} \
         ({:.1}× reduction, enforced ≥ {MESH_REDUNDANCY_FACTOR:.0}×)",
        bflood / bmesh.max(1e-9)
    );
    assert!(
        bmesh * MESH_REDUNDANCY_FACTOR <= bflood,
        "gossip mesh failed to collapse broadcast redundancy: \
         mesh {bmesh:.2} vs flood {bflood:.2} (need ≥ {MESH_REDUNDANCY_FACTOR:.0}×)"
    );
    table.print();
    println!(
        "aggregate: {} events in {:.2}s wall  →  {:.0} Kevents/s",
        total_events,
        total_wall,
        total_events as f64 / total_wall.max(1e-9) / 1e3
    );

    let doc = Json::obj()
        .set("bench", "sim_scale")
        .set("version", env!("CARGO_PKG_VERSION"))
        .set(
            "aggregate_events_per_sec",
            total_events as f64 / total_wall.max(1e-9),
        )
        .set("scenarios", Json::Arr(records));
    std::fs::write("BENCH_sim.json", doc.pretty()).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");
    println!("sim_scale OK");
}

//! Experiment 2 (paper §IV-A, Fig. 4 bottom): bootstrapping time vs
//! cluster size.
//!
//! "52 peers are added bit by bit to an already populated PeersDB cluster
//! comprising initially of the root peer only. In the beginning, they
//! were added with a downtime of 1 minute between startups, which was
//! reduced to 30 seconds after the first 12 peers. The chosen physical
//! machine and therefore region were changed with every deployment."
//!
//! Regenerates the figure's series: bootstrap time per joining peer,
//! annotated with the cluster size at join time.

use peersdb::modeling::datagen;
use peersdb::peersdb::{NodeConfig, NodeEvent};
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::{Region, ALL};
use peersdb::util::bench::{print_environment, scaled, Table};
use peersdb::util::stats;
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;

const JOINERS_FULL: usize = 52;
/// Contributions pre-loaded on the root ("already populated cluster").
const PRELOAD: usize = 150;

fn main() {
    print_environment("PROTOTYPE: HARDWARE & SOFTWARE SPECIFICATIONS (Table I analogue)");
    let joiners = scaled(JOINERS_FULL);
    println!("experiment 2: {joiners} peers join one by one (populated root, region rotated)\n");

    // Join schedule: 60 s gaps for the first 12, 30 s afterwards.
    let mut start = Duration::from_secs(30); // root warmup + preload window
    // Pods land on the six GKE machines (one per region); rotating the
    // region per deployment rotates the machine, as in the paper
    // ("to avoid resource contention between starting peers").
    let mut specs = vec![PeerSpec {
        region: Region::AsiaEast2,
        start_at: Nanos::ZERO,
        cfg: NodeConfig { auto_validate: false, ..NodeConfig::default() },
        machine: Some(0),
        ..Default::default()
    }];
    for i in 0..joiners {
        let gap = if i < 12 { Duration::from_secs(60) } else { Duration::from_secs(30) };
        start = start + gap;
        let region = ALL[(i + 1) % ALL.len()]; // rotate regions per join
        specs.push(PeerSpec {
            region,
            start_at: Nanos(start.0),
            cfg: NodeConfig { auto_validate: false, ..NodeConfig::default() },
            machine: Some(ALL.iter().position(|r| *r == region).unwrap()),
            ..Default::default()
        });
    }
    let end_at = Nanos(start.0) + Duration::from_secs(120);
    let mut cluster = harness::build_cluster(0xE2, NetModel::default(), specs);

    // Populate the root before anyone joins.
    cluster.run_for(Duration::from_secs(5));
    let mut rng = Rng::new(0xB007);
    for i in 0..PRELOAD {
        let wl = (i % 6) as u32;
        let (file, _) = datagen::generate_contribution(&mut rng, wl, 120);
        harness::contribute(&mut cluster, 0, &file, datagen::WORKLOADS[wl as usize]);
    }
    println!("root populated with {PRELOAD} contributions; joining begins\n");
    cluster.run_until(end_at);

    // Bootstrap durations in join order.
    let mut rows: Vec<(usize, &'static str, f64)> = Vec::new(); // (cluster size, region, secs)
    let events = harness::drain_events(&mut cluster);
    let mut durations: Vec<Option<f64>> = vec![None; cluster.len()];
    for (idx, ev) in &events {
        if let NodeEvent::BootstrapDone { started, completed, .. } = ev {
            durations[*idx] = Some((completed.0 - started.0) as f64 / 1e9);
        }
    }
    for idx in 1..cluster.len() {
        if let Some(secs) = durations[idx] {
            rows.push((idx, cluster.region_of(idx).name(), secs));
        }
    }

    println!("Fig. 4 (bottom) — bootstrapping time per joining peer [s]:");
    let mut table = Table::new(&["join#", "cluster size", "region", "bootstrap [s]"]);
    for (idx, region, secs) in &rows {
        table.row(&[
            idx.to_string(),
            idx.to_string(), // size of the cluster it joined
            region.to_string(),
            format!("{secs:.2}"),
        ]);
    }
    table.print();

    // Paper observation 1: "the overall size of the cluster impacts the
    // bootstrapping time for every new peer to join" — check an upward
    // trend via regression slope over join index.
    let xs: Vec<f64> = rows.iter().map(|(i, _, _)| *i as f64).collect();
    let ys: Vec<f64> = rows.iter().map(|(_, _, s)| *s).collect();
    let slope = stats::slope(&xs, &ys);
    let first_q = ys[..ys.len() / 4].iter().sum::<f64>() / (ys.len() / 4) as f64;
    let last_q = ys[ys.len() * 3 / 4..].iter().sum::<f64>() / (ys.len() - ys.len() * 3 / 4) as f64;
    println!(
        "trend: slope {slope:+.4} s/join; first-quartile mean {first_q:.2}s \
         vs last-quartile mean {last_q:.2}s"
    );

    // Paper observation 2: a geographically nearby peer that already
    // holds the data speeds up joining — compare joins where the region
    // already hosted a peer vs first-in-region joins.
    let mut seen = std::collections::HashSet::new();
    seen.insert("asia-east2"); // the root
    let (mut first_in_region, mut nearby): (Vec<f64>, Vec<f64>) = (vec![], vec![]);
    for (_, region, secs) in &rows {
        if seen.insert(region) {
            first_in_region.push(*secs);
        } else {
            nearby.push(*secs);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "first-in-region joins: mean {:.2}s ({}) | joins with an in-region peer: mean {:.2}s ({})",
        mean(&first_in_region),
        first_in_region.len(),
        mean(&nearby),
        nearby.len()
    );

    assert_eq!(rows.len(), joiners, "all joiners bootstrapped");
    // Paper observation 2 must hold: an in-region peer that already holds
    // the data accelerates bootstrap.
    assert!(
        mean(&nearby) < mean(&first_in_region),
        "nearby-peer speedup not reproduced"
    );
    // Observation 1 (growth with cluster size) is CPU-contention driven on
    // the paper's shared GKE machines; in our DES bootstrap is dominated
    // by the serial log-walk RTT, so the trend is ~flat — see
    // EXPERIMENTS.md §F4-bot for the analysis of this divergence. We
    // assert only that it does not *collapse*.
    assert!(
        last_q > first_q * 0.3,
        "bootstrap time collapsed with cluster size"
    );
    println!("exp2_bootstrap OK");
}

//! Validation-strategy study (paper §IV-B): scaling behaviours, async vs
//! blocking responses, batching, and the quorum-threshold tuning knob.
//!
//! Four sub-experiments mirroring the paper's "Learnings":
//!
//! 1. **Cost-model scaling** — validation latency per cost model
//!    (constant/linear/polynomial/exponential/logarithmic) across data
//!    amounts ("different validation procedures exhibit different
//!    scaling behaviors").
//! 2. **Async vs blocking** — response time of validation *queries*
//!    while heavy validation work is in flight ("responses to validation
//!    requests … should be fast, which requires that validation
//!    processes run asynchronously in a background task").
//! 3. **Batching** — total time to validate a backlog vs batch size
//!    ("it might be worth considering batched performance data
//!    validation").
//! 4. **Quorum threshold** — responses-needed sweep: share of verdicts
//!    adopted from the network vs validated locally ("the number of
//!    responses from peers deemed sufficient in order to decide on a
//!    vote").

use peersdb::modeling::datagen;
use peersdb::net::Outbox;
use peersdb::peersdb::{Node, NodeConfig, NodeEvent, ValidationSource};
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::{Region, ALL};
use peersdb::util::bench::{print_environment, Table};
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;
use peersdb::validation::quorum::QuorumConfig;
use peersdb::validation::CostModel;

fn models() -> Vec<CostModel> {
    vec![
        CostModel::Constant { ns: 5_000_000 },
        CostModel::Logarithmic { base_ns: 1_000_000, ns_per_log_kb: 2_000_000.0 },
        CostModel::Linear { base_ns: 1_000_000, ns_per_kb: 1_000_000.0 },
        CostModel::Polynomial { base_ns: 1_000_000, ns_per_kb: 100_000.0, power: 1.8 },
        CostModel::Exponential {
            base_ns: 1_000_000,
            ns_per_kb: 1_000_000.0,
            growth_per_kb: 0.01,
            cap_ns: 120_000_000_000,
        },
    ]
}

/// Sub-experiment 1: cost scaling table (pure model evaluation — the
/// "function families" of the paper).
fn cost_scaling() {
    println!("1) validation-cost scaling by model and data amount [ms]:");
    let sizes_kb = [1.0, 10.0, 100.0, 1000.0];
    let mut table = Table::new(&["model", "1 KB", "10 KB", "100 KB", "1 MB"]);
    for m in models() {
        let mut cells = vec![m.name().to_string()];
        for &kb in &sizes_kb {
            cells.push(format!("{:.2}", m.cost(kb).as_millis_f64()));
        }
        table.row(&cells);
    }
    table.print();
    // Ordering assertion at the large end (2 MB, past the poly/exp
    // crossover): log < linear < poly < exp.
    let at = |i: usize| models()[i].cost(2000.0).0;
    assert!(at(1) < at(2) && at(2) < at(3) && at(3) <= at(4), "scaling order violated");
}

/// Sub-experiment 2: async vs blocking query latency under load.
fn async_vs_blocking() {
    println!("2) validation-query response time while heavy validation runs [ms]:");
    let mut table = Table::new(&["design", "p50", "p95", "max"]);
    for blocking in [false, true] {
        let specs: Vec<PeerSpec> = (0..3)
            .map(|i| PeerSpec {
                region: Region::Local,
                start_at: Nanos(Duration::from_millis(100).0 * i as u64),
                cfg: NodeConfig {
                    auto_validate: false,
                    blocking_validation: blocking,
                    // No quorum consultation: validations go straight to
                    // the local background worker.
                    quorum: QuorumConfig { fanout: 0, ..Default::default() },
                    // Heavy model: ~2 s per validation.
                    cost_model: CostModel::Constant { ns: 2_000_000_000 },
                    ..NodeConfig::default()
                },
                ..Default::default()
            })
            .collect();
        let model = NetModel::uniform(5.0, 1024.0, 0.0);
        let mut cluster = harness::build_cluster(0x51 + blocking as u64, model, specs);
        cluster.run_for(Duration::from_secs(5));
        // Node 1 receives a stream of contributions to validate...
        let mut rng = Rng::new(3);
        let mut cids = Vec::new();
        for _ in 0..10 {
            let (file, _) = datagen::generate_contribution(&mut rng, 0, 60);
            cids.push(harness::contribute(&mut cluster, 1, &file, "spark-sort"));
            cluster.run_for(Duration::from_millis(300));
        }
        cluster.run_for(Duration::from_secs(3));
        for cid in &cids {
            let c = *cid;
            cluster.with_node(1, move |n: &mut Node, now, out: &mut Outbox<_>| {
                n.validate(now, c, out);
            });
        }
        // ...while node 2 keeps querying node 1 for verdicts.
        let mut lat = peersdb::util::stats::Summary::new();
        let target = cluster.peer_id(1);
        for (i, cid) in cids.iter().cycle().take(40).enumerate() {
            let c = *cid;
            let before = cluster.node(2).metrics.counter("val_replies_received");
            let t0 = cluster.now();
            cluster.with_node(2, move |n: &mut Node, _now, out: &mut Outbox<_>| {
                n.query_verdict_remote(target, c, out);
            });
            // Advance until the reply lands (or 8 s).
            let deadline = t0 + Duration::from_secs(8);
            while cluster.node(2).metrics.counter("val_replies_received") == before
                && cluster.now() < deadline
            {
                cluster.run_for(Duration::from_millis(10));
            }
            if cluster.node(2).metrics.counter("val_replies_received") > before {
                lat.push((cluster.now() - t0).as_millis_f64());
            }
            let _ = i;
        }
        table.row(&[
            if blocking {
                "blocking (ablation)".into()
            } else {
                "async (paper design)".to_string()
            },
            format!("{:.1}", lat.p50()),
            format!("{:.1}", lat.p95()),
            format!("{:.1}", lat.max()),
        ]);
        if blocking {
            assert!(lat.max() > 500.0, "blocking ablation should show slow replies");
        } else {
            assert!(lat.p95() < 100.0, "async design should answer fast");
        }
    }
    table.print();
}

/// Sub-experiment 3: batching a validation backlog.
fn batching() {
    println!("3) time to validate a 64-contribution backlog vs batch size [virtual s]:");
    let mut table = Table::new(&["batch size", "completion [s]", "batches run"]);
    for &batch in &[1usize, 8, 32] {
        let specs = vec![PeerSpec {
            region: Region::Local,
            start_at: Nanos::ZERO,
            cfg: NodeConfig {
                auto_validate: false,
                batch_size: batch,
                batch_flush: Duration::from_millis(200),
                // Expensive per-invocation base cost → batching pays.
                cost_model: CostModel::Linear { base_ns: 500_000_000, ns_per_kb: 5_000_000.0 },
                ..NodeConfig::default()
            },
            ..Default::default()
        }];
        let mut cluster = harness::build_cluster(0xBA + batch as u64, NetModel::default(), specs);
        cluster.run_for(Duration::from_secs(2));
        let mut rng = Rng::new(9);
        let mut cids = Vec::new();
        for _ in 0..64 {
            let (file, _) = datagen::generate_contribution(&mut rng, 1, 60);
            cids.push(harness::contribute(&mut cluster, 0, &file, "spark-grep"));
        }
        let t0 = cluster.now();
        for cid in &cids {
            let c = *cid;
            cluster.with_node(0, move |n: &mut Node, now, out: &mut Outbox<_>| {
                n.validate(now, c, out);
            });
        }
        let deadline = t0 + Duration::from_secs(3600);
        while cluster.node(0).validations.len() < 64 && cluster.now() < deadline {
            cluster.run_for(Duration::from_secs(1));
        }
        assert_eq!(cluster.node(0).validations.len(), 64, "backlog not validated");
        let batches = cluster.node(0).metrics.counter("local_validations_enqueued");
        table.row(&[
            batch.to_string(),
            format!("{:.1}", (cluster.now() - t0).as_secs_f64()),
            batches.to_string(),
        ]);
    }
    table.print();
}

/// Sub-experiment 4: quorum responses-needed sweep.
fn quorum_sweep() {
    println!("4) quorum threshold: verdict source mix + time-to-verdict:");
    let mut table = Table::new(&[
        "responses needed",
        "network-adopted",
        "validated locally",
        "p50 time-to-verdict [ms]",
    ]);
    for &needed in &[1usize, 3, 5] {
        let n = 8;
        let mk_cfg = || NodeConfig {
            auto_validate: true,
            quorum: QuorumConfig { fanout: 6, responses_needed: needed, ..Default::default() },
            cost_model: CostModel::Constant { ns: 50_000_000 },
            ..NodeConfig::default()
        };
        // Heavy stagger so later peers find existing verdicts.
        let specs: Vec<PeerSpec> = (0..n)
            .map(|i| PeerSpec {
                region: ALL[i % ALL.len()],
                start_at: Nanos(Duration::from_secs(20).0 * i as u64),
                cfg: mk_cfg(),
                ..Default::default()
            })
            .collect();
        let mut cluster = harness::build_cluster(0x900 + needed as u64, NetModel::default(), specs);
        cluster.run_for(Duration::from_secs(10));
        let mut rng = Rng::new(31 + needed as u64);
        for i in 0..6 {
            let (file, _) = datagen::generate_contribution(&mut rng, (i % 6) as u32, 60);
            harness::contribute(&mut cluster, 1, &file, "spark-sort");
            cluster.run_for(Duration::from_secs(5));
        }
        cluster.run_for(Duration::from_secs(400));
        let events = harness::drain_events(&mut cluster);
        let (mut network, mut local) = (0, 0);
        for (_, e) in &events {
            if let NodeEvent::ValidationDone { source, .. } = e {
                match source {
                    ValidationSource::Network => network += 1,
                    ValidationSource::Local => local += 1,
                }
            }
        }
        // Pooled time-to-verdict: mean of per-node medians.
        let mut lat = peersdb::util::stats::Summary::new();
        for i in 0..cluster.len() {
            let n_obs = cluster
                .node(i)
                .metrics
                .summary("verdict_latency_ms")
                .map(|s| s.len())
                .unwrap_or(0);
            if n_obs > 0 {
                let p50 = cluster.node_mut(i).metrics.summary_mut("verdict_latency_ms").p50();
                lat.push(p50);
            }
        }
        table.row(&[
            needed.to_string(),
            network.to_string(),
            local.to_string(),
            format!("{:.0}", lat.mean()),
        ]);
    }
    table.print();
    println!("(lower thresholds let peers rely on the network's verdicts sooner,");
    println!(" trading independent re-validation for trust — the paper's tuning knob)");
}

fn main() {
    print_environment("SIMULATION: HARDWARE & SOFTWARE SPECIFICATIONS (Table II analogue)");
    cost_scaling();
    async_vs_blocking();
    batching();
    quorum_sweep();
    println!("sim_validation OK");
}

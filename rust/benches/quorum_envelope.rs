//! Quorum safety envelope under delayed honest verdicts.
//!
//! The attack this maps: a late joiner's validation vote samples a
//! byzantine *majority* that answers instantly with a unanimous lie,
//! while the honest minority's verdicts crawl in over slowed links and
//! miss `QuorumConfig::timeout`. The legacy forced tally then decides
//! from whatever answered — i.e. from the lie. This bench sweeps the
//! quorum knobs (`fanout` × `agreement` × `min_force_verdicts`) against
//! an honest-verdict delay factor and measures, per cell, the
//! *adopted-lie rate*: the fraction of seeded trials in which any honest
//! node ends holding a network-adopted verdict that contradicts ground
//! truth. The result is the empirical safety map — `BENCH_quorum.json`
//! — naming the cliff edge where the envelope fails, plus a rerun of
//! that cliff cell with the `timeout_grace` defense switched on
//! (mirroring `bank::delayed_honest_majority`) showing the same cell
//! held open past the timeout resolves honestly.
//!
//! Cluster shape per trial (fixed, mirroring the bank scenario): node 0
//! is the honest root in asia-east2, nodes 1–4 run
//! `ByzantineValidator` (node 1 authors the one *clean* contribution,
//! so data distribution rides fast links), node 5 is honest in
//! australia-southeast1, and node 6 — the victim voter — starts 40 s
//! late in us-west1 with its links to *both* honest validators slowed
//! by the cell's delay factor. By vote time every early node holds a
//! local verdict, the four byzantine peers answer the voter's
//! `ValQuery` within ~300 ms, and the two honest answers take
//! `factor × ~130 ms` round trips.

use peersdb::codec::Json;
use peersdb::modeling::datagen;
use peersdb::peersdb::NodeConfig;
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::{Region, ALL};
use peersdb::util::bench::{print_environment, Table};
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;
use peersdb::validation::{ByzantineValidator, CostModel, StatsValidator, Validator};

/// Cluster indices, mirroring `bank::delayed_honest_majority`.
const BYZANTINE: [usize; 4] = [1, 2, 3, 4];
const HONEST: [usize; 2] = [0, 5];
const VOTER: usize = 6;
const TRIALS: u64 = 5;

/// The defended rerun's grace window (30 s, as in the bank scenario).
const GRACE: Duration = Duration(30_000_000_000);

struct Cell {
    fanout: usize,
    agreement: f64,
    min_force: usize,
    factor: f64,
}

struct CellResult {
    lie_trials: u64,
    extended: u64,
    rescued: u64,
}

fn node_cfg(fanout: usize, agreement: f64, min_force: usize, grace: Duration) -> NodeConfig {
    let mut cfg = NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 2_000_000, ns_per_kb: 50_000.0 },
        ..NodeConfig::default()
    };
    cfg.quorum.fanout = fanout;
    cfg.quorum.responses_needed = fanout.saturating_sub(1).max(1);
    cfg.quorum.agreement = agreement;
    cfg.quorum.min_force_verdicts = min_force;
    cfg.quorum.timeout_grace = grace;
    cfg
}

/// One seeded trial of one cell. Returns (lie_adopted, votes_extended,
/// votes_rescued_by_grace).
fn run_trial(seed: u64, cell: &Cell, grace: Duration) -> (bool, u64, u64) {
    let cfg = node_cfg(cell.fanout, cell.agreement, cell.min_force, grace);
    let mut specs = Vec::new();
    for i in 0..VOTER {
        let region = if i == 0 { Region::AsiaEast2 } else { ALL[i % ALL.len()] };
        let validator: Box<dyn Validator> = if BYZANTINE.contains(&i) {
            Box::new(ByzantineValidator::default())
        } else {
            Box::new(StatsValidator::default())
        };
        specs.push(PeerSpec {
            region,
            cfg: cfg.clone(),
            validator: Some(validator),
            ..Default::default()
        });
    }
    // The victim voter: late joiner, far from both honest validators.
    specs.push(PeerSpec {
        region: Region::UsWest1,
        start_at: Nanos(Duration::from_secs(40).0),
        cfg: cfg.clone(),
        validator: Some(Box::new(StatsValidator::default())),
        ..Default::default()
    });

    let mut cluster = harness::build_cluster(seed, NetModel::default(), specs);
    // Slow the voter's links to both honest validators before anything
    // runs (links are directed — set both ways, as Fault::SlowLink does).
    for &h in &HONEST {
        cluster.set_link_latency_factor(VOTER, h, cell.factor);
        cluster.set_link_latency_factor(h, VOTER, cell.factor);
    }

    // Warmup: the early cluster joins and settles, then the byzantine
    // author injects the one *clean* contribution. By the time the
    // voter arrives at 40 s, every early node holds a local verdict.
    cluster.run_for(Duration::from_secs(10));
    let (data, _) = datagen::generate_contribution(&mut Rng::new(seed ^ 0xDA7A), 0, 40);
    let cid = harness::contribute(&mut cluster, 1, &data, "workload-0");
    // Long tail: covers the slowest cell's bootstrap-over-slow-link plus
    // a full grace window with margin.
    cluster.run_until(Nanos(Duration::from_secs(240).0));

    let truth = [(cid, false)];
    let lies = harness::false_verdicts(&cluster, &truth, &BYZANTINE);
    let (_forced, extended, rescued) = harness::quorum_totals(&cluster);
    (lies > 0, extended, rescued)
}

fn run_cell(cell: &Cell, grace: Duration, seed_base: u64) -> CellResult {
    let mut r = CellResult { lie_trials: 0, extended: 0, rescued: 0 };
    for t in 0..TRIALS {
        let (lied, extended, rescued) = run_trial(seed_base + t * 7919, cell, grace);
        if lied {
            r.lie_trials += 1;
        }
        r.extended += extended;
        r.rescued += rescued;
    }
    r
}

fn main() {
    print_environment("QUORUM ENVELOPE: ADOPTED-LIE RATE UNDER DELAYED HONEST VERDICTS");
    println!(
        "7-peer clusters, 4 byzantine validators, honest verdicts delayed by `factor`; \
         {TRIALS} seeded trials per cell\n"
    );

    let fanouts = [4usize, 6];
    let agreements = [0.67f64, 0.85];
    let min_forces = [1usize, 2, 5];
    let factors = [1.0f64, 20.0, 60.0, 120.0];

    let mut table =
        Table::new(&["fanout", "agreement", "min_force", "delay ×", "lie rate", "extended"]);
    let mut records: Vec<Json> = Vec::new();
    // The cliff edge: among cells safe at nominal latency (factor 1),
    // the first that adopts the lie once honest verdicts are delayed —
    // the delay flips the verdict, not the parameters alone.
    let mut cliff: Option<Json> = None;
    let mut seed_base = 0x0051_AFE0u64;
    let t0 = std::time::Instant::now();

    for &fanout in &fanouts {
        for &agreement in &agreements {
            let mut safe_at_nominal = false;
            for &min_force in &min_forces {
                for &factor in &factors {
                    let cell = Cell { fanout, agreement, min_force, factor };
                    let r = run_cell(&cell, Duration::ZERO, seed_base);
                    seed_base += 1_000_003;
                    let rate = r.lie_trials as f64 / TRIALS as f64;
                    if factor == 1.0 {
                        safe_at_nominal = r.lie_trials == 0;
                    }
                    table.row(&[
                        fanout.to_string(),
                        format!("{agreement:.2}"),
                        min_force.to_string(),
                        format!("{factor:.0}"),
                        format!("{rate:.2}"),
                        r.extended.to_string(),
                    ]);
                    let rec = Json::obj()
                        .set("fanout", fanout)
                        .set("agreement", agreement)
                        .set("min_force_verdicts", min_force)
                        .set("delay_factor", factor)
                        .set("trials", TRIALS)
                        .set("lie_trials", r.lie_trials)
                        .set("adopted_lie_rate", rate);
                    if cliff.is_none() && safe_at_nominal && factor > 1.0 && r.lie_trials > 0 {
                        cliff = Some(rec.clone());
                    }
                    records.push(rec);
                }
            }
        }
    }
    table.print();

    // Defense rerun: the bank scenario's cell — fanout 6, agreement
    // 0.85, min_force 2, factor 60 — with the grace window on. The
    // rescue counters are the proof the extension engaged; the bank
    // test `scenario_delayed_honest_majority_grace_rescues` owns the
    // hard assertion, this records the measurement alongside the map.
    let cliff_cell = Cell { fanout: 6, agreement: 0.85, min_force: 2, factor: 60.0 };
    let defended = run_cell(&cliff_cell, GRACE, 0x00DE_F300);
    let defended_rate = defended.lie_trials as f64 / TRIALS as f64;
    println!(
        "\ndefended cliff cell (fanout 6, agreement 0.85, min_force 2, delay 60×, grace 30 s): \
         lie rate {defended_rate:.2}, votes extended {}, rescued {}",
        defended.extended, defended.rescued
    );
    match &cliff {
        Some(c) => println!(
            "cliff edge: fanout {} agreement {} min_force {} first adopts the lie at delay {}×",
            c.get("fanout").and_then(Json::as_u64).unwrap_or(0),
            c.get("agreement").and_then(Json::as_f64).unwrap_or(0.0),
            c.get("min_force_verdicts").and_then(Json::as_u64).unwrap_or(0),
            c.get("delay_factor").and_then(Json::as_f64).unwrap_or(0.0),
        ),
        None => println!("cliff edge: no delay-induced adoption observed (unexpected)"),
    }

    let doc = Json::obj()
        .set("bench", "quorum_envelope")
        .set("version", env!("CARGO_PKG_VERSION"))
        .set("trials_per_cell", TRIALS)
        .set("wall_s", t0.elapsed().as_secs_f64())
        .set("cells", Json::Arr(records))
        .set(
            "cliff_edge",
            cliff.unwrap_or_else(|| Json::obj().set("note", "no delay-induced adoption observed")),
        )
        .set(
            "defense",
            Json::obj()
                .set("fanout", 6u64)
                .set("agreement", 0.85)
                .set("min_force_verdicts", 2u64)
                .set("delay_factor", 60.0)
                .set("timeout_grace_ms", 30_000u64)
                .set("trials", TRIALS)
                .set("lie_trials", defended.lie_trials)
                .set("adopted_lie_rate", defended_rate)
                .set("votes_extended", defended.extended)
                .set("votes_rescued_by_grace", defended.rescued),
        );
    std::fs::write("BENCH_quorum.json", doc.pretty()).expect("write BENCH_quorum.json");
    println!("wrote BENCH_quorum.json");
    println!("quorum_envelope OK");
}

//! Testground `transfer` plan (paper §IV-B): transmission of differently
//! sized files under manifold network configurations — instance count,
//! file sizes, latencies, jitter, bandwidth limitations.
//!
//! Regenerates the study as a fetch-time grid: a seeder holds a file, a
//! fetcher retrieves it block-wise (bitswap), and we report completion
//! time per (size × latency × bandwidth) cell plus a jitter column.

use peersdb::net::Outbox;
use peersdb::peersdb::{Node, NodeConfig};
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::Region;
use peersdb::util::bench::{print_environment, Table};
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;

const SIZES_MB: [f64; 4] = [0.25, 1.0, 4.0, 16.0];
const LATENCIES_MS: [f64; 3] = [10.0, 50.0, 150.0];
const BANDWIDTHS_MBIT: [f64; 3] = [10.0, 100.0, 1024.0];

/// One transfer cell: returns fetch completion seconds.
fn run_cell(size_mb: f64, latency_ms: f64, bw_mbit: f64, jitter: f64, seed: u64) -> f64 {
    let model = NetModel::uniform(latency_ms, bw_mbit, jitter);
    let specs = vec![
        PeerSpec {
            region: Region::Local,
            start_at: Nanos::ZERO,
            cfg: NodeConfig { auto_validate: false, ..NodeConfig::default() },
            ..Default::default()
        },
        PeerSpec {
            region: Region::AsiaEast2, // any non-equal region → inter-node latency applies
            start_at: Nanos::ZERO,
            cfg: NodeConfig { auto_validate: false, ..NodeConfig::default() },
            ..Default::default()
        },
    ];
    let mut cluster = harness::build_cluster(seed, model, specs);
    cluster.run_for(Duration::from_secs(10));

    // Seeder (root, node 0) holds the file.
    let mut rng = Rng::new(seed ^ 1);
    let mut data = vec![0u8; (size_mb * 1048576.0) as usize];
    rng.fill_bytes(&mut data);
    let cid = {
        let owned = data.clone();
        cluster.with_node(0, move |n: &mut Node, now, out: &mut Outbox<_>| {
            n.contribute(now, &owned, "transfer-plan", "testground", out)
        })
    };
    // Quiesce announcements, then measure a cold block-wise fetch.
    cluster.run_for(Duration::from_secs(5));
    let already = cluster.node(1).get_file(&cid).is_some();
    let t0 = cluster.now();
    if !already {
        let seeder = cluster.peer_id(0);
        cluster.with_node(1, move |n: &mut Node, now, out: &mut Outbox<_>| {
            n.fetch_cid(now, cid, vec![seeder], out);
        });
    }
    // Run until the fetcher has the file (or timeout).
    let deadline = t0 + Duration::from_secs(600);
    while cluster.node(1).get_file(&cid).is_none() && cluster.now() < deadline {
        cluster.run_for(Duration::from_millis(200));
    }
    assert!(cluster.node(1).get_file(&cid).is_some(), "transfer timed out");
    if already {
        // Auto-replication already moved it; measure from contribution time.
        let s = cluster.node(1).metrics.summary("replication_ms").map(|s| s.mean()).unwrap_or(0.0);
        return s / 1e3;
    }
    (cluster.now() - t0).as_secs_f64()
}

fn main() {
    print_environment("SIMULATION: HARDWARE & SOFTWARE SPECIFICATIONS (Table II analogue)");
    println!("transfer plan: fetch completion time [s] per (file size × latency × bandwidth)\n");

    let mut table = Table::new(&[
        "size", "latency", "10 Mbit/s", "100 Mbit/s", "1024 Mbit/s", "1024 Mbit/s +10% jitter",
    ]);
    for &size in &SIZES_MB {
        for &lat in &LATENCIES_MS {
            let mut cells = vec![format!("{size} MB"), format!("{lat} ms")];
            for &bw in &BANDWIDTHS_MBIT {
                let t = run_cell(size, lat, bw, 0.0, 0x77AA ^ ((size as u64) << 8) ^ lat as u64);
                cells.push(format!("{t:.2}"));
            }
            let tj = run_cell(size, lat, 1024.0, 0.10, 0x77AB ^ (size as u64) << 8 ^ lat as u64);
            cells.push(format!("{tj:.2}"));
            table.row(&cells);
        }
    }
    table.print();

    // Shape checks: time grows with size at fixed bw; shrinks with bw at
    // fixed size; grows with latency at fixed size/bw.
    let t_small = run_cell(0.25, 50.0, 100.0, 0.0, 1);
    let t_big = run_cell(16.0, 50.0, 100.0, 0.0, 2);
    assert!(t_big > t_small * 4.0, "size scaling violated: {t_small} vs {t_big}");
    let t_slow = run_cell(4.0, 50.0, 10.0, 0.0, 3);
    let t_fast = run_cell(4.0, 50.0, 1024.0, 0.0, 4);
    assert!(t_slow > t_fast * 3.0, "bandwidth scaling violated: {t_slow} vs {t_fast}");
    println!("sim_transfer OK");
}

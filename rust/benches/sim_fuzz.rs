//! Testground `fuzz` plan (paper §IV-B): "randomly disconnect and
//! reconnect during transmission".
//!
//! A cluster distributes a stream of contributions while links between
//! random peer pairs flap. The flap schedule is generated up front from
//! the sweep seed and executed through the **scenario harness**
//! (`peersdb::sim::scenario`), so every trial runs the same cluster-wide
//! invariants as `tests/scenarios.rs` — log convergence, quorum safety,
//! DHT routing health, and block availability — instead of an ad-hoc
//! length check, and every trial is replayable bit-for-bit from its
//! seed. We sweep the churn intensity and report convergence time
//! inflation relative to the churn-free baseline.

use peersdb::sim::model::NetModel;
use peersdb::sim::scenario::{self, Fault, Scenario};
use peersdb::util::bench::{print_environment, Table};
use peersdb::util::time::Duration;
use peersdb::util::Rng;

const PEERS: usize = 12;
const FILES: usize = 30;

/// Build one fuzz trial as a declarative scenario: contribution every
/// two virtual seconds, link flaps sampled per round with `flap_prob`.
fn fuzz_scenario(flap_prob: f64, seed: u64) -> Scenario {
    let mut rng = Rng::new(seed ^ 0xF1A2);
    let mut sc = Scenario::named("fuzz-flap", seed, PEERS);
    sc.model = NetModel::uniform(20.0, 512.0, 0.05);
    sc.cfg.auto_validate = false;
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    let mut blocked: Vec<(usize, usize)> = Vec::new();
    let mut t = 0u64;
    for i in 0..FILES {
        // Random link flaps before each contribution round.
        if rng.chance(flap_prob) {
            let a = rng.range(0, PEERS);
            let b = rng.range(0, PEERS);
            if a != b {
                sc = sc.at(t, Fault::BlockPair { a, b });
                blocked.push((a, b));
            }
        }
        if rng.chance(flap_prob * 0.8) && !blocked.is_empty() {
            let k = rng.range(0, blocked.len());
            let (a, b) = blocked.swap_remove(k);
            sc = sc.at(t, Fault::UnblockPair { a, b });
        }
        let node = 1 + rng.range(0, PEERS - 1);
        sc = sc.at(t, Fault::Contribute { node, workload: (i % 6) as u32, rows: 60 });
        t += 2;
    }
    sc
}

fn main() {
    print_environment("SIMULATION: HARDWARE & SOFTWARE SPECIFICATIONS (Table II analogue)");
    println!(
        "fuzz plan: {PEERS} peers, {FILES} contributions, random link disconnect/reconnect\n\
         (scenario harness: full invariant suite at quiesce — convergence,\n\
          quorum safety, routing health, availability)\n"
    );

    let mut table = Table::new(&[
        "flap prob/round", "converged", "virtual time [s]", "msgs dropped (blocked links)",
    ]);
    let mut baseline = f64::NAN;
    for (i, &p) in [0.0, 0.3, 0.6, 0.9].iter().enumerate() {
        let sc = fuzz_scenario(p, 0xF0 + i as u64);
        let report = match scenario::run(&sc) {
            Ok(r) => r,
            Err(e) => panic!("cluster failed invariants under churn p={p}: {e}"),
        };
        let t = report
            .converged_at
            .expect("quiesce poll records convergence")
            .as_secs_f64();
        if i == 0 {
            baseline = t;
        }
        if p > 0.0 {
            assert!(
                report.stats.msgs_dropped_blocked > 0,
                "fuzz produced no drops at p={p} — churn not exercised"
            );
        }
        table.row(&[
            format!("{p:.1}"),
            "yes".into(),
            format!("{t:.0}"),
            report.stats.msgs_dropped_blocked.to_string(),
        ]);
    }
    table.print();

    // Shape: heavier churn costs messages but never convergence — and a
    // replay of the heaviest trial must be bit-identical.
    let heavy = fuzz_scenario(0.9, 0xFF);
    let a = scenario::run(&heavy).expect("heavy churn trial");
    let b = scenario::run(&heavy).expect("heavy churn replay");
    assert_eq!(a, b, "fuzz trial not deterministic");
    let t_heavy = a.converged_at.unwrap().as_secs_f64();
    println!(
        "baseline {baseline:.0}s vs heavy churn {t_heavy:.0}s (inflation {:.2}x), {} drops",
        t_heavy / baseline,
        a.stats.msgs_dropped_blocked
    );
    assert!(a.stats.msgs_dropped_blocked > 0, "fuzz produced no drops — churn not exercised");
    println!("sim_fuzz OK");
}

//! Testground `fuzz` plan (paper §IV-B): "randomly disconnect and
//! reconnect during transmission".
//!
//! A cluster distributes a stream of contributions while links between
//! random peer pairs flap. We sweep the churn intensity and report
//! convergence success and completion-time inflation relative to the
//! churn-free baseline.

use peersdb::modeling::datagen;
use peersdb::peersdb::NodeConfig;
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::Region;
use peersdb::util::bench::{print_environment, Table};
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;

const PEERS: usize = 12;
const FILES: usize = 30;

/// Run one fuzz trial; returns (converged, virtual seconds to converge,
/// messages dropped on blocked links).
fn run_trial(flap_prob: f64, seed: u64) -> (bool, f64, u64) {
    let specs: Vec<PeerSpec> = (0..PEERS)
        .map(|i| PeerSpec {
            region: Region::Local, // single-DC, as in Testground's docker runner
            start_at: Nanos(Duration::from_millis(100).0 * i as u64),
            cfg: NodeConfig { auto_validate: false, ..NodeConfig::default() },
            ..Default::default()
        })
        .collect();
    let mut cluster = harness::build_cluster(seed, NetModel::uniform(20.0, 512.0, 0.05), specs);
    cluster.run_for(Duration::from_secs(10));

    let mut rng = Rng::new(seed ^ 0xF122);
    let mut blocked: Vec<(usize, usize)> = Vec::new();
    for i in 0..FILES {
        // Random link flaps before each contribution round.
        if rng.chance(flap_prob) {
            let a = rng.range(0, PEERS);
            let b = rng.range(0, PEERS);
            if a != b {
                cluster.block_pair(a, b);
                blocked.push((a, b));
            }
        }
        if rng.chance(flap_prob * 0.8) {
            if !blocked.is_empty() {
                let k = rng.range(0, blocked.len());
                let (a, b) = blocked.swap_remove(k);
                cluster.unblock_pair(a, b);
            }
        }
        let wl = (i % 6) as u32;
        let (file, _) = datagen::generate_contribution(&mut rng, wl, 60);
        harness::contribute(&mut cluster, rng.range(1, PEERS), &file, datagen::WORKLOADS[wl as usize]);
        cluster.run_for(Duration::from_secs(2));
    }
    // Heal all links, allow anti-entropy to finish.
    for (a, b) in blocked.drain(..) {
        cluster.unblock_pair(a, b);
    }
    let t_heal = cluster.now();
    let deadline = t_heal + Duration::from_secs(600);
    let mut converged_at = None;
    while cluster.now() < deadline {
        cluster.run_for(Duration::from_secs(5));
        let target = cluster.node(0).contributions.len();
        let all = (0..PEERS).all(|i| {
            cluster.node(i).contributions.len() == FILES && target == FILES
        });
        if all {
            converged_at = Some(cluster.now());
            break;
        }
    }
    let dropped = cluster.stats.msgs_dropped_blocked;
    match converged_at {
        Some(t) => (true, (t - Nanos(0)).as_secs_f64(), dropped),
        None => (false, f64::NAN, dropped),
    }
}

fn main() {
    print_environment("SIMULATION: HARDWARE & SOFTWARE SPECIFICATIONS (Table II analogue)");
    println!("fuzz plan: {PEERS} peers, {FILES} contributions, random link disconnect/reconnect\n");

    let mut table = Table::new(&[
        "flap prob/round", "converged", "virtual time [s]", "msgs dropped (blocked links)",
    ]);
    let mut baseline = f64::NAN;
    for (i, &p) in [0.0, 0.3, 0.6, 0.9].iter().enumerate() {
        let (ok, t, dropped) = run_trial(p, 0xF0 + i as u64);
        if i == 0 {
            baseline = t;
        }
        table.row(&[
            format!("{p:.1}"),
            if ok { "yes".into() } else { "NO".into() },
            format!("{t:.0}"),
            dropped.to_string(),
        ]);
        assert!(ok, "cluster failed to converge under churn p={p}");
    }
    table.print();

    // Shape: heavier churn costs messages but never convergence.
    let (_, t_heavy, dropped_heavy) = run_trial(0.9, 0xFF);
    println!(
        "baseline {baseline:.0}s vs heavy churn {t_heavy:.0}s (inflation {:.2}x), {dropped_heavy} drops",
        t_heavy / baseline
    );
    assert!(dropped_heavy > 0, "fuzz produced no drops — churn not exercised");
    println!("sim_fuzz OK");
}

//! Threaded TCP driver for [`Runner`] nodes.
//!
//! The deployment counterpart of the DES: each node gets a listener
//! thread, per-connection reader threads, and one event-loop thread that
//! owns the runner and serializes all callbacks (the same single-threaded
//! discipline the simulator enforces). Frames are `u32`-length-prefixed
//! canonical-codec messages carrying `(sender PeerId, msg)`.
//!
//! Peer addresses are resolved through a shared [`Directory`] — in a
//! production deployment this would be the DHT's address records; for the
//! loopback clusters in `examples/tcp_cluster.rs` a process-wide map is
//! exactly what Kubernetes DNS gave the paper's prototype.

use crate::codec::bin::{Decode, Encode, Reader as BinReader, Writer};
use crate::net::{Outbox, PeerId, Runner};
use crate::util::time::{Duration as VDuration, Nanos};
use std::collections::{BinaryHeap, HashMap};
use std::io::{Read, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared PeerId → socket address map.
#[derive(Clone, Default)]
pub struct Directory {
    inner: Arc<Mutex<HashMap<PeerId, SocketAddr>>>,
}

impl Directory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&self, id: PeerId, addr: SocketAddr) {
        self.inner.lock().unwrap().insert(id, addr);
    }

    pub fn get(&self, id: &PeerId) -> Option<SocketAddr> {
        self.inner.lock().unwrap().get(id).copied()
    }
}

enum Op<R: Runner> {
    Incoming { from: PeerId, msg: R::Msg },
    Call(Box<dyn FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) + Send>),
    Stop,
}

/// Handle to a running TCP node.
pub struct TcpNode<R: Runner> {
    pub id: PeerId,
    pub addr: SocketAddr,
    tx: Sender<Op<R>>,
    stopping: Arc<std::sync::atomic::AtomicBool>,
    event_thread: Option<JoinHandle<()>>,
    listener_thread: Option<JoinHandle<()>>,
}

struct TimerEntry {
    at: Instant,
    token: u64,
}
impl PartialEq for TimerEntry {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.token == o.token
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (o.at, o.token).cmp(&(self.at, self.token)) // min-heap
    }
}

fn write_frame(stream: &mut TcpStream, from: PeerId, payload: &[u8]) -> std::io::Result<()> {
    let mut hdr = Writer::new();
    from.encode(&mut hdr);
    let head = hdr.into_bytes();
    let total = (head.len() + payload.len()) as u32;
    stream.write_all(&total.to_be_bytes())?;
    stream.write_all(&head)?;
    stream.write_all(payload)?;
    Ok(())
}

const MAX_FRAME: u32 = 64 * 1024 * 1024;

fn read_frame(stream: &mut TcpStream) -> std::io::Result<Option<(PeerId, Vec<u8>)>> {
    let mut len_buf = [0u8; 4];
    if let Err(e) = stream.read_exact(&mut len_buf) {
        return if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Ok(None)
        } else {
            Err(e)
        };
    }
    let len = u32::from_be_bytes(len_buf);
    if len < 32 || len > MAX_FRAME {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    let mut r = BinReader::new(&buf);
    let from = PeerId::decode(&mut r)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad peer id"))?;
    let payload = buf[32..].to_vec();
    Ok(Some((from, payload)))
}

impl<R: Runner + Send + 'static> TcpNode<R>
where
    R::Msg: Send,
{
    /// Start a node: binds a listener on 127.0.0.1, registers in the
    /// directory, runs `on_start`, and begins the event loop.
    pub fn start(runner: R, dir: Directory) -> std::io::Result<TcpNode<R>> {
        let id = runner.id();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        dir.insert(id, addr);
        let (tx, rx) = mpsc::channel::<Op<R>>();

        let stopping = Arc::new(std::sync::atomic::AtomicBool::new(false));

        // Listener: accept → spawn frame-reader per connection.
        let tx_listen = tx.clone();
        let stop_flag = stopping.clone();
        let listener_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_flag.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = conn else { break };
                let tx = tx_listen.clone();
                std::thread::spawn(move || {
                    loop {
                        match read_frame(&mut stream) {
                            Ok(Some((from, payload))) => {
                                let mut r = BinReader::new(&payload);
                                let Ok(msg) = R::Msg::decode(&mut r) else { break };
                                // A closed event loop ends this reader.
                                if tx.send(Op::Incoming { from, msg }).is_err() {
                                    break;
                                }
                            }
                            _ => break,
                        }
                    }
                });
            }
        });

        let event_thread = std::thread::spawn(move || event_loop(runner, rx, dir));
        Ok(TcpNode {
            id,
            addr,
            tx,
            stopping,
            event_thread: Some(event_thread),
            listener_thread: Some(listener_thread),
        })
    }

    /// Run a closure on the event-loop thread against the runner
    /// (API-call injection, mirrors `Cluster::with_node`).
    pub fn call(&self, f: impl FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) + Send + 'static) {
        let _ = self.tx.send(Op::Call(Box::new(f)));
    }

    /// Run a closure returning a value, blocking until it completes.
    pub fn call_sync<T: Send + 'static>(
        &self,
        f: impl FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) -> T + Send + 'static,
    ) -> T {
        let (tx, rx) = mpsc::channel();
        self.call(move |r, now, out| {
            let _ = tx.send(f(r, now, out));
        });
        rx.recv().expect("event loop gone")
    }

    /// Stop the node and join its threads.
    pub fn stop(mut self) {
        let _ = self.tx.send(Op::Stop);
        if let Some(t) = self.event_thread.take() {
            let _ = t.join();
        }
        // Unblock the accept loop; the flag makes it exit.
        self.stopping.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
    }
}

fn event_loop<R: Runner>(mut runner: R, rx: Receiver<Op<R>>, dir: Directory) {
    let epoch = Instant::now();
    let now = |at: Instant| Nanos((at - epoch).as_nanos() as u64);
    let mut timers: BinaryHeap<TimerEntry> = BinaryHeap::new();
    let mut conns: HashMap<PeerId, TcpStream> = HashMap::new();
    let mut out = Outbox::new();
    runner.on_start(now(Instant::now()), &mut out);
    flush(&runner, &mut out, &mut conns, &dir, &mut timers, epoch);

    loop {
        let timeout = timers
            .peek()
            .map(|t| t.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(200));
        match rx.recv_timeout(timeout) {
            Ok(Op::Incoming { from, msg }) => {
                runner.on_message(now(Instant::now()), from, msg, &mut out);
            }
            Ok(Op::Call(f)) => f(&mut runner, now(Instant::now()), &mut out),
            Ok(Op::Stop) => return,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return,
        }
        // Fire due timers.
        while timers.peek().map(|t| t.at <= Instant::now()).unwrap_or(false) {
            let t = timers.pop().unwrap();
            runner.on_timer(now(Instant::now()), t.token, &mut out);
        }
        flush(&runner, &mut out, &mut conns, &dir, &mut timers, epoch);
    }
}

fn flush<R: Runner>(
    runner: &R,
    out: &mut Outbox<R::Msg>,
    conns: &mut HashMap<PeerId, TcpStream>,
    dir: &Directory,
    timers: &mut BinaryHeap<TimerEntry>,
    _epoch: Instant,
) {
    for (token, after) in out.timers.drain(..) {
        timers.push(TimerEntry {
            at: Instant::now() + Duration::from_nanos(after.0),
            token,
        });
    }
    for (to, msg) in out.sends.drain(..) {
        let payload = crate::codec::to_bytes(&msg);
        let stream = match conns.get_mut(&to) {
            Some(s) => s,
            None => {
                let Some(addr) = dir.get(&to) else { continue };
                let Ok(s) = TcpStream::connect_timeout(&addr, Duration::from_millis(500)) else {
                    continue; // unreachable peer: drop, like UDP semantics
                };
                conns.entry(to).or_insert(s)
            }
        };
        if write_frame(stream, runner.id(), &payload).is_err() {
            conns.remove(&to); // stale connection; next send re-dials
        }
    }
}

/// Convert a virtual duration to wall-clock (used by tests).
pub fn to_wall(d: VDuration) -> Duration {
    Duration::from_nanos(d.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::token;
    use crate::util::Rng;
    use std::sync::atomic::{AtomicU64, Ordering};

    struct Echo {
        id: PeerId,
        peer: Option<PeerId>,
        hits: Arc<AtomicU64>,
    }

    impl Runner for Echo {
        type Msg = u64;
        fn id(&self) -> PeerId {
            self.id
        }
        fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
            out.timer(token::pack(token::PEERSDB, 1), VDuration::from_millis(5));
            if let Some(p) = self.peer {
                out.send(p, 1);
            }
        }
        fn on_message(&mut self, _now: Nanos, from: PeerId, msg: u64, out: &mut Outbox<u64>) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            if msg < 6 {
                out.send(from, msg + 1);
            }
        }
        fn on_timer(&mut self, _now: Nanos, _tok: u64, _out: &mut Outbox<u64>) {
            self.hits.fetch_add(100, Ordering::SeqCst);
        }
    }

    #[test]
    fn tcp_ping_pong_and_timers() {
        let mut rng = Rng::new(1);
        let a_id = PeerId::from_rng(&mut rng);
        let b_id = PeerId::from_rng(&mut rng);
        let hits_a = Arc::new(AtomicU64::new(0));
        let hits_b = Arc::new(AtomicU64::new(0));
        let dir = Directory::new();
        let b = TcpNode::start(
            Echo { id: b_id, peer: None, hits: hits_b.clone() },
            dir.clone(),
        )
        .unwrap();
        let a = TcpNode::start(
            Echo { id: a_id, peer: Some(b_id), hits: hits_a.clone() },
            dir.clone(),
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        // b receives 1,3,5 (3 msgs) + ≥1 timer; a receives 2,4,6 + ≥1 timer.
        while Instant::now() < deadline {
            if hits_a.load(Ordering::SeqCst) >= 103 && hits_b.load(Ordering::SeqCst) >= 103 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(hits_a.load(Ordering::SeqCst) >= 103, "a={}", hits_a.load(Ordering::SeqCst));
        assert!(hits_b.load(Ordering::SeqCst) >= 103, "b={}", hits_b.load(Ordering::SeqCst));
        let n = a.call_sync(|r, _, _| r.id());
        assert_eq!(n, a_id);
        a.stop();
        b.stop();
    }
}

//! Threaded TCP driver for [`Runner`] nodes.
//!
//! The deployment counterpart of the DES: each node gets a listener
//! thread, per-connection reader threads, and one event-loop thread that
//! owns the runner and serializes all callbacks (the same single-threaded
//! discipline the simulator enforces). Frames are `u32`-length-prefixed
//! canonical-codec messages carrying `(sender PeerId, msg)`.
//!
//! Peer addresses are resolved through a shared [`Directory`] — in a
//! production deployment this would be the DHT's address records; for the
//! loopback clusters in `examples/tcp_cluster.rs` a process-wide map is
//! exactly what Kubernetes DNS gave the paper's prototype.
//!
//! Fault injection: a shared [`LinkPolicy`] handed to
//! [`TcpNode::start_with_policy`] lets a harness drop or pace frames per
//! directed `(src, dst)` link — the real-socket counterpart of the DES's
//! link-state plane, and what `sim::parity` lowers `Fault::Partition` /
//! `Fault::SlowLink` schedules onto.
//!
//! Lifecycle: [`TcpNode::shutdown`] stops all threads, reaps every
//! `JoinHandle`, and hands the runner back with its state intact — a
//! crash/restart in `sim::parity` is `shutdown()` followed by a fresh
//! `start` of the same runner, mirroring the DES's `set_offline` /
//! `set_online` (which re-runs `on_start`).

use crate::codec::bin::{Decode, Encode, Reader as BinReader, Writer};
use crate::net::{Outbox, PeerId, Runner};
use crate::util::time::{Duration as VDuration, Nanos};
use std::collections::{BinaryHeap, HashMap};
use std::io::{Read, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared PeerId → socket address map.
#[derive(Clone, Default)]
pub struct Directory {
    inner: Arc<Mutex<HashMap<PeerId, SocketAddr>>>,
}

impl Directory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&self, id: PeerId, addr: SocketAddr) {
        self.inner.lock().unwrap().insert(id, addr);
    }

    pub fn get(&self, id: &PeerId) -> Option<SocketAddr> {
        self.inner.lock().unwrap().get(id).copied()
    }

    /// Remove `id`'s registration, but only while it still maps to
    /// `addr`. Shutdown withdraws its own entry this way so a restarted
    /// successor that already re-registered under a fresh address is
    /// never clobbered by the old handle's teardown.
    pub fn remove_if(&self, id: PeerId, addr: SocketAddr) {
        let mut m = self.inner.lock().unwrap();
        if m.get(&id) == Some(&addr) {
            m.remove(&id);
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct LinkRule {
    drop: bool,
    delay: Duration,
}

/// Per-directed-link fault rules applied by reader threads on the
/// receiving node — the real-socket counterpart of the DES link-state
/// plane. [`LinkPolicy::block`] makes frames `from → to` vanish (a
/// partition); [`LinkPolicy::set_delay`] paces their delivery (a slow
/// link). One shared instance is handed to every node of a cluster via
/// [`TcpNode::start_with_policy`]; rules take effect on frames read
/// after the change, no reconnect needed.
#[derive(Clone, Default)]
pub struct LinkPolicy {
    inner: Arc<Mutex<HashMap<(PeerId, PeerId), LinkRule>>>,
}

impl LinkPolicy {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop every frame sent `from → to` (one direction only).
    pub fn block(&self, from: PeerId, to: PeerId) {
        self.inner.lock().unwrap().entry((from, to)).or_default().drop = true;
    }

    /// Let frames `from → to` through again (pacing, if any, persists).
    pub fn unblock(&self, from: PeerId, to: PeerId) {
        let mut m = self.inner.lock().unwrap();
        if let Some(r) = m.get_mut(&(from, to)) {
            r.drop = false;
        }
    }

    /// Heal every blocked link while keeping pacing rules — mirrors
    /// `Fault::Heal`, whose DES lowering unblocks links but leaves
    /// latency multipliers in place until teardown.
    pub fn unblock_all(&self) {
        self.inner.lock().unwrap().retain(|_, r| {
            r.drop = false;
            !r.delay.is_zero()
        });
    }

    /// Delay each frame `from → to` by `delay` before delivery
    /// (pacing). `Duration::ZERO` removes the pacing.
    pub fn set_delay(&self, from: PeerId, to: PeerId, delay: Duration) {
        self.inner.lock().unwrap().entry((from, to)).or_default().delay = delay;
    }

    /// Drop every rule — the teardown reset (`reset_links` in the DES).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }

    fn rule(&self, from: &PeerId, to: &PeerId) -> LinkRule {
        self.inner
            .lock()
            .unwrap()
            .get(&(*from, *to))
            .copied()
            .unwrap_or_default()
    }
}

/// Error returned by [`TcpNode::call`] / [`TcpNode::try_call_sync`]
/// after the node has been stopped: sends after stop are errors, not
/// panics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeStopped;

impl std::fmt::Display for NodeStopped {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tcp node is stopped")
    }
}

impl std::error::Error for NodeStopped {}

enum Op<R: Runner> {
    Incoming { from: PeerId, msg: R::Msg },
    Call(Box<dyn FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) + Send>),
    Stop,
}

struct ReaderSlot {
    stream: TcpStream,
    handle: JoinHandle<()>,
}

/// Handle to a running TCP node.
pub struct TcpNode<R: Runner> {
    pub id: PeerId,
    pub addr: SocketAddr,
    tx: Sender<Op<R>>,
    dir: Directory,
    stopping: Arc<std::sync::atomic::AtomicBool>,
    event_thread: Mutex<Option<JoinHandle<R>>>,
    listener_thread: Mutex<Option<JoinHandle<()>>>,
    readers: Arc<Mutex<Vec<ReaderSlot>>>,
}

struct TimerEntry {
    at: Instant,
    token: u64,
}
impl PartialEq for TimerEntry {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.token == o.token
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (o.at, o.token).cmp(&(self.at, self.token)) // min-heap
    }
}

fn write_frame(stream: &mut TcpStream, from: PeerId, payload: &[u8]) -> std::io::Result<()> {
    let mut hdr = Writer::new();
    from.encode(&mut hdr);
    let head = hdr.into_bytes();
    let total = (head.len() + payload.len()) as u32;
    stream.write_all(&total.to_be_bytes())?;
    stream.write_all(&head)?;
    stream.write_all(payload)?;
    Ok(())
}

/// Frames above this are rejected before any allocation: the length
/// prefix arrives from the network and is otherwise an attacker-chosen
/// `Vec` size (a 4 GiB allocation per connection). A hostile prefix
/// costs the peer its connection, nothing else.
const MAX_FRAME: u32 = 64 * 1024 * 1024;

fn read_frame(stream: &mut TcpStream) -> std::io::Result<Option<(PeerId, Vec<u8>)>> {
    let mut len_buf = [0u8; 4];
    if let Err(e) = stream.read_exact(&mut len_buf) {
        return if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Ok(None)
        } else {
            Err(e)
        };
    }
    let len = u32::from_be_bytes(len_buf);
    if len < 32 || len > MAX_FRAME {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    let mut r = BinReader::new(&buf);
    let from = PeerId::decode(&mut r)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad peer id"))?;
    let payload = buf[32..].to_vec();
    Ok(Some((from, payload)))
}

impl<R: Runner + Send + 'static> TcpNode<R>
where
    R::Msg: Send,
{
    /// Start a node: binds a listener on 127.0.0.1, registers in the
    /// directory, runs `on_start`, and begins the event loop.
    pub fn start(runner: R, dir: Directory) -> std::io::Result<TcpNode<R>> {
        Self::start_with_policy(runner, dir, LinkPolicy::default())
    }

    /// Like [`TcpNode::start`], with a shared [`LinkPolicy`] applied to
    /// every frame this node receives.
    pub fn start_with_policy(
        runner: R,
        dir: Directory,
        policy: LinkPolicy,
    ) -> std::io::Result<TcpNode<R>> {
        let id = runner.id();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        dir.insert(id, addr);
        let (tx, rx) = mpsc::channel::<Op<R>>();

        let stopping = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Arc<Mutex<Vec<ReaderSlot>>> = Arc::new(Mutex::new(Vec::new()));

        // Listener: accept → spawn frame-reader per connection. Each
        // reader registers in `readers` (with a handle to its stream)
        // so shutdown can unblock and join it.
        let tx_listen = tx.clone();
        let stop_flag = stopping.clone();
        let readers_reg = readers.clone();
        let listener_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_flag.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = conn else { break };
                let registered = stream.try_clone().ok();
                let tx = tx_listen.clone();
                let policy = policy.clone();
                let handle = std::thread::spawn(move || {
                    loop {
                        match read_frame(&mut stream) {
                            Ok(Some((from, payload))) => {
                                let rule = policy.rule(&from, &id);
                                if rule.drop {
                                    continue; // partitioned: the frame vanishes
                                }
                                if !rule.delay.is_zero() {
                                    std::thread::sleep(rule.delay); // paced link
                                }
                                let mut r = BinReader::new(&payload);
                                let Ok(msg) = R::Msg::decode(&mut r) else { break };
                                // A closed event loop ends this reader.
                                if tx.send(Op::Incoming { from, msg }).is_err() {
                                    break;
                                }
                            }
                            // Clean EOF, an oversized/hostile frame, or
                            // a mid-frame I/O error: drop the connection
                            // (the sender re-dials) — never the node.
                            _ => break,
                        }
                    }
                });
                let mut reg = readers_reg.lock().unwrap();
                reg.retain(|s: &ReaderSlot| !s.handle.is_finished());
                if let Some(stream) = registered {
                    reg.push(ReaderSlot { stream, handle });
                }
            }
        });

        let dir_loop = dir.clone();
        let event_thread = std::thread::spawn(move || event_loop(runner, rx, dir_loop));
        Ok(TcpNode {
            id,
            addr,
            tx,
            dir,
            stopping,
            event_thread: Mutex::new(Some(event_thread)),
            listener_thread: Mutex::new(Some(listener_thread)),
            readers,
        })
    }
}

impl<R: Runner> TcpNode<R> {
    /// Run a closure on the event-loop thread against the runner
    /// (API-call injection, mirrors `Cluster::with_node`). Errors —
    /// instead of panicking — once the node is stopped.
    pub fn call(
        &self,
        f: impl FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) + Send + 'static,
    ) -> Result<(), NodeStopped> {
        self.tx.send(Op::Call(Box::new(f))).map_err(|_| NodeStopped)
    }

    /// Run a closure returning a value, blocking until it completes;
    /// errors once the node is stopped.
    pub fn try_call_sync<T: Send + 'static>(
        &self,
        f: impl FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) -> T + Send + 'static,
    ) -> Result<T, NodeStopped> {
        let (tx, rx) = mpsc::channel();
        self.call(move |r, now, out| {
            let _ = tx.send(f(r, now, out));
        })?;
        rx.recv().map_err(|_| NodeStopped)
    }

    /// [`TcpNode::try_call_sync`] for paths that hold a live node by
    /// construction; panics if the node was stopped underneath.
    pub fn call_sync<T: Send + 'static>(
        &self,
        f: impl FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) -> T + Send + 'static,
    ) -> T {
        self.try_call_sync(f).expect("event loop gone")
    }

    /// Stop the node, join every thread it spawned (event loop,
    /// listener, per-connection readers), withdraw its directory entry,
    /// and hand back the runner with its state intact. Idempotent: the
    /// first call returns `Some(runner)`, later calls (and `Drop`)
    /// return `None` without touching anything.
    pub fn shutdown(&self) -> Option<R> {
        let event = self.event_thread.lock().unwrap().take()?;
        // Flag first: the accept loop must not hand the wake-up
        // connection below to a fresh reader thread.
        self.stopping.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = self.tx.send(Op::Stop);
        let runner = event.join().ok();
        self.dir.remove_if(self.id, self.addr);
        // Unblock the accept loop; the flag makes it exit.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.listener_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        // Readers block in `read_exact` (or a pacing sleep); closing
        // their sockets errors the read and the dead Op channel ends
        // any send, so every join terminates.
        let slots = std::mem::take(&mut *self.readers.lock().unwrap());
        for slot in slots {
            let _ = slot.stream.shutdown(std::net::Shutdown::Both);
            let _ = slot.handle.join();
        }
        runner
    }

    /// Stop the node and join its threads, discarding the runner.
    pub fn stop(self) {
        let _ = self.shutdown();
    }

    /// Number of this node's threads still alive (event loop, listener,
    /// readers). Zero after [`TcpNode::shutdown`]; the lifecycle tests
    /// assert on it.
    pub fn thread_count(&self) -> usize {
        let mut n = 0;
        if self
            .event_thread
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|t| !t.is_finished())
        {
            n += 1;
        }
        if self
            .listener_thread
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|t| !t.is_finished())
        {
            n += 1;
        }
        n + self
            .readers
            .lock()
            .unwrap()
            .iter()
            .filter(|s| !s.handle.is_finished())
            .count()
    }
}

impl<R: Runner> Drop for TcpNode<R> {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

fn event_loop<R: Runner>(mut runner: R, rx: Receiver<Op<R>>, dir: Directory) -> R {
    let epoch = Instant::now();
    let now = |at: Instant| Nanos((at - epoch).as_nanos() as u64);
    let mut timers: BinaryHeap<TimerEntry> = BinaryHeap::new();
    let mut conns: HashMap<PeerId, TcpStream> = HashMap::new();
    let mut out = Outbox::new();
    runner.on_start(now(Instant::now()), &mut out);
    flush(&runner, &mut out, &mut conns, &dir, &mut timers, epoch);

    loop {
        let timeout = timers
            .peek()
            .map(|t| t.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(200));
        match rx.recv_timeout(timeout) {
            Ok(Op::Incoming { from, msg }) => {
                runner.on_message(now(Instant::now()), from, msg, &mut out);
            }
            Ok(Op::Call(f)) => f(&mut runner, now(Instant::now()), &mut out),
            Ok(Op::Stop) => return runner,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return runner,
        }
        // Fire due timers.
        while timers.peek().map(|t| t.at <= Instant::now()).unwrap_or(false) {
            let t = timers.pop().unwrap();
            runner.on_timer(now(Instant::now()), t.token, &mut out);
        }
        flush(&runner, &mut out, &mut conns, &dir, &mut timers, epoch);
    }
}

fn flush<R: Runner>(
    runner: &R,
    out: &mut Outbox<R::Msg>,
    conns: &mut HashMap<PeerId, TcpStream>,
    dir: &Directory,
    timers: &mut BinaryHeap<TimerEntry>,
    _epoch: Instant,
) {
    for (token, after) in out.timers.drain(..) {
        timers.push(TimerEntry {
            at: Instant::now() + Duration::from_nanos(after.0),
            token,
        });
    }
    for (to, msg) in out.sends.drain(..) {
        let payload = crate::codec::to_bytes(&msg);
        let stream = match conns.get_mut(&to) {
            Some(s) => s,
            None => {
                let Some(addr) = dir.get(&to) else { continue };
                let Ok(s) = TcpStream::connect_timeout(&addr, Duration::from_millis(500)) else {
                    continue; // unreachable peer: drop, like UDP semantics
                };
                conns.entry(to).or_insert(s)
            }
        };
        if write_frame(stream, runner.id(), &payload).is_err() {
            conns.remove(&to); // stale connection; next send re-dials
        }
    }
}

/// Convert a virtual duration to wall-clock (used by tests).
pub fn to_wall(d: VDuration) -> Duration {
    Duration::from_nanos(d.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::token;
    use crate::util::Rng;
    use std::sync::atomic::{AtomicU64, Ordering};

    struct Echo {
        id: PeerId,
        peer: Option<PeerId>,
        hits: Arc<AtomicU64>,
    }

    impl Runner for Echo {
        type Msg = u64;
        fn id(&self) -> PeerId {
            self.id
        }
        fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
            out.timer(token::pack(token::PEERSDB, 1), VDuration::from_millis(5));
            if let Some(p) = self.peer {
                out.send(p, 1);
            }
        }
        fn on_message(&mut self, _now: Nanos, from: PeerId, msg: u64, out: &mut Outbox<u64>) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            if msg < 6 {
                out.send(from, msg + 1);
            }
        }
        fn on_timer(&mut self, _now: Nanos, _tok: u64, _out: &mut Outbox<u64>) {
            self.hits.fetch_add(100, Ordering::SeqCst);
        }
    }

    fn ids(n: usize) -> Vec<PeerId> {
        let mut rng = Rng::new(1);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    /// Messages delivered (timer hits excluded).
    fn msgs(hits: &AtomicU64) -> u64 {
        hits.load(Ordering::SeqCst) % 100
    }

    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn tcp_ping_pong_and_timers() {
        let mut rng = Rng::new(1);
        let a_id = PeerId::from_rng(&mut rng);
        let b_id = PeerId::from_rng(&mut rng);
        let hits_a = Arc::new(AtomicU64::new(0));
        let hits_b = Arc::new(AtomicU64::new(0));
        let dir = Directory::new();
        let b = TcpNode::start(
            Echo { id: b_id, peer: None, hits: hits_b.clone() },
            dir.clone(),
        )
        .unwrap();
        let a = TcpNode::start(
            Echo { id: a_id, peer: Some(b_id), hits: hits_a.clone() },
            dir.clone(),
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        // b receives 1,3,5 (3 msgs) + ≥1 timer; a receives 2,4,6 + ≥1 timer.
        while Instant::now() < deadline {
            if hits_a.load(Ordering::SeqCst) >= 103 && hits_b.load(Ordering::SeqCst) >= 103 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(hits_a.load(Ordering::SeqCst) >= 103, "a={}", hits_a.load(Ordering::SeqCst));
        assert!(hits_b.load(Ordering::SeqCst) >= 103, "b={}", hits_b.load(Ordering::SeqCst));
        let n = a.call_sync(|r, _, _| r.id());
        assert_eq!(n, a_id);
        a.stop();
        b.stop();
    }

    #[test]
    fn framing_round_trips_over_a_real_socket_pair() {
        let (mut client, mut server) = socket_pair();
        let from = ids(1)[0];
        let payload: Vec<u8> = (0..1000u32).flat_map(|x| x.to_be_bytes()).collect();
        write_frame(&mut client, from, &payload).unwrap();
        let (got_from, got_payload) = read_frame(&mut server).unwrap().unwrap();
        assert_eq!(got_from, from);
        assert_eq!(got_payload, payload);
        // Clean shutdown reads as end-of-stream, not an error.
        client.shutdown(std::net::Shutdown::Both).unwrap();
        assert!(read_frame(&mut server).unwrap().is_none());
    }

    #[test]
    fn framing_reassembles_partial_reads() {
        let (mut client, mut server) = socket_pair();
        let from = ids(1)[0];
        let payload = vec![0xABu8; 257];
        // Serialize the frame, then trickle it in three chunks with
        // pauses: read_frame must reassemble across short reads.
        let mut wire = Vec::new();
        {
            let mut hdr = Writer::new();
            from.encode(&mut hdr);
            let head = hdr.into_bytes();
            wire.extend_from_slice(&((head.len() + payload.len()) as u32).to_be_bytes());
            wire.extend_from_slice(&head);
            wire.extend_from_slice(&payload);
        }
        let writer = std::thread::spawn(move || {
            for chunk in wire.chunks(wire.len() / 3 + 1) {
                client.write_all(chunk).unwrap();
                client.flush().unwrap();
                std::thread::sleep(Duration::from_millis(20));
            }
            client
        });
        let (got_from, got_payload) = read_frame(&mut server).unwrap().unwrap();
        assert_eq!(got_from, from);
        assert_eq!(got_payload, payload);
        drop(writer.join().unwrap());
    }

    #[test]
    fn mid_frame_connection_drop_is_an_error() {
        let (mut client, mut server) = socket_pair();
        // Claim a 100-byte frame, deliver 10 bytes, hang up.
        client.write_all(&100u32.to_be_bytes()).unwrap();
        client.write_all(&[0u8; 10]).unwrap();
        drop(client);
        let err = read_frame(&mut server).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn hostile_length_prefix_is_rejected_without_allocating() {
        for bad in [u32::MAX, MAX_FRAME + 1, 4, 0] {
            let (mut client, mut server) = socket_pair();
            client.write_all(&bad.to_be_bytes()).unwrap();
            client.write_all(b"junk that must never be read as a frame").unwrap();
            let err = read_frame(&mut server).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "prefix {bad:#x}");
        }
    }

    #[test]
    fn hostile_prefix_drops_the_connection_not_the_node() {
        let peer_ids = ids(2);
        let hits = Arc::new(AtomicU64::new(0));
        let dir = Directory::new();
        let node = TcpNode::start(
            Echo { id: peer_ids[0], peer: None, hits: hits.clone() },
            dir.clone(),
        )
        .unwrap();

        // Attacker claims a 4 GiB frame; the node must close this
        // connection rather than allocate.
        let mut evil = TcpStream::connect(node.addr).unwrap();
        evil.write_all(&u32::MAX.to_be_bytes()).unwrap();
        let mut probe = [0u8; 1];
        // The read unblocks with EOF (Ok(0)) or a reset once the reader
        // thread drops its end; either proves the connection died.
        evil.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        match evil.read(&mut probe) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("unexpected {n} bytes from the node"),
        }

        // The node itself is still alive: a well-formed frame from a
        // fresh connection is processed.
        let mut good = TcpStream::connect(node.addr).unwrap();
        write_frame(&mut good, peer_ids[1], &crate::codec::to_bytes(&7u64)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while msgs(&hits) == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(msgs(&hits), 1, "node wedged after hostile prefix");
        assert_eq!(node.call_sync(|r, _, _| r.id()), peer_ids[0]);
        node.stop();
    }

    #[test]
    fn shutdown_reaps_threads_and_is_idempotent() {
        let peer_ids = ids(2);
        let hits_a = Arc::new(AtomicU64::new(0));
        let hits_b = Arc::new(AtomicU64::new(0));
        let dir = Directory::new();
        let b = TcpNode::start(
            Echo { id: peer_ids[1], peer: None, hits: hits_b.clone() },
            dir.clone(),
        )
        .unwrap();
        let a = TcpNode::start(
            Echo { id: peer_ids[0], peer: Some(peer_ids[1]), hits: hits_a.clone() },
            dir.clone(),
        )
        .unwrap();
        // Wait for the ping-pong so both nodes have live reader threads.
        let deadline = Instant::now() + Duration::from_secs(5);
        while (msgs(&hits_a) < 3 || msgs(&hits_b) < 3) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(a.thread_count() >= 3, "expected event+listener+reader threads");

        let runner = a.shutdown().expect("first shutdown returns the runner");
        assert_eq!(runner.id, peer_ids[0], "runner state survives shutdown");
        assert_eq!(a.thread_count(), 0, "all JoinHandles reaped");
        assert!(a.shutdown().is_none(), "double-stop is a no-op");
        assert_eq!(dir.get(&peer_ids[0]), None, "directory entry withdrawn");

        // Sends after stop are errors, not panics.
        assert_eq!(a.call(|_, _, _| {}), Err(NodeStopped));
        assert_eq!(a.try_call_sync(|r, _, _| r.id()), Err(NodeStopped));

        // The reclaimed runner restarts on fresh threads (the parity
        // harness's crash → restart path) and answers again.
        let a2 = TcpNode::start(runner, dir.clone()).unwrap();
        assert_eq!(a2.call_sync(|r, _, _| r.id()), peer_ids[0]);
        assert!(dir.get(&peer_ids[0]).is_some());
        a2.stop();
        b.stop();
        assert_eq!(b.thread_count(), 0);
    }

    #[test]
    fn link_policy_drops_then_delivers_after_unblock() {
        let peer_ids = ids(2);
        let hits_a = Arc::new(AtomicU64::new(0));
        let hits_b = Arc::new(AtomicU64::new(0));
        let dir = Directory::new();
        let policy = LinkPolicy::new();
        policy.block(peer_ids[0], peer_ids[1]);
        let b = TcpNode::start_with_policy(
            Echo { id: peer_ids[1], peer: None, hits: hits_b.clone() },
            dir.clone(),
            policy.clone(),
        )
        .unwrap();
        let a = TcpNode::start_with_policy(
            Echo { id: peer_ids[0], peer: Some(peer_ids[1]), hits: hits_a.clone() },
            dir.clone(),
            policy.clone(),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(msgs(&hits_b), 0, "blocked a→b frame leaked through");

        // Heal and resend: the same connection starts delivering.
        policy.unblock_all();
        a.call(|r, _, out| {
            if let Some(p) = r.peer {
                out.send(p, 1);
            }
        })
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while msgs(&hits_b) == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(msgs(&hits_b) >= 1, "unblocked link still dropping");

        // Pacing delays but never loses frames.
        policy.set_delay(peer_ids[0], peer_ids[1], Duration::from_millis(50));
        let before = msgs(&hits_b);
        a.call(|r, _, out| {
            if let Some(p) = r.peer {
                out.send(p, 1);
            }
        })
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while msgs(&hits_b) <= before && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(msgs(&hits_b) > before, "paced frame never arrived");
        a.stop();
        b.stop();
    }
}

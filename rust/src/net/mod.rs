//! Sans-io networking core.
//!
//! Every protocol in this crate is written as a deterministic state
//! machine implementing [`Runner`]: it reacts to `(now, event)` pairs and
//! pushes sends/timers into an [`Outbox`]. Two drivers execute runners:
//!
//! * [`crate::sim`] — the discrete-event simulator (virtual time), used by
//!   all experiments; and
//! * [`tcp`] — a threaded TCP driver (wall-clock time) proving the same
//!   cores run over real sockets.
//!
//! This mirrors how the paper's prototype separates its service routine
//! from go-libp2p transports, and is what makes the evaluation
//! reproducible: given a seed, a simulation run is bit-identical.
//! `sim::parity` runs the same fault schedules through both drivers
//! (partitions and slow links lowered onto [`LinkPolicy`]) and
//! differentially compares the convergence outcomes.

pub mod tcp;

pub use tcp::{Directory, LinkPolicy, NodeStopped, TcpNode};

use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use crate::util::hex;
use crate::util::time::{Duration, Nanos};

/// A peer identity: 32 opaque bytes (in production a public-key hash —
/// here drawn from the experiment's seeded PRNG).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PeerId(pub [u8; 32]);

impl PeerId {
    pub fn from_rng(rng: &mut crate::util::Rng) -> PeerId {
        PeerId(rng.bytes32())
    }

    /// Short printable prefix.
    pub fn short(&self) -> String {
        hex::encode(&self.0[..4])
    }
}

impl std::fmt::Debug for PeerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PeerId({})", self.short())
    }
}

impl std::fmt::Display for PeerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&hex::encode(&self.0))
    }
}

impl Encode for PeerId {
    fn encode(&self, w: &mut Writer) {
        w.put_raw(&self.0);
    }
}

impl Decode for PeerId {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(PeerId(r.get_raw(32)?.try_into().unwrap()))
    }
}

/// Wire-size computation, used by the simulator's bandwidth model on
/// every simulated send. All protocol messages (dht, bitswap, pubsub,
/// peersdb) override with an O(1) computation that is *exact* — equal to
/// the encoded length, property-tested in `tests/prop.rs` — so
/// `Cluster::dispatch` never allocates a `Writer` and the bandwidth
/// model charges precisely what the codec would emit. The default
/// (encode and measure) remains as a correct-by-construction fallback
/// for ad-hoc test runners.
pub trait WireSize: Encode {
    fn wire_size(&self) -> usize {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.len()
    }
}

/// Commands a runner emits in response to an event.
pub struct Outbox<M> {
    /// Messages to transmit.
    pub sends: Vec<(PeerId, M)>,
    /// Timers to arm: `(token, fires_after)`. Tokens are runner-scoped.
    pub timers: Vec<(u64, Duration)>,
}

impl<M> Default for Outbox<M> {
    fn default() -> Self {
        Outbox {
            sends: Vec::new(),
            timers: Vec::new(),
        }
    }
}

impl<M> Outbox<M> {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn send(&mut self, to: PeerId, msg: M) {
        self.sends.push((to, msg));
    }

    #[inline]
    pub fn timer(&mut self, token: u64, after: Duration) {
        self.timers.push((token, after));
    }

    pub fn drain_into(&mut self, other: &mut Outbox<M>) {
        other.sends.append(&mut self.sends);
        other.timers.append(&mut self.timers);
    }

    pub fn is_empty(&self) -> bool {
        self.sends.is_empty() && self.timers.is_empty()
    }
}

/// A sans-io protocol node. Implementations must be deterministic: all
/// randomness comes from a seeded PRNG owned by the runner, and all time
/// from the `now` argument.
pub trait Runner {
    type Msg: Clone + Encode + Decode + WireSize;

    /// The runner's own identity.
    fn id(&self) -> PeerId;

    /// Called once when the node comes online (or back online after a
    /// restart).
    fn on_start(&mut self, now: Nanos, out: &mut Outbox<Self::Msg>);

    /// A message arrived from `from`.
    fn on_message(&mut self, now: Nanos, from: PeerId, msg: Self::Msg, out: &mut Outbox<Self::Msg>);

    /// A previously-armed timer fired.
    fn on_timer(&mut self, now: Nanos, token: u64, out: &mut Outbox<Self::Msg>);

    /// Estimated CPU cost of processing one inbound message, used by the
    /// simulator's per-node compute model. Default: flat 20 µs.
    fn processing_cost(&self, _msg: &Self::Msg) -> Duration {
        Duration::from_micros(20)
    }
}

/// Timer-token namespacing helpers: the top byte selects the protocol,
/// the remaining 56 bits are protocol-private.
pub mod token {
    pub const DHT: u8 = 1;
    pub const BITSWAP: u8 = 2;
    pub const PUBSUB: u8 = 3;
    pub const PEERSDB: u8 = 4;
    pub const VALIDATION: u8 = 5;

    #[inline]
    pub fn pack(proto: u8, inner: u64) -> u64 {
        debug_assert!(inner < (1 << 56));
        ((proto as u64) << 56) | inner
    }

    #[inline]
    pub fn proto(token: u64) -> u8 {
        (token >> 56) as u8
    }

    #[inline]
    pub fn inner(token: u64) -> u64 {
        token & ((1 << 56) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn peer_id_roundtrip() {
        let mut rng = Rng::new(1);
        let id = PeerId::from_rng(&mut rng);
        let b = crate::codec::to_bytes(&id);
        assert_eq!(crate::codec::from_bytes::<PeerId>(&b).unwrap(), id);
        assert_eq!(b.len(), 32);
    }

    #[test]
    fn token_packing() {
        let t = token::pack(token::DHT, 0xABCDEF);
        assert_eq!(token::proto(t), token::DHT);
        assert_eq!(token::inner(t), 0xABCDEF);
    }

    #[test]
    fn outbox_drain() {
        let mut rng = Rng::new(2);
        let a = PeerId::from_rng(&mut rng);
        let mut o1: Outbox<u64> = Outbox::new();
        let mut o2: Outbox<u64> = Outbox::new();
        o1.send(a, 42);
        o1.timer(7, Duration::from_millis(5));
        o1.drain_into(&mut o2);
        assert!(o1.is_empty());
        assert_eq!(o2.sends.len(), 1);
        assert_eq!(o2.timers.len(), 1);
    }
}

impl WireSize for u64 {
    fn wire_size(&self) -> usize {
        crate::codec::bin::varint_len(*self)
    }
}

//! IPFS-Log: a content-addressed, append-only Merkle log CRDT.
//!
//! The paper's contributions store is "an append-only log with traversable
//! history, which in turn uses the IPFS-Log internally" — an
//! operation-based conflict-free replicated data type. Each [`Entry`] is
//! content-addressed (its CID is the hash of its canonical encoding) and
//! references the log's previous heads, forming a Merkle DAG. Replication
//! is therefore just block exchange: learn remote heads (via pubsub),
//! fetch missing entries (via bitswap), [`Log::join_entry`] them, and the
//! logs converge — commutatively, associatively, idempotently (verified by
//! property tests in `rust/tests/prop.rs`).

use crate::cid::{Cid, Codec};
use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use crate::net::PeerId;
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// One log entry. `lamport` is a Lamport clock establishing a total order
/// consistent with causality; ties break on `(author, cid)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub author: PeerId,
    pub lamport: u64,
    /// CIDs of the heads this entry supersedes (Merkle parents).
    pub next: Vec<Cid>,
    /// Opaque payload (the stores define its schema).
    pub payload: Vec<u8>,
}

impl Encode for Entry {
    fn encode(&self, w: &mut Writer) {
        self.author.encode(w);
        w.put_varint(self.lamport);
        self.next.encode(w);
        w.put_bytes(&self.payload);
    }
}

impl Decode for Entry {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Entry {
            author: PeerId::decode(r)?,
            lamport: r.get_varint()?,
            next: Vec::decode(r)?,
            payload: r.get_bytes()?.to_vec(),
        })
    }
}

impl Entry {
    /// The entry's content identifier.
    pub fn cid(&self) -> Cid {
        Cid::of(Codec::LogEntry, &crate::codec::to_bytes(self))
    }
}

/// Result of joining a remote entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Join {
    /// Entry was new and has been added.
    Added,
    /// Entry was already present.
    Known,
    /// Entry hash did not match its CID (tampered) — rejected.
    Rejected,
}

/// The replicated log. Entries keyed by CID; heads are entries no other
/// entry references.
#[derive(Clone, Debug, Default)]
pub struct Log {
    entries: HashMap<Cid, Entry>,
    /// Entries referenced by some entry (present or not).
    referenced: BTreeSet<Cid>,
    heads: BTreeSet<Cid>,
    /// Referenced but absent (maintained incrementally — the replication
    /// fetch list is queried on hot paths).
    missing: BTreeSet<Cid>,
    max_lamport: u64,
}

impl Log {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, cid: &Cid) -> bool {
        self.entries.contains_key(cid)
    }

    pub fn get(&self, cid: &Cid) -> Option<&Entry> {
        self.entries.get(cid)
    }

    /// Current heads, sorted (deterministic across replicas).
    pub fn heads(&self) -> Vec<Cid> {
        self.heads.iter().copied().collect()
    }

    pub fn max_lamport(&self) -> u64 {
        self.max_lamport
    }

    /// Parents referenced by known entries but not yet present — the
    /// fetch list during replication. O(missing), maintained
    /// incrementally.
    pub fn missing(&self) -> Vec<Cid> {
        self.missing.iter().copied().collect()
    }

    pub fn missing_is_empty(&self) -> bool {
        self.missing.is_empty()
    }

    /// Append a new local entry; returns `(cid, entry)`.
    pub fn append(&mut self, author: PeerId, payload: Vec<u8>) -> (Cid, Entry) {
        let entry = Entry {
            author,
            lamport: self.max_lamport + 1,
            next: self.heads(),
            payload,
        };
        let cid = entry.cid();
        self.insert(cid, entry.clone());
        (cid, entry)
    }

    /// Join a replicated entry after verifying its content address.
    pub fn join_entry(&mut self, cid: Cid, entry: Entry) -> Join {
        if self.entries.contains_key(&cid) {
            return Join::Known;
        }
        if entry.cid() != cid {
            return Join::Rejected;
        }
        self.insert(cid, entry);
        Join::Added
    }

    /// Join every entry of another log (set union).
    pub fn join(&mut self, other: &Log) {
        // BTreeMap pass for deterministic insertion order.
        let sorted: BTreeMap<&Cid, &Entry> = other.entries.iter().collect();
        for (cid, entry) in sorted {
            if !self.entries.contains_key(cid) {
                self.insert(*cid, entry.clone());
            }
        }
    }

    fn insert(&mut self, cid: Cid, entry: Entry) {
        self.max_lamport = self.max_lamport.max(entry.lamport);
        for parent in &entry.next {
            self.referenced.insert(*parent);
            self.heads.remove(parent);
            if !self.entries.contains_key(parent) {
                self.missing.insert(*parent);
            }
        }
        if !self.referenced.contains(&cid) {
            self.heads.insert(cid);
        }
        self.missing.remove(&cid);
        self.entries.insert(cid, entry);
    }

    /// All entries in deterministic total order: `(lamport, author, cid)`.
    /// The order is consistent with causality (a parent's lamport is
    /// strictly smaller than its child's).
    pub fn traverse(&self) -> Vec<(Cid, &Entry)> {
        let mut v: Vec<(Cid, &Entry)> = self.entries.iter().map(|(c, e)| (*c, e)).collect();
        v.sort_by(|a, b| {
            (a.1.lamport, a.1.author, a.0).cmp(&(b.1.lamport, b.1.author, b.0))
        });
        v
    }

    /// Payloads in traversal order (the store-level view).
    pub fn payloads(&self) -> impl Iterator<Item = &[u8]> {
        self.traverse()
            .into_iter()
            .map(|(_, e)| e.payload.as_slice())
            .collect::<Vec<_>>()
            .into_iter()
    }

    /// Structural digest of the log state: hash over sorted entry CIDs.
    /// Two replicas are converged iff their digests match.
    pub fn digest(&self) -> [u8; 32] {
        use sha2::{Digest, Sha256};
        let mut cids: Vec<&Cid> = self.entries.keys().collect();
        cids.sort();
        let mut h = Sha256::new();
        for c in cids {
            h.update([c.codec as u8]);
            h.update(c.hash);
        }
        h.finalize().into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn pid(rng: &mut Rng) -> PeerId {
        PeerId::from_rng(rng)
    }

    #[test]
    fn entry_roundtrip_and_cid_stability() {
        let mut rng = Rng::new(1);
        let e = Entry {
            author: pid(&mut rng),
            lamport: 7,
            next: vec![Cid::of_raw(b"x")],
            payload: b"data".to_vec(),
        };
        let b = crate::codec::to_bytes(&e);
        let d = crate::codec::from_bytes::<Entry>(&b).unwrap();
        assert_eq!(d, e);
        assert_eq!(d.cid(), e.cid());
    }

    #[test]
    fn append_chains_heads() {
        let mut rng = Rng::new(2);
        let me = pid(&mut rng);
        let mut log = Log::new();
        let (c1, _) = log.append(me, b"a".to_vec());
        assert_eq!(log.heads(), vec![c1]);
        let (c2, e2) = log.append(me, b"b".to_vec());
        assert_eq!(log.heads(), vec![c2]);
        assert_eq!(e2.next, vec![c1]);
        assert_eq!(e2.lamport, 2);
        assert_eq!(log.len(), 2);
    }

    #[test]
    fn join_converges_two_writers() {
        let mut rng = Rng::new(3);
        let (a, b) = (pid(&mut rng), pid(&mut rng));
        let mut la = Log::new();
        let mut lb = Log::new();
        la.append(a, b"a1".to_vec());
        lb.append(b, b"b1".to_vec());
        la.append(a, b"a2".to_vec());
        // Cross-join.
        la.join(&lb);
        lb.join(&la);
        assert_eq!(la.digest(), lb.digest());
        assert_eq!(la.len(), 3);
        assert_eq!(la.heads(), lb.heads());
        // Both heads present (concurrent branches).
        assert_eq!(la.heads().len(), 2);
        // Appending after join merges the branches.
        let (c, e) = la.append(a, b"merge".to_vec());
        assert_eq!(e.next.len(), 2);
        assert_eq!(la.heads(), vec![c]);
    }

    #[test]
    fn join_idempotent_and_commutative() {
        let mut rng = Rng::new(4);
        let (a, b) = (pid(&mut rng), pid(&mut rng));
        let mut la = Log::new();
        let mut lb = Log::new();
        for i in 0..5 {
            la.append(a, vec![i]);
            lb.append(b, vec![100 + i]);
        }
        let mut ab = la.clone();
        ab.join(&lb);
        let mut ba = lb.clone();
        ba.join(&la);
        assert_eq!(ab.digest(), ba.digest());
        let before = ab.digest();
        ab.join(&lb); // idempotent
        ab.join(&la);
        assert_eq!(ab.digest(), before);
    }

    #[test]
    fn tampered_entry_rejected() {
        let mut rng = Rng::new(5);
        let a = pid(&mut rng);
        let mut log = Log::new();
        let entry = Entry { author: a, lamport: 1, next: vec![], payload: b"x".to_vec() };
        let cid = entry.cid();
        let mut forged = entry.clone();
        forged.payload = b"y".to_vec();
        assert_eq!(log.join_entry(cid, forged), Join::Rejected);
        assert_eq!(log.join_entry(cid, entry), Join::Added);
    }

    #[test]
    fn missing_parents_tracked() {
        let mut rng = Rng::new(6);
        let a = pid(&mut rng);
        let mut origin = Log::new();
        origin.append(a, b"1".to_vec());
        let (c2, e2) = origin.append(a, b"2".to_vec());
        // A replica that only received the newest entry knows what's missing.
        let mut replica = Log::new();
        replica.join_entry(c2, e2);
        assert_eq!(replica.missing().len(), 1);
        assert!(origin.contains(&replica.missing()[0]));
        // Head of replica is the entry it has (its parent is absent).
        assert_eq!(replica.heads(), vec![c2]);
        // After fetching the parent, nothing is missing and heads match.
        let (c1, e1) = origin.traverse()[0];
        replica.join_entry(c1, (*e1).clone());
        assert!(replica.missing().is_empty());
        assert_eq!(replica.digest(), origin.digest());
        assert_eq!(replica.heads(), origin.heads());
    }

    #[test]
    fn traversal_is_causal_and_deterministic() {
        let mut rng = Rng::new(7);
        let (a, b) = (pid(&mut rng), pid(&mut rng));
        let mut la = Log::new();
        la.append(a, b"a1".to_vec());
        let mut lb = la.clone();
        lb.append(b, b"b1".to_vec());
        la.join(&lb);
        la.append(a, b"a2".to_vec());
        let order: Vec<u64> = la.traverse().iter().map(|(_, e)| e.lamport).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted, "lamport order");
        // Parent lamports strictly smaller than children.
        for (_, e) in la.traverse() {
            for p in &e.next {
                assert!(la.get(p).unwrap().lamport < e.lamport);
            }
        }
    }
}

//! Minimal HTTP/1.1 server exposing the [`ApiRequest`] surface over a
//! running [`TcpNode`].
//!
//! Routes:
//!
//! | Method & path               | ApiRequest                        |
//! |-----------------------------|-----------------------------------|
//! | `GET  /status`              | `Status`                          |
//! | `POST /contributions?workload=w&platform=p` | `Contribute` (body = file) |
//! | `POST /private`             | `PutPrivate` (body = file)        |
//! | `GET  /file/<cid>`          | `GetFile`                         |
//! | `GET  /contributions[?workload=w]` | `Query`                    |
//! | `GET  /verdict/<cid>`       | `GetVerdict`                      |
//! | `POST /validate/<cid>`      | `Validate`                        |
//! | `GET  /metrics`             | `Metrics`                         |

use crate::api::{dispatch, ApiRequest, ApiResponse};
use crate::cid::Cid;
use crate::net::tcp::TcpNode;
use crate::peersdb::Node;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub query: Vec<(String, String)>,
    pub body: Vec<u8>,
}

/// Parse an HTTP/1.1 request from a stream.
pub fn parse_request(stream: &mut TcpStream) -> std::io::Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let target = parts.next().unwrap_or("/").to_string();
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let query = query_str
        .split('&')
        .filter(|s| !s.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), String::new()),
        })
        .collect();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(64 * 1024 * 1024)];
    reader.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, query, body })
}

fn write_response(stream: &mut TcpStream, status: u16, content_type: &str, body: &[u8]) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body);
}

/// Translate an HTTP request into the internal abstraction.
pub fn route(req: &HttpRequest) -> Result<ApiRequest, String> {
    let q = |name: &str| {
        req.query
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.clone())
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/status") => Ok(ApiRequest::Status),
        ("GET", "/metrics") => Ok(ApiRequest::Metrics),
        ("GET", "/contributions") => Ok(ApiRequest::Query { workload: q("workload") }),
        ("POST", "/contributions") => Ok(ApiRequest::Contribute {
            workload: q("workload").unwrap_or_else(|| "unknown".into()),
            platform: q("platform").unwrap_or_else(|| "unknown".into()),
            data: req.body.clone(),
        }),
        ("POST", "/private") => Ok(ApiRequest::PutPrivate { data: req.body.clone() }),
        ("GET", p) if p.starts_with("/file/") => {
            let cid = Cid::parse(&p[6..]).ok_or("bad cid")?;
            Ok(ApiRequest::GetFile { cid })
        }
        ("GET", p) if p.starts_with("/verdict/") => {
            let cid = Cid::parse(&p[9..]).ok_or("bad cid")?;
            Ok(ApiRequest::GetVerdict { cid })
        }
        ("POST", p) if p.starts_with("/validate/") => {
            let cid = Cid::parse(&p[10..]).ok_or("bad cid")?;
            Ok(ApiRequest::Validate { cid })
        }
        _ => Err(format!("no route for {} {}", req.method, req.path)),
    }
}

/// HTTP server bound to a [`TcpNode`]; one thread per connection.
pub struct HttpServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl HttpServer {
    pub fn start(node: Arc<TcpNode<Node>>) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = conn else { break };
                let node = node.clone();
                std::thread::spawn(move || {
                    let Ok(req) = parse_request(&mut stream) else { return };
                    match route(&req) {
                        Err(e) => write_response(&mut stream, 404, "text/plain", e.as_bytes()),
                        Ok(api_req) => {
                            let resp =
                                node.call_sync(move |n, now, out| dispatch(n, now, api_req, out));
                            match resp {
                                ApiResponse::Json(j) => write_response(
                                    &mut stream,
                                    200,
                                    "application/json",
                                    j.to_string().as_bytes(),
                                ),
                                ApiResponse::Bytes(b) => {
                                    write_response(&mut stream, 200, "application/octet-stream", &b)
                                }
                                ApiResponse::Text(t) => {
                                    write_response(&mut stream, 200, "text/plain", t.as_bytes())
                                }
                                ApiResponse::NotFound(e) => {
                                    write_response(&mut stream, 404, "text/plain", e.as_bytes())
                                }
                                ApiResponse::BadRequest(e) => {
                                    write_response(&mut stream, 400, "text/plain", e.as_bytes())
                                }
                            }
                        }
                    }
                });
            }
        });
        Ok(HttpServer { addr, stop, thread: Some(thread) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Tiny HTTP client for tests and the CLI.
pub fn http_get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
    http_call(addr, "GET", path, &[])
}

pub fn http_post(addr: SocketAddr, path: &str, body: &[u8]) -> std::io::Result<(u16, Vec<u8>)> {
    http_call(addr, "POST", path, body)
}

fn http_call(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.write_all(body)?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.trim_end().split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::json::Json;
    use crate::net::tcp::Directory;
    use crate::peersdb::NodeConfig;
    use crate::util::Rng;

    #[test]
    fn http_round_trip_over_real_sockets() {
        let mut rng = Rng::new(1);
        let id = crate::net::PeerId::from_rng(&mut rng);
        let node = Node::new(id, NodeConfig::default(), 2);
        let dir = Directory::new();
        let tcp = Arc::new(TcpNode::start(node, dir).unwrap());
        let server = HttpServer::start(tcp.clone()).unwrap();

        // Status.
        let (code, body) = http_get(server.addr, "/status").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.path("contributions").unwrap().as_u64(), Some(0));

        // POST a contribution, then read it back.
        let (code, body) = http_post(
            server.addr,
            "/contributions?workload=spark-sort&platform=gcp",
            b"file-bytes",
        )
        .unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let cid = j.path("cid").unwrap().as_str().unwrap().to_string();
        let (code, body) = http_get(server.addr, &format!("/file/{cid}")).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, b"file-bytes");

        // Query + 404s.
        let (code, body) = http_get(server.addr, "/contributions?workload=spark-sort").unwrap();
        assert_eq!(code, 200);
        assert!(std::str::from_utf8(&body).unwrap().contains(&cid));
        let (code, _) = http_get(server.addr, "/file/junk").unwrap();
        assert_eq!(code, 404);
        let (code, _) = http_get(server.addr, "/nope").unwrap();
        assert_eq!(code, 404);

        server.stop();
        match Arc::try_unwrap(tcp) {
            Ok(t) => t.stop(),
            Err(_) => panic!("server threads still hold the node"),
        }
    }
}

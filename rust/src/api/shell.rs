//! Shell API: line-oriented commands over the same internal abstraction
//! (the prototype's second access path besides HTTP).

use crate::api::{ApiRequest, ApiResponse};
use crate::cid::Cid;
use crate::util::hex;

/// Parse one shell line into an [`ApiRequest`].
///
/// ```text
/// status
/// metrics
/// contribute <workload> <platform> <hex-bytes>
/// private <hex-bytes>
/// get <cid>
/// query [workload]
/// verdict <cid>
/// validate <cid>
/// ```
pub fn parse_line(line: &str) -> Result<ApiRequest, String> {
    let mut it = line.split_whitespace();
    let cmd = it.next().ok_or("empty command")?;
    let parse_cid = |s: Option<&str>| -> Result<Cid, String> {
        Cid::parse(s.ok_or("missing cid")?).ok_or_else(|| "bad cid".to_string())
    };
    match cmd {
        "status" => Ok(ApiRequest::Status),
        "metrics" => Ok(ApiRequest::Metrics),
        "contribute" => {
            let workload = it.next().ok_or("missing workload")?.to_string();
            let platform = it.next().ok_or("missing platform")?.to_string();
            let data = hex::decode(it.next().ok_or("missing data")?).ok_or("bad hex")?;
            Ok(ApiRequest::Contribute { workload, platform, data })
        }
        "private" => {
            let data = hex::decode(it.next().ok_or("missing data")?).ok_or("bad hex")?;
            Ok(ApiRequest::PutPrivate { data })
        }
        "get" => Ok(ApiRequest::GetFile { cid: parse_cid(it.next())? }),
        "query" => Ok(ApiRequest::Query { workload: it.next().map(|s| s.to_string()) }),
        "verdict" => Ok(ApiRequest::GetVerdict { cid: parse_cid(it.next())? }),
        "validate" => Ok(ApiRequest::Validate { cid: parse_cid(it.next())? }),
        other => Err(format!("unknown command: {other}")),
    }
}

/// Render a response for terminal output.
pub fn render(resp: &ApiResponse) -> String {
    match resp {
        ApiResponse::Json(j) => j.pretty(),
        ApiResponse::Bytes(b) => hex::encode(b),
        ApiResponse::Text(t) => t.clone(),
        ApiResponse::NotFound(e) => format!("not found: {e}"),
        ApiResponse::BadRequest(e) => format!("bad request: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_commands() {
        assert_eq!(parse_line("status").unwrap(), ApiRequest::Status);
        let r = parse_line("contribute spark-sort gcp deadbeef").unwrap();
        let ApiRequest::Contribute { workload, data, .. } = r else { panic!() };
        assert_eq!(workload, "spark-sort");
        assert_eq!(data, vec![0xde, 0xad, 0xbe, 0xef]);
        assert!(parse_line("query").is_ok());
        assert!(parse_line("query spark-sort").is_ok());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("").is_err());
        assert!(parse_line("bogus").is_err());
        assert!(parse_line("get notacid").is_err());
        assert!(parse_line("contribute w p nothex!").is_err());
    }

    #[test]
    fn render_shapes() {
        assert_eq!(render(&ApiResponse::Bytes(vec![1, 2])), "0102");
        assert!(render(&ApiResponse::NotFound("x".into())).contains("not found"));
    }
}

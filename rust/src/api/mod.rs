//! API layer: HTTP and shell front-ends (Fig. 3 of the paper).
//!
//! "APIs translate requests (e.g. get, post, query) to an internal
//! abstraction, suitable for the service component." Here the internal
//! abstraction is [`ApiRequest`]/[`ApiResponse`]; both the HTTP server
//! ([`http`]) and the shell REPL ([`shell`]) translate into it, and
//! [`dispatch`] executes it against a [`Node`] (on the node's event-loop
//! thread when run over TCP).

pub mod http;
pub mod shell;

use crate::cid::Cid;
use crate::codec::json::Json;
use crate::net::Outbox;
use crate::peersdb::{Message, Node};
use crate::util::time::Nanos;

/// The internal request abstraction shared by all API front-ends.
#[derive(Clone, Debug, PartialEq)]
pub enum ApiRequest {
    Status,
    /// POST a contribution file.
    Contribute { workload: String, platform: String, data: Vec<u8> },
    /// Store a private (unshared) file.
    PutPrivate { data: Vec<u8> },
    /// GET a file by root CID.
    GetFile { cid: Cid },
    /// Query contribution records, optionally by workload.
    Query { workload: Option<String> },
    /// Stored validation verdict for a CID.
    GetVerdict { cid: Cid },
    /// Trigger validation of a CID.
    Validate { cid: Cid },
    /// Metrics report.
    Metrics,
}

/// The internal response abstraction.
#[derive(Clone, Debug, PartialEq)]
pub enum ApiResponse {
    Json(Json),
    Bytes(Vec<u8>),
    Text(String),
    NotFound(String),
    BadRequest(String),
}

/// Execute a request against the node. `now`/`out` come from the driver
/// (timer wheel + transport), exactly like any other node callback.
pub fn dispatch(
    node: &mut Node,
    now: Nanos,
    req: ApiRequest,
    out: &mut Outbox<Message>,
) -> ApiResponse {
    match req {
        ApiRequest::Status => {
            let j = Json::obj()
                .set("peer_id", node.peer_id().to_string())
                .set("bootstrapped", node.is_bootstrapped())
                .set("contributions", node.contributions.len() as u64)
                .set("validations", node.validations.len() as u64)
                .set("blocks", node.bs.len() as u64)
                .set("bytes_stored", node.bs.bytes_stored() as u64);
            ApiResponse::Json(j)
        }
        ApiRequest::Contribute { workload, platform, data } => {
            if data.is_empty() {
                return ApiResponse::BadRequest("empty contribution".into());
            }
            let cid = node.contribute(now, &data, &workload, &platform, out);
            ApiResponse::Json(Json::obj().set("cid", cid.to_string_full()))
        }
        ApiRequest::PutPrivate { data } => {
            if data.is_empty() {
                return ApiResponse::BadRequest("empty file".into());
            }
            let cid = node.put_private(&data);
            ApiResponse::Json(Json::obj().set("cid", cid.to_string_full()).set("private", true))
        }
        ApiRequest::GetFile { cid } => match node.get_file(&cid) {
            Some(data) => ApiResponse::Bytes(data),
            None => ApiResponse::NotFound(format!("no local data for {cid}")),
        },
        ApiRequest::Query { workload } => {
            let list = node.query_contributions(|c| {
                workload.as_deref().map(|w| c.workload == w).unwrap_or(true)
            });
            let arr: Vec<Json> = list
                .into_iter()
                .map(|c| {
                    Json::obj()
                        .set("cid", c.data_cid.to_string_full())
                        .set("workload", c.workload)
                        .set("platform", c.platform)
                        .set("size_bytes", c.size_bytes)
                        .set("author", c.author.to_string())
                        .set("created_at", c.created_at)
                })
                .collect();
            ApiResponse::Json(Json::obj().set("contributions", Json::Arr(arr)))
        }
        ApiRequest::GetVerdict { cid } => match node.validations.get(&cid) {
            Some(r) => ApiResponse::Json(
                Json::obj()
                    .set("cid", cid.to_string_full())
                    .set(
                        "verdict",
                        match r.verdict {
                            crate::stores::documents::Verdict::Valid => "valid",
                            crate::stores::documents::Verdict::Invalid => "invalid",
                            crate::stores::documents::Verdict::Inconclusive => "inconclusive",
                        },
                    )
                    .set("score", r.score),
            ),
            None => ApiResponse::NotFound(format!("no verdict for {cid}")),
        },
        ApiRequest::Validate { cid } => {
            if !node.bs.has(&cid) {
                return ApiResponse::NotFound(format!("no local data for {cid}"));
            }
            node.validate(now, cid, out);
            ApiResponse::Json(Json::obj().set("scheduled", true))
        }
        ApiRequest::Metrics => ApiResponse::Text(node.metrics.report()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peersdb::NodeConfig;
    use crate::util::Rng;

    fn node() -> Node {
        let mut rng = Rng::new(1);
        let id = crate::net::PeerId::from_rng(&mut rng);
        Node::new(id, NodeConfig::default(), 2)
    }

    #[test]
    fn status_and_contribute_roundtrip() {
        let mut n = node();
        let mut out = Outbox::new();
        let r = dispatch(&mut n, Nanos(0), ApiRequest::Status, &mut out);
        let ApiResponse::Json(j) = r else { panic!() };
        assert_eq!(j.path("contributions").unwrap().as_u64(), Some(0));

        let r = dispatch(
            &mut n,
            Nanos(1),
            ApiRequest::Contribute {
                workload: "spark-sort".into(),
                platform: "gcp".into(),
                data: b"rows".to_vec(),
            },
            &mut out,
        );
        let ApiResponse::Json(j) = r else { panic!() };
        let cid = Cid::parse(j.path("cid").unwrap().as_str().unwrap()).unwrap();

        let r = dispatch(&mut n, Nanos(2), ApiRequest::GetFile { cid }, &mut out);
        assert_eq!(r, ApiResponse::Bytes(b"rows".to_vec()));

        let query = ApiRequest::Query { workload: Some("spark-sort".into()) };
        let r = dispatch(&mut n, Nanos(3), query, &mut out);
        let ApiResponse::Json(j) = r else { panic!() };
        assert_eq!(j.path("contributions").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn errors_are_structured() {
        let mut n = node();
        let mut out = Outbox::new();
        let missing = Cid::of_raw(b"missing");
        assert!(matches!(
            dispatch(&mut n, Nanos(0), ApiRequest::GetFile { cid: missing }, &mut out),
            ApiResponse::NotFound(_)
        ));
        assert!(matches!(
            dispatch(
                &mut n,
                Nanos(0),
                ApiRequest::Contribute { workload: "w".into(), platform: "p".into(), data: vec![] },
                &mut out
            ),
            ApiResponse::BadRequest(_)
        ));
    }
}

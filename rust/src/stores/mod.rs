//! OrbitDB-like data stores backed by the IPFS substrate.
//!
//! * [`ContributionsStore`] — the paper's *contributions store*: an
//!   `EventLogStore` (append-only, fully replicated among peers) whose
//!   payloads are [`Contribution`] records referencing performance-data
//!   files by CID. "References are shared via OrbitDB among peers in the
//!   contributions store, fully replicated, granting access to training
//!   data without individual storage."
//! * [`ValidationsStore`] — the *validations store*: a `DocumentStore`
//!   holding per-CID validation verdicts, local-only (non-replicated) but
//!   queryable by other peers on request.
//! * [`KvStore`] — a small key-value store for node state (private data
//!   bookkeeping, workflow checkpoints).

pub mod contributions;
pub mod documents;
pub mod kv;

pub use contributions::{Contribution, ContributionsStore};
pub use documents::{DocumentStore, ValidationRecord, ValidationsStore, Verdict};
pub use kv::KvStore;

/// Address of a replicated store: its name determines the pubsub topic
/// and is the rendezvous by which peers find each other's replicas.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct StoreAddress(pub String);

impl StoreAddress {
    pub fn topic(&self) -> crate::pubsub::Topic {
        crate::pubsub::Topic::named(&self.0)
    }
}

//! The contributions store: an event-log store of performance-data
//! references (§III-B of the paper).

use crate::cid::Cid;
use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use crate::ipfs_log::{Entry, Join, Log};
use crate::net::PeerId;
use std::collections::BTreeSet;

/// One shared performance-data contribution. The actual data lives in the
/// blockstore under `data_cid`; this record is what replicates in the log.
/// The attribute fields implement the paper's "the data format of the
/// contributions store could also be extended with additional attributes,
/// e.g., in order to filter CIDs by cloud platforms".
#[derive(Clone, Debug, PartialEq)]
pub struct Contribution {
    /// Root CID of the performance-data file.
    pub data_cid: Cid,
    /// Contributing peer.
    pub author: PeerId,
    /// Dataflow workload identifier (e.g. "spark-sort", "flink-wordcount").
    pub workload: String,
    /// Cloud platform / cluster the data was recorded on.
    pub platform: String,
    /// Compressed size of the referenced file in bytes.
    pub size_bytes: u64,
    /// Unix-like timestamp (virtual ns in simulations).
    pub created_at: u64,
}

impl Encode for Contribution {
    fn encode(&self, w: &mut Writer) {
        self.data_cid.encode(w);
        self.author.encode(w);
        w.put_str(&self.workload);
        w.put_str(&self.platform);
        w.put_varint(self.size_bytes);
        w.put_varint(self.created_at);
    }
}

impl Decode for Contribution {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Contribution {
            data_cid: Cid::decode(r)?,
            author: PeerId::decode(r)?,
            workload: r.get_str()?.to_string(),
            platform: r.get_str()?.to_string(),
            size_bytes: r.get_varint()?,
            created_at: r.get_varint()?,
        })
    }
}

/// EventLogStore over [`Log`] with `Contribution` payloads.
#[derive(Clone, Debug, Default)]
pub struct ContributionsStore {
    log: Log,
    /// Referenced data CIDs: membership tests plus deterministic,
    /// decode-free iteration (the availability-repair cycle walks this
    /// instead of re-decoding every log entry payload).
    data_cids: BTreeSet<Cid>,
}

impl ContributionsStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    pub fn log(&self) -> &Log {
        &self.log
    }

    pub fn heads(&self) -> Vec<Cid> {
        self.log.heads()
    }

    pub fn missing(&self) -> Vec<Cid> {
        self.log.missing()
    }

    pub fn digest(&self) -> [u8; 32] {
        self.log.digest()
    }

    /// Does the store already reference this data CID?
    pub fn contains_data(&self, cid: &Cid) -> bool {
        self.data_cids.contains(cid)
    }

    /// Every data CID referenced by any entry, in CID order. O(1) to
    /// obtain and free of payload decoding, unlike [`Self::iter`].
    pub fn data_cids(&self) -> &BTreeSet<Cid> {
        &self.data_cids
    }

    pub fn contains_entry(&self, cid: &Cid) -> bool {
        self.log.contains(cid)
    }

    /// Append a local contribution; returns the log entry `(cid, entry)`
    /// for blockstore persistence + provider announcement.
    pub fn add(&mut self, author: PeerId, c: &Contribution) -> (Cid, Entry) {
        self.data_cids.insert(c.data_cid);
        self.log.append(author, crate::codec::to_bytes(c))
    }

    /// Join a replicated entry (verified against its CID).
    pub fn join_entry(&mut self, cid: Cid, entry: Entry) -> Join {
        let res = self.log.join_entry(cid, entry);
        if res == Join::Added {
            if let Some(e) = self.log.get(&cid) {
                if let Ok(c) = crate::codec::from_bytes::<Contribution>(&e.payload) {
                    self.data_cids.insert(c.data_cid);
                }
            }
        }
        res
    }

    /// Get the raw log entry (for serving replication requests).
    pub fn entry(&self, cid: &Cid) -> Option<&Entry> {
        self.log.get(cid)
    }

    /// All contributions in deterministic causal order. Malformed
    /// payloads (never produced by this codebase) are skipped.
    pub fn iter(&self) -> Vec<Contribution> {
        self.log
            .traverse()
            .into_iter()
            .filter_map(|(_, e)| crate::codec::from_bytes::<Contribution>(&e.payload).ok())
            .collect()
    }

    /// Filtered view, e.g. by workload or platform (§III-D pre-filtering).
    pub fn filter(&self, pred: impl Fn(&Contribution) -> bool) -> Vec<Contribution> {
        self.iter().into_iter().filter(|c| pred(c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn contribution(rng: &mut Rng, workload: &str) -> Contribution {
        let data = rng.bytes32().to_vec();
        Contribution {
            data_cid: Cid::of_raw(&data),
            author: PeerId::from_rng(rng),
            workload: workload.to_string(),
            platform: "gcp-e2-standard-2".into(),
            size_bytes: 9060,
            created_at: 0,
        }
    }

    #[test]
    fn contribution_roundtrip() {
        let mut rng = Rng::new(1);
        let c = contribution(&mut rng, "spark-sort");
        let b = crate::codec::to_bytes(&c);
        assert_eq!(crate::codec::from_bytes::<Contribution>(&b).unwrap(), c);
    }

    #[test]
    fn add_and_iterate_in_order() {
        let mut rng = Rng::new(2);
        let me = PeerId::from_rng(&mut rng);
        let mut s = ContributionsStore::new();
        let c1 = contribution(&mut rng, "spark-sort");
        let c2 = contribution(&mut rng, "flink-wordcount");
        s.add(me, &c1);
        s.add(me, &c2);
        let all = s.iter();
        assert_eq!(all, vec![c1.clone(), c2]);
        assert!(s.contains_data(&c1.data_cid));
        assert_eq!(s.data_cids().len(), 2);
        assert!(s.data_cids().contains(&c1.data_cid));
    }

    #[test]
    fn replication_converges() {
        let mut rng = Rng::new(3);
        let (a, b) = (PeerId::from_rng(&mut rng), PeerId::from_rng(&mut rng));
        let mut sa = ContributionsStore::new();
        let mut sb = ContributionsStore::new();
        let ca = contribution(&mut rng, "spark-pagerank");
        let cb = contribution(&mut rng, "spark-kmeans");
        let (ea_cid, ea) = sa.add(a, &ca);
        let (eb_cid, eb) = sb.add(b, &cb);
        assert_eq!(sa.join_entry(eb_cid, eb), Join::Added);
        assert_eq!(sb.join_entry(ea_cid, ea), Join::Added);
        assert_eq!(sa.digest(), sb.digest());
        assert_eq!(sa.iter(), sb.iter());
        assert!(sa.contains_data(&cb.data_cid));
    }

    #[test]
    fn filter_by_attributes() {
        let mut rng = Rng::new(4);
        let me = PeerId::from_rng(&mut rng);
        let mut s = ContributionsStore::new();
        for w in ["spark-sort", "spark-sort", "flink-wordcount"] {
            let c = contribution(&mut rng, w);
            s.add(me, &c);
        }
        assert_eq!(s.filter(|c| c.workload == "spark-sort").len(), 2);
        assert_eq!(s.filter(|c| c.platform == "aws").len(), 0);
    }
}

//! Document store + the validations store built on it.
//!
//! OrbitDB's `DocumentStore` equivalent: keyed documents with put/get/
//! delete/query. The paper instantiates one as the *validations store*:
//! "each user maintains a local data structure in IPFS with validation
//! results for particular CIDs, called validations store, which can be
//! consulted if needed or used to share validation data with other peers
//! upon request." It is local-only (never replicated wholesale); peers
//! answer targeted queries from it.

use crate::cid::Cid;
use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use crate::net::PeerId;
use std::collections::BTreeMap;

/// Generic document store: string key → encoded document.
#[derive(Clone, Debug)]
pub struct DocumentStore<D> {
    docs: BTreeMap<String, D>,
}

impl<D> Default for DocumentStore<D> {
    fn default() -> Self {
        DocumentStore { docs: BTreeMap::new() }
    }
}

impl<D: Clone> DocumentStore<D> {
    pub fn new() -> Self {
        DocumentStore { docs: BTreeMap::new() }
    }

    pub fn put(&mut self, key: impl Into<String>, doc: D) {
        self.docs.insert(key.into(), doc);
    }

    pub fn get(&self, key: &str) -> Option<&D> {
        self.docs.get(key)
    }

    pub fn delete(&mut self, key: &str) -> Option<D> {
        self.docs.remove(key)
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    pub fn query(&self, pred: impl Fn(&D) -> bool) -> Vec<(&str, &D)> {
        self.docs
            .iter()
            .filter(|(_, d)| pred(d))
            .map(|(k, d)| (k.as_str(), d))
            .collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &D)> {
        self.docs.iter().map(|(k, d)| (k.as_str(), d))
    }
}

/// Outcome of validating one performance-data contribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Verdict {
    Valid = 0,
    Invalid = 1,
    Inconclusive = 2,
}

impl Verdict {
    fn from_u8(v: u8) -> Result<Verdict, DecodeError> {
        match v {
            0 => Ok(Verdict::Valid),
            1 => Ok(Verdict::Invalid),
            2 => Ok(Verdict::Inconclusive),
            _ => Err(DecodeError("bad verdict")),
        }
    }
}

impl Encode for Verdict {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(*self as u8);
    }
}
impl Decode for Verdict {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Verdict::from_u8(r.get_u8()?)
    }
}

/// One validation result for a contribution CID.
#[derive(Clone, Debug, PartialEq)]
pub struct ValidationRecord {
    pub data_cid: Cid,
    pub verdict: Verdict,
    /// Quality score in [0, 1] produced by the validation pipeline
    /// (e.g. the k-NN plausibility score from the AOT model).
    pub score: f64,
    pub validator: PeerId,
    pub validated_at: u64,
    /// Wall/virtual time the validation computation took, ns.
    pub cost_ns: u64,
}

impl Encode for ValidationRecord {
    fn encode(&self, w: &mut Writer) {
        self.data_cid.encode(w);
        self.verdict.encode(w);
        w.put_f64(self.score);
        self.validator.encode(w);
        w.put_varint(self.validated_at);
        w.put_varint(self.cost_ns);
    }
}

impl Decode for ValidationRecord {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(ValidationRecord {
            data_cid: Cid::decode(r)?,
            verdict: Verdict::decode(r)?,
            score: r.get_f64()?,
            validator: PeerId::decode(r)?,
            validated_at: r.get_varint()?,
            cost_ns: r.get_varint()?,
        })
    }
}

/// The validations store: local verdicts keyed by data CID.
#[derive(Clone, Debug, Default)]
pub struct ValidationsStore {
    inner: DocumentStore<ValidationRecord>,
}

impl ValidationsStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, rec: ValidationRecord) {
        self.inner.put(rec.data_cid.to_string_full(), rec);
    }

    pub fn get(&self, cid: &Cid) -> Option<&ValidationRecord> {
        self.inner.get(&cid.to_string_full())
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Verdict for a CID if we have one (what we answer remote
    /// validation queries with).
    pub fn verdict(&self, cid: &Cid) -> Option<Verdict> {
        self.get(cid).map(|r| r.verdict)
    }

    pub fn invalid_cids(&self) -> Vec<Cid> {
        self.inner
            .query(|r| r.verdict == Verdict::Invalid)
            .into_iter()
            .map(|(_, r)| r.data_cid)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn document_store_crud() {
        let mut s: DocumentStore<u64> = DocumentStore::new();
        s.put("a", 1);
        s.put("b", 2);
        s.put("a", 3); // overwrite
        assert_eq!(s.get("a"), Some(&3));
        assert_eq!(s.len(), 2);
        assert_eq!(s.delete("a"), Some(3));
        assert_eq!(s.get("a"), None);
        s.put("c", 10);
        assert_eq!(s.query(|v| *v >= 2).len(), 2);
    }

    #[test]
    fn validation_record_roundtrip() {
        let mut rng = Rng::new(1);
        let rec = ValidationRecord {
            data_cid: Cid::of_raw(b"data"),
            verdict: Verdict::Inconclusive,
            score: 0.75,
            validator: PeerId::from_rng(&mut rng),
            validated_at: 123,
            cost_ns: 456,
        };
        let b = crate::codec::to_bytes(&rec);
        assert_eq!(crate::codec::from_bytes::<ValidationRecord>(&b).unwrap(), rec);
    }

    #[test]
    fn validations_store_by_cid() {
        let mut rng = Rng::new(2);
        let me = PeerId::from_rng(&mut rng);
        let mut s = ValidationsStore::new();
        let good = Cid::of_raw(b"good");
        let bad = Cid::of_raw(b"bad");
        s.put(ValidationRecord {
            data_cid: good,
            verdict: Verdict::Valid,
            score: 0.9,
            validator: me,
            validated_at: 1,
            cost_ns: 10,
        });
        s.put(ValidationRecord {
            data_cid: bad,
            verdict: Verdict::Invalid,
            score: 0.1,
            validator: me,
            validated_at: 2,
            cost_ns: 10,
        });
        assert_eq!(s.verdict(&good), Some(Verdict::Valid));
        assert_eq!(s.verdict(&bad), Some(Verdict::Invalid));
        assert_eq!(s.verdict(&Cid::of_raw(b"unknown")), None);
        assert_eq!(s.invalid_cids(), vec![bad]);
    }
}

//! Minimal key-value store for node-local state (private-data indexes,
//! workflow checkpoints). Not replicated.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct KvStore {
    map: BTreeMap<String, Vec<u8>>,
}

impl KvStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, key: impl Into<String>, value: Vec<u8>) {
        self.map.insert(key.into(), value);
    }

    pub fn get(&self, key: &str) -> Option<&[u8]> {
        self.map.get(key).map(|v| v.as_slice())
    }

    pub fn delete(&mut self, key: &str) -> bool {
        self.map.remove(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Keys with a given prefix (range scan).
    pub fn scan_prefix(&self, prefix: &str) -> Vec<(&str, &[u8])> {
        self.map
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v.as_slice()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crud_and_scan() {
        let mut kv = KvStore::new();
        kv.put("pin/a", vec![1]);
        kv.put("pin/b", vec![2]);
        kv.put("cfg/x", vec![3]);
        assert_eq!(kv.get("pin/a"), Some(&[1u8][..]));
        assert_eq!(kv.scan_prefix("pin/").len(), 2);
        assert!(kv.delete("pin/a"));
        assert!(!kv.delete("pin/a"));
        assert_eq!(kv.scan_prefix("pin/").len(), 1);
        assert_eq!(kv.len(), 2);
    }
}

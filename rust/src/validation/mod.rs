//! Collaborative performance-data validation (§III-C, §IV-B).
//!
//! Validation happens on two paths:
//!
//! 1. **Opportunistic network consultation** — a peer asks others for
//!    their stored verdicts on a CID and consolidates them by quorum
//!    voting; "in case of an inconclusive vote or undesired outcome, the
//!    performance data of interest is validated independently, otherwise
//!    the decision of the network is used."
//! 2. **Local validation** — an *asynchronous background task* (the
//!    paper's key simulation learning) whose running time follows a
//!    configurable [`CostModel`] (constant, linear, polynomial,
//!    exponential, logarithmic — the scaling behaviours studied in
//!    §IV-B), optionally batched to amortize per-item overhead.
//!
//! The verdict itself comes from a pluggable [`Validator`]; production
//! deployments plug the AOT-compiled k-NN scorer from
//! [`crate::modeling`], simulations use deterministic stand-ins (the
//! paper: "any candidate for a performance data validation strategy must
//! guarantee to produce a deterministic outcome").

pub mod quorum;

use crate::cid::Cid;
use crate::stores::documents::Verdict;
use crate::util::time::{Duration, Nanos};

pub use quorum::{QuorumConfig, VoteOutcome, VoteState};

/// Scaling behaviour of a validation procedure as a function of the data
/// amount (in KiB). These mirror the function families the paper sweeps
/// in its Testground study.
#[derive(Clone, Debug, PartialEq)]
pub enum CostModel {
    /// e.g. schema check / identity function (the prototype experiments).
    Constant { ns: u64 },
    /// e.g. per-record range checks.
    Linear { base_ns: u64, ns_per_kb: f64 },
    /// e.g. pairwise similarity against the batch itself, O(n^p).
    Polynomial { base_ns: u64, ns_per_kb: f64, power: f64 },
    /// e.g. combinatorial feature-subset checks.
    Exponential { base_ns: u64, ns_per_kb: f64, growth_per_kb: f64, cap_ns: u64 },
    /// e.g. index-backed novelty lookups.
    Logarithmic { base_ns: u64, ns_per_log_kb: f64 },
}

impl CostModel {
    /// Virtual compute time to validate `kb` KiB of data.
    pub fn cost(&self, kb: f64) -> Duration {
        let kb = kb.max(0.0);
        let ns = match self {
            CostModel::Constant { ns } => *ns as f64,
            CostModel::Linear { base_ns, ns_per_kb } => *base_ns as f64 + ns_per_kb * kb,
            CostModel::Polynomial { base_ns, ns_per_kb, power } => {
                *base_ns as f64 + ns_per_kb * kb.powf(*power)
            }
            CostModel::Exponential { base_ns, ns_per_kb, growth_per_kb, cap_ns } => {
                (*base_ns as f64 + ns_per_kb * (growth_per_kb * kb).exp()).min(*cap_ns as f64)
            }
            CostModel::Logarithmic { base_ns, ns_per_log_kb } => {
                *base_ns as f64 + ns_per_log_kb * (1.0 + kb).ln()
            }
        };
        Duration(ns.max(0.0) as u64)
    }

    pub fn name(&self) -> &'static str {
        match self {
            CostModel::Constant { .. } => "constant",
            CostModel::Linear { .. } => "linear",
            CostModel::Polynomial { .. } => "polynomial",
            CostModel::Exponential { .. } => "exponential",
            CostModel::Logarithmic { .. } => "logarithmic",
        }
    }
}

/// Produces verdicts for contribution payloads. Must be deterministic.
pub trait Validator: Send {
    fn validate(&mut self, data: &[u8]) -> (Verdict, f64);
}

/// Always-valid validator with score 1.0 — the paper's prototype uses
/// "a validation model … with a fairly constant response time (identity
/// function)".
pub struct IdentityValidator;

impl Validator for IdentityValidator {
    fn validate(&mut self, _data: &[u8]) -> (Verdict, f64) {
        (Verdict::Valid, 1.0)
    }
}

/// Structural validator for the gzip+json contribution files produced by
/// [`crate::modeling::datagen`]: decompresses, parses rows, checks value
/// sanity (no NaN/negatives, plausible ranges). Deterministic.
pub struct StatsValidator {
    /// Runtimes above this (seconds) are considered implausible.
    pub max_runtime_s: f64,
}

impl Default for StatsValidator {
    fn default() -> Self {
        StatsValidator { max_runtime_s: 1e6 }
    }
}

impl Validator for StatsValidator {
    fn validate(&mut self, data: &[u8]) -> (Verdict, f64) {
        let Some(rows) = crate::modeling::datagen::parse_contribution(data) else {
            return (Verdict::Invalid, 0.0);
        };
        if rows.is_empty() {
            return (Verdict::Inconclusive, 0.5);
        }
        let mut ok = 0usize;
        for r in &rows {
            let sane = r.runtime_s.is_finite()
                && r.runtime_s > 0.0
                && r.runtime_s < self.max_runtime_s
                && r.nodes >= 1
                && r.dataset_gb > 0.0;
            if sane {
                ok += 1;
            }
        }
        let frac = ok as f64 / rows.len() as f64;
        let verdict = if frac >= 0.99 {
            Verdict::Valid
        } else if frac >= 0.8 {
            Verdict::Inconclusive
        } else {
            Verdict::Invalid
        };
        (verdict, frac)
    }
}

/// Adversarial validator for fault-injection scenarios: computes the
/// honest structural verdict, then *inverts* it — valid data is reported
/// invalid and vice versa. Deterministic (so scenario replays are exact),
/// and the worst case short of a colluding majority: every lie is
/// maximally wrong.
pub struct ByzantineValidator {
    inner: StatsValidator,
}

impl Default for ByzantineValidator {
    fn default() -> Self {
        ByzantineValidator { inner: StatsValidator::default() }
    }
}

impl Validator for ByzantineValidator {
    fn validate(&mut self, data: &[u8]) -> (Verdict, f64) {
        let (v, s) = self.inner.validate(data);
        match v {
            Verdict::Valid => (Verdict::Invalid, 1.0 - s),
            Verdict::Invalid => (Verdict::Valid, 1.0 - s),
            Verdict::Inconclusive => (Verdict::Inconclusive, s),
        }
    }
}

/// One queued local-validation work item.
#[derive(Clone, Debug)]
pub struct Task {
    pub data_cid: Cid,
    pub size_bytes: u64,
}

/// Batching queue for local validation (§IV-B: "for certain validation
/// procedures, it might be worth considering batched performance data
/// validation in order to accelerate the process").
///
/// Tasks accumulate until `batch_size` is reached (or `flush`), then one
/// background "computation" covers the whole batch; its duration is the
/// cost model applied to the batch's total size.
pub struct BatchQueue {
    pub batch_size: usize,
    pending: Vec<Task>,
    in_flight: std::collections::HashMap<u64, (Vec<Task>, Nanos)>,
    next_batch_id: u64,
}

impl BatchQueue {
    pub fn new(batch_size: usize) -> Self {
        BatchQueue {
            batch_size: batch_size.max(1),
            pending: Vec::new(),
            in_flight: std::collections::HashMap::new(),
            next_batch_id: 1,
        }
    }

    pub fn enqueue(&mut self, task: Task) {
        self.pending.push(task);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn in_flight_len(&self) -> usize {
        self.in_flight.len()
    }

    /// If a batch is ready (or `force`), take it: returns
    /// `(batch_id, completion_delay)` to arm a timer with.
    ///
    /// Batches execute one at a time (a single background worker — the
    /// validation task is CPU-bound): while one is in flight, nothing new
    /// starts.
    pub fn maybe_start(
        &mut self,
        now: Nanos,
        cost: &CostModel,
        force: bool,
    ) -> Option<(u64, Duration)> {
        if !self.in_flight.is_empty() {
            return None;
        }
        if self.pending.is_empty() || (!force && self.pending.len() < self.batch_size) {
            return None;
        }
        let take = self.pending.len().min(self.batch_size);
        let batch: Vec<Task> = self.pending.drain(..take).collect();
        let total_kb: f64 = batch.iter().map(|t| t.size_bytes as f64 / 1024.0).sum();
        let delay = cost.cost(total_kb);
        let id = self.next_batch_id;
        self.next_batch_id += 1;
        self.in_flight.insert(id, (batch, now));
        Some((id, delay))
    }

    /// A batch timer fired: hand back its tasks for verdict computation.
    pub fn complete(&mut self, batch_id: u64) -> Option<(Vec<Task>, Nanos)> {
        self.in_flight.remove(&batch_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_orderings() {
        let c = CostModel::Constant { ns: 1000 };
        let lin = CostModel::Linear { base_ns: 0, ns_per_kb: 100.0 };
        let pol = CostModel::Polynomial { base_ns: 0, ns_per_kb: 100.0, power: 2.0 };
        let log = CostModel::Logarithmic { base_ns: 0, ns_per_log_kb: 100.0 };
        // At 1 KB everything is small; at 1000 KB the order is
        // log < const? (const fixed) — check monotone growth relations.
        assert_eq!(c.cost(1.0), c.cost(1000.0));
        assert!(lin.cost(1000.0) > lin.cost(10.0));
        assert!(pol.cost(1000.0).0 > lin.cost(1000.0).0);
        assert!(log.cost(1000.0).0 < lin.cost(1000.0).0);
    }

    #[test]
    fn exponential_capped() {
        let e = CostModel::Exponential {
            base_ns: 0,
            ns_per_kb: 1.0,
            growth_per_kb: 1.0,
            cap_ns: 1_000_000,
        };
        assert_eq!(e.cost(1e6), Duration(1_000_000));
        assert!(e.cost(5.0).0 > e.cost(1.0).0);
    }

    #[test]
    fn identity_validator_constant() {
        let mut v = IdentityValidator;
        assert_eq!(v.validate(b"anything"), (Verdict::Valid, 1.0));
        assert_eq!(v.validate(b""), (Verdict::Valid, 1.0));
    }

    #[test]
    fn byzantine_validator_inverts_honest_verdict() {
        let mut rng = crate::util::Rng::new(5);
        let (good, _) = crate::modeling::datagen::generate_contribution(&mut rng, 0, 40);
        let (bad, _) =
            crate::modeling::datagen::generate_corrupt_contribution(&mut rng, 0, 40, 0.9);
        let mut honest = StatsValidator::default();
        let mut liar = ByzantineValidator::default();
        assert_eq!(honest.validate(&good).0, Verdict::Valid);
        assert_eq!(liar.validate(&good).0, Verdict::Invalid);
        assert_eq!(honest.validate(&bad).0, Verdict::Invalid);
        assert_eq!(liar.validate(&bad).0, Verdict::Valid);
    }

    #[test]
    fn batch_queue_waits_for_batch() {
        let mut q = BatchQueue::new(3);
        let cost = CostModel::Linear { base_ns: 1000, ns_per_kb: 1000.0 };
        q.enqueue(Task { data_cid: Cid::of_raw(b"a"), size_bytes: 1024 });
        q.enqueue(Task { data_cid: Cid::of_raw(b"b"), size_bytes: 1024 });
        assert!(q.maybe_start(Nanos(0), &cost, false).is_none());
        q.enqueue(Task { data_cid: Cid::of_raw(b"c"), size_bytes: 1024 });
        let (id, delay) = q.maybe_start(Nanos(0), &cost, false).unwrap();
        // 3 KiB → 1000 + 3000 ns.
        assert_eq!(delay, Duration(4000));
        let (tasks, started) = q.complete(id).unwrap();
        assert_eq!(tasks.len(), 3);
        assert_eq!(started, Nanos(0));
        assert!(q.complete(id).is_none());
    }

    #[test]
    fn batch_queue_force_flush() {
        let mut q = BatchQueue::new(100);
        let cost = CostModel::Constant { ns: 5 };
        q.enqueue(Task { data_cid: Cid::of_raw(b"a"), size_bytes: 10 });
        let got = q.maybe_start(Nanos(1), &cost, true);
        assert!(got.is_some());
        assert_eq!(q.pending_len(), 0);
    }

    #[test]
    fn batching_amortizes_per_item_base_cost() {
        // With a large base cost, one batch of 10 is far cheaper than 10
        // singleton validations — the §IV-B batching observation.
        let cost = CostModel::Linear { base_ns: 1_000_000, ns_per_kb: 10.0 };
        let singleton_total = 10 * cost.cost(9.0).0;
        let batched = cost.cost(90.0).0;
        assert!(batched < singleton_total / 5);
    }
}

//! Quorum voting over remote validation verdicts.
//!
//! "A user requests the individual validation results of other peers in
//! the network and consolidates them — in case of an inconclusive vote or
//! undesired outcome, the performance data of interest is validated
//! independently, otherwise the decision of the network is used."
//!
//! "Another tuning parameter is the number of responses from peers deemed
//! sufficient in order to decide on a vote" — that is
//! [`QuorumConfig::responses_needed`], swept in `benches/sim_validation`.

use crate::net::PeerId;
use crate::stores::documents::Verdict;
use crate::util::time::{Duration, Nanos};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct QuorumConfig {
    /// How many peers to query.
    pub fanout: usize,
    /// Verdict-carrying responses required before tallying.
    pub responses_needed: usize,
    /// Fraction of responses that must agree for the network decision to
    /// be adopted.
    pub agreement: f64,
    /// Give up waiting after this long and fall back to local validation.
    pub timeout: Duration,
    /// Minimum verdict-carrying responses a *timeout* tally may decide
    /// on (the non-timeout path always waits for `responses_needed`).
    /// The default of 1 keeps the prototype's eager behaviour; raising
    /// it to 2 makes a single byzantine responder unable to sneak a lie
    /// through a sparsely-answered vote (with `agreement` > 0.5, one
    /// honest verdict then always blocks the lie).
    pub min_force_verdicts: usize,
    /// Extra time granted **once** to a vote that expires with fewer
    /// than `responses_needed` verdicts while some asked peers are still
    /// outstanding. `ZERO` (the default) disables the extension and
    /// keeps the legacy force-tally-at-timeout behaviour.
    ///
    /// This closes a delay attack the plain timeout path is open to: a
    /// colluding byzantine *majority* of one vote's `fanout` sample can
    /// answer promptly with a unanimous lie while the honest responders
    /// sit behind slow links, so their truthful verdicts arrive *late*
    /// rather than never. At the timeout, `tally(force=true)` sees only
    /// the prompt liars — enough of them to clear `min_force_verdicts`
    /// *and* `agreement` — and the lie is adopted as a
    /// `ValidationSource::Network` verdict. With a grace period, the
    /// vote is instead extended once (no re-query, just more patience),
    /// and while extended the forced tally applies a stricter floor of
    /// `responses_needed` verdicts — so the late honest majority gets to
    /// outvote the prompt liars, and if it still hasn't arrived when the
    /// grace runs out the vote degrades to local validation instead of
    /// adopting the attacker-only sample.
    pub timeout_grace: Duration,
}

impl Default for QuorumConfig {
    fn default() -> Self {
        QuorumConfig {
            fanout: 5,
            responses_needed: 3,
            agreement: 2.0 / 3.0,
            timeout: Duration::from_secs(5),
            min_force_verdicts: 1,
            timeout_grace: Duration::ZERO,
        }
    }
}

/// Result of a vote.
#[derive(Clone, Debug, PartialEq)]
pub enum VoteOutcome {
    /// The network agrees; adopt this verdict (mean score attached).
    Decided { verdict: Verdict, mean_score: f64, responses: usize },
    /// Not enough agreement / information — validate locally.
    Inconclusive { responses: usize },
}

/// State of one in-flight vote.
#[derive(Clone, Debug)]
pub struct VoteState {
    pub started_at: Nanos,
    asked: Vec<PeerId>,
    /// Set once when the vote's first deadline passes under a nonzero
    /// [`QuorumConfig::timeout_grace`]; an extended vote waits the grace
    /// out and force-tallies under a stricter verdict floor.
    extended: bool,
    /// Keyed deterministically: tallies (and their float means) must not
    /// depend on map iteration order — the simulator's reproducibility
    /// guarantee reaches down to here.
    answers: BTreeMap<PeerId, Option<(Verdict, f64)>>,
}

impl VoteState {
    pub fn new(started_at: Nanos, asked: Vec<PeerId>) -> Self {
        VoteState { started_at, asked, extended: false, answers: BTreeMap::new() }
    }

    pub fn asked(&self) -> &[PeerId] {
        &self.asked
    }

    /// Record an answer; ignores peers that were never asked. The first
    /// answer from a peer wins: a responder (or a forged duplicate
    /// reply) cannot revise a verdict mid-vote.
    pub fn record(&mut self, from: PeerId, verdict: Option<(Verdict, f64)>) {
        if self.asked.contains(&from) {
            self.answers.entry(from).or_insert(verdict);
        }
    }

    pub fn responses(&self) -> usize {
        self.answers.len()
    }

    /// Asked peers that have not answered yet.
    pub fn outstanding(&self) -> usize {
        self.asked.len().saturating_sub(self.answers.len())
    }

    /// Verdict-carrying responses received so far.
    pub fn verdict_count(&self) -> usize {
        self.verdicts().len()
    }

    pub fn is_extended(&self) -> bool {
        self.extended
    }

    pub fn mark_extended(&mut self) {
        self.extended = true;
    }

    fn verdicts(&self) -> Vec<(Verdict, f64)> {
        self.answers.values().filter_map(|v| *v).collect()
    }

    /// Tally if possible. `force` tallies with whatever arrived (timeout
    /// path); otherwise requires `responses_needed` verdicts first.
    ///
    /// A grace-extended vote already blew its first deadline with asked
    /// peers outstanding, so its forced tally applies the stricter floor
    /// of `responses_needed` verdicts: the extension exists to let late
    /// honest responders catch up, not to adopt whatever the prompt
    /// (possibly colluding) minority of the sample said.
    pub fn tally(&self, cfg: &QuorumConfig, force: bool) -> Option<VoteOutcome> {
        let floor = if self.extended {
            cfg.min_force_verdicts.max(cfg.responses_needed)
        } else {
            cfg.min_force_verdicts
        };
        self.tally_with_floor(cfg, force, floor)
    }

    /// The outcome a *forced* tally would produce at the legacy
    /// (un-extended) floor, regardless of this vote's extension state.
    /// Comparing it against the real extended tally is how the node
    /// detects a rescue: the stricter floor degraded a would-be verdict
    /// adoption to local validation.
    pub fn forced_outcome_at_legacy_floor(&self, cfg: &QuorumConfig) -> Option<VoteOutcome> {
        self.tally_with_floor(cfg, true, cfg.min_force_verdicts)
    }

    fn tally_with_floor(
        &self,
        cfg: &QuorumConfig,
        force: bool,
        min_force_verdicts: usize,
    ) -> Option<VoteOutcome> {
        let verdicts = self.verdicts();
        if !force {
            if verdicts.len() < cfg.responses_needed {
                return None;
            }
        } else if verdicts.len() < min_force_verdicts.max(1) {
            return Some(VoteOutcome::Inconclusive { responses: self.responses() });
        }
        // Majority verdict. BTreeMap keeps ties deterministic (the last
        // maximum in key order wins).
        let mut counts: BTreeMap<u8, usize> = BTreeMap::new();
        for (v, _) in &verdicts {
            *counts.entry(*v as u8).or_insert(0) += 1;
        }
        let (&best, &n) = counts.iter().max_by_key(|(_, n)| **n).unwrap();
        let frac = n as f64 / verdicts.len() as f64;
        if frac >= cfg.agreement {
            let verdict = match best {
                0 => Verdict::Valid,
                1 => Verdict::Invalid,
                _ => Verdict::Inconclusive,
            };
            if verdict == Verdict::Inconclusive {
                return Some(VoteOutcome::Inconclusive { responses: self.responses() });
            }
            let mean_score = verdicts
                .iter()
                .filter(|(v, _)| *v == verdict)
                .map(|(_, s)| *s)
                .sum::<f64>()
                / n as f64;
            Some(VoteOutcome::Decided { verdict, mean_score, responses: self.responses() })
        } else {
            Some(VoteOutcome::Inconclusive { responses: self.responses() })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn peers(n: usize) -> Vec<PeerId> {
        let mut rng = Rng::new(9);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    #[test]
    fn waits_for_quorum_then_decides() {
        let cfg = QuorumConfig::default();
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Valid, 0.9)));
        assert!(v.tally(&cfg, false).is_none());
        v.record(ps[1], Some((Verdict::Valid, 0.8)));
        v.record(ps[2], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, false).unwrap();
        let VoteOutcome::Decided { verdict, mean_score, responses } = out else { panic!() };
        assert_eq!(verdict, Verdict::Valid);
        assert!((mean_score - 0.9).abs() < 1e-9);
        assert_eq!(responses, 3);
    }

    #[test]
    fn split_vote_is_inconclusive() {
        let cfg = QuorumConfig { agreement: 0.75, ..Default::default() };
        let ps = peers(4);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Valid, 1.0)));
        v.record(ps[1], Some((Verdict::Invalid, 0.0)));
        v.record(ps[2], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, false).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
    }

    #[test]
    fn empty_answers_dont_count_toward_quorum() {
        let cfg = QuorumConfig::default();
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], None);
        v.record(ps[1], None);
        v.record(ps[2], None);
        assert!(v.tally(&cfg, false).is_none(), "no verdicts yet");
        // Timeout path: force-tally.
        let out = v.tally(&cfg, true).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { responses: 3 }));
    }

    #[test]
    fn unasked_peer_ignored() {
        let cfg = QuorumConfig { responses_needed: 1, ..Default::default() };
        let ps = peers(3);
        let stranger = peers(4)[3];
        let mut v = VoteState::new(Nanos(0), ps);
        v.record(stranger, Some((Verdict::Invalid, 0.0)));
        assert_eq!(v.responses(), 0);
        assert!(v.tally(&cfg, false).is_none());
    }

    #[test]
    fn min_force_verdicts_blocks_lone_answer() {
        let cfg = QuorumConfig { min_force_verdicts: 2, ..Default::default() };
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Invalid, 0.0))); // a lone (possibly lying) voice
        let out = v.tally(&cfg, true).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
        // A second verdict satisfies the floor; a 1-1 split still fails
        // the agreement threshold, so no lie can be adopted.
        v.record(ps[1], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, true).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
    }

    #[test]
    fn first_answer_wins() {
        let cfg = QuorumConfig { responses_needed: 1, ..Default::default() };
        let ps = peers(3);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Valid, 0.9)));
        // A duplicate (or forged) second reply must not revise the verdict.
        v.record(ps[0], Some((Verdict::Invalid, 0.0)));
        assert_eq!(v.responses(), 1);
        let out = v.tally(&cfg, false).unwrap();
        let VoteOutcome::Decided { verdict, .. } = out else { panic!() };
        assert_eq!(verdict, Verdict::Valid);
        // Nor can a duplicate upgrade an earlier empty answer.
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[1], None);
        v.record(ps[1], Some((Verdict::Invalid, 0.0)));
        assert_eq!(v.verdict_count(), 0);
    }

    #[test]
    fn outstanding_tracks_unanswered_peers() {
        let ps = peers(4);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        assert_eq!(v.outstanding(), 4);
        v.record(ps[0], Some((Verdict::Valid, 1.0)));
        v.record(ps[1], None);
        assert_eq!(v.outstanding(), 2);
        assert_eq!(v.verdict_count(), 1);
        // Unasked strangers and duplicates don't change the count.
        v.record(peers(5)[4], Some((Verdict::Valid, 1.0)));
        v.record(ps[0], Some((Verdict::Valid, 1.0)));
        assert_eq!(v.outstanding(), 2);
    }

    #[test]
    fn extended_vote_applies_stricter_forced_floor() {
        // 4 prompt unanimous liars in a 6-peer sample, responses_needed 5:
        // the legacy forced tally adopts the lie, the extended one holds.
        let cfg = QuorumConfig {
            fanout: 6,
            responses_needed: 5,
            agreement: 0.85,
            min_force_verdicts: 2,
            ..Default::default()
        };
        let ps = peers(6);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        for p in &ps[..4] {
            v.record(*p, Some((Verdict::Invalid, 0.0)));
        }
        let legacy = v.forced_outcome_at_legacy_floor(&cfg).unwrap();
        assert!(
            matches!(legacy, VoteOutcome::Decided { verdict: Verdict::Invalid, .. }),
            "un-extended timeout tally adopts the attacker-majority sample"
        );
        v.mark_extended();
        let out = v.tally(&cfg, true).unwrap();
        assert!(
            matches!(out, VoteOutcome::Inconclusive { .. }),
            "extended tally demands responses_needed verdicts"
        );
        // A late honest verdict completes the quorum — and the honest
        // 1-of-5 dissent now denies the liars the agreement threshold.
        v.record(ps[4], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, false).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
    }

    /// Table-driven walk of the forced-tally envelope boundary: verdict
    /// counts straddling `min_force_verdicts`, agreement fractions
    /// straddling `cfg.agreement`, and all-byzantine vs mixed samples.
    /// These cells pin at the unit level the cliff edge that
    /// `benches/quorum_envelope.rs` finds empirically.
    #[test]
    fn forced_tally_envelope() {
        struct Case {
            name: &'static str,
            // (invalid_lies, honest_valids) answered; the rest of the
            // 8-peer sample stays outstanding.
            lies: usize,
            valids: usize,
            min_force_verdicts: usize,
            agreement: f64,
            // None => Inconclusive; Some(v) => Decided { verdict: v, .. }.
            expect: Option<Verdict>,
        }
        let cases = [
            Case {
                name: "below the verdict floor: one lie, floor 2",
                lies: 1,
                valids: 0,
                min_force_verdicts: 2,
                agreement: 0.5,
                expect: None,
            },
            Case {
                name: "at the verdict floor: two unanimous lies clear floor 2",
                lies: 2,
                valids: 0,
                min_force_verdicts: 2,
                agreement: 0.5,
                expect: Some(Verdict::Invalid),
            },
            Case {
                name: "above the verdict floor: three unanimous lies, floor 2",
                lies: 3,
                valids: 0,
                min_force_verdicts: 2,
                agreement: 0.5,
                expect: Some(Verdict::Invalid),
            },
            Case {
                name: "all-byzantine sample: unanimity clears any agreement",
                lies: 4,
                valids: 0,
                min_force_verdicts: 1,
                agreement: 1.0,
                expect: Some(Verdict::Invalid),
            },
            Case {
                name: "mixed sample just over agreement: 3 of 4 at 0.75",
                lies: 3,
                valids: 1,
                min_force_verdicts: 1,
                agreement: 0.75,
                expect: Some(Verdict::Invalid),
            },
            Case {
                name: "mixed sample just under agreement: 3 of 4 at 0.76",
                lies: 3,
                valids: 1,
                min_force_verdicts: 1,
                agreement: 0.76,
                expect: None,
            },
            Case {
                name: "honest majority outvotes lies: 1 of 4 at 0.75",
                lies: 1,
                valids: 3,
                min_force_verdicts: 1,
                agreement: 0.75,
                expect: Some(Verdict::Valid),
            },
            Case {
                name: "even split never clears a >0.5 agreement",
                lies: 2,
                valids: 2,
                min_force_verdicts: 1,
                agreement: 0.51,
                expect: None,
            },
        ];
        for c in cases {
            let cfg = QuorumConfig {
                fanout: 8,
                responses_needed: 8, // force path only: never tallied non-forced
                agreement: c.agreement,
                min_force_verdicts: c.min_force_verdicts,
                ..Default::default()
            };
            let ps = peers(8);
            let mut v = VoteState::new(Nanos(0), ps.clone());
            for p in &ps[..c.lies] {
                v.record(*p, Some((Verdict::Invalid, 0.0)));
            }
            for p in &ps[c.lies..c.lies + c.valids] {
                v.record(*p, Some((Verdict::Valid, 1.0)));
            }
            let out = v.tally(&cfg, true).unwrap();
            match (c.expect, out) {
                (None, VoteOutcome::Inconclusive { .. }) => {}
                (Some(want), VoteOutcome::Decided { verdict, .. }) if verdict == want => {}
                (_, got) => panic!("case '{}': unexpected outcome {:?}", c.name, got),
            }
        }
    }

    #[test]
    fn majority_invalid_detected() {
        let cfg = QuorumConfig { responses_needed: 3, agreement: 0.6, ..Default::default() };
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Invalid, 0.1)));
        v.record(ps[1], Some((Verdict::Invalid, 0.2)));
        v.record(ps[2], Some((Verdict::Valid, 0.9)));
        let out = v.tally(&cfg, false).unwrap();
        let VoteOutcome::Decided { verdict, .. } = out else { panic!() };
        assert_eq!(verdict, Verdict::Invalid);
    }
}

//! Quorum voting over remote validation verdicts.
//!
//! "A user requests the individual validation results of other peers in
//! the network and consolidates them — in case of an inconclusive vote or
//! undesired outcome, the performance data of interest is validated
//! independently, otherwise the decision of the network is used."
//!
//! "Another tuning parameter is the number of responses from peers deemed
//! sufficient in order to decide on a vote" — that is
//! [`QuorumConfig::responses_needed`], swept in `benches/sim_validation`.

use crate::net::PeerId;
use crate::stores::documents::Verdict;
use crate::util::time::{Duration, Nanos};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct QuorumConfig {
    /// How many peers to query.
    pub fanout: usize,
    /// Verdict-carrying responses required before tallying.
    pub responses_needed: usize,
    /// Fraction of responses that must agree for the network decision to
    /// be adopted.
    pub agreement: f64,
    /// Give up waiting after this long and fall back to local validation.
    pub timeout: Duration,
    /// Minimum verdict-carrying responses a *timeout* tally may decide
    /// on (the non-timeout path always waits for `responses_needed`).
    /// The default of 1 keeps the prototype's eager behaviour; raising
    /// it to 2 makes a single byzantine responder unable to sneak a lie
    /// through a sparsely-answered vote (with `agreement` > 0.5, one
    /// honest verdict then always blocks the lie).
    pub min_force_verdicts: usize,
}

impl Default for QuorumConfig {
    fn default() -> Self {
        QuorumConfig {
            fanout: 5,
            responses_needed: 3,
            agreement: 2.0 / 3.0,
            timeout: Duration::from_secs(5),
            min_force_verdicts: 1,
        }
    }
}

/// Result of a vote.
#[derive(Clone, Debug, PartialEq)]
pub enum VoteOutcome {
    /// The network agrees; adopt this verdict (mean score attached).
    Decided { verdict: Verdict, mean_score: f64, responses: usize },
    /// Not enough agreement / information — validate locally.
    Inconclusive { responses: usize },
}

/// State of one in-flight vote.
#[derive(Clone, Debug)]
pub struct VoteState {
    pub started_at: Nanos,
    asked: Vec<PeerId>,
    /// Keyed deterministically: tallies (and their float means) must not
    /// depend on map iteration order — the simulator's reproducibility
    /// guarantee reaches down to here.
    answers: BTreeMap<PeerId, Option<(Verdict, f64)>>,
}

impl VoteState {
    pub fn new(started_at: Nanos, asked: Vec<PeerId>) -> Self {
        VoteState { started_at, asked, answers: BTreeMap::new() }
    }

    pub fn asked(&self) -> &[PeerId] {
        &self.asked
    }

    /// Record an answer; ignores peers that were never asked.
    pub fn record(&mut self, from: PeerId, verdict: Option<(Verdict, f64)>) {
        if self.asked.contains(&from) {
            self.answers.insert(from, verdict);
        }
    }

    pub fn responses(&self) -> usize {
        self.answers.len()
    }

    fn verdicts(&self) -> Vec<(Verdict, f64)> {
        self.answers.values().filter_map(|v| *v).collect()
    }

    /// Tally if possible. `force` tallies with whatever arrived (timeout
    /// path); otherwise requires `responses_needed` verdicts first.
    pub fn tally(&self, cfg: &QuorumConfig, force: bool) -> Option<VoteOutcome> {
        let verdicts = self.verdicts();
        if !force {
            if verdicts.len() < cfg.responses_needed {
                return None;
            }
        } else if verdicts.len() < cfg.min_force_verdicts.max(1) {
            return Some(VoteOutcome::Inconclusive { responses: self.responses() });
        }
        // Majority verdict. BTreeMap keeps ties deterministic (the last
        // maximum in key order wins).
        let mut counts: BTreeMap<u8, usize> = BTreeMap::new();
        for (v, _) in &verdicts {
            *counts.entry(*v as u8).or_insert(0) += 1;
        }
        let (&best, &n) = counts.iter().max_by_key(|(_, n)| **n).unwrap();
        let frac = n as f64 / verdicts.len() as f64;
        if frac >= cfg.agreement {
            let verdict = match best {
                0 => Verdict::Valid,
                1 => Verdict::Invalid,
                _ => Verdict::Inconclusive,
            };
            if verdict == Verdict::Inconclusive {
                return Some(VoteOutcome::Inconclusive { responses: self.responses() });
            }
            let mean_score = verdicts
                .iter()
                .filter(|(v, _)| *v == verdict)
                .map(|(_, s)| *s)
                .sum::<f64>()
                / n as f64;
            Some(VoteOutcome::Decided { verdict, mean_score, responses: self.responses() })
        } else {
            Some(VoteOutcome::Inconclusive { responses: self.responses() })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn peers(n: usize) -> Vec<PeerId> {
        let mut rng = Rng::new(9);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    #[test]
    fn waits_for_quorum_then_decides() {
        let cfg = QuorumConfig::default();
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Valid, 0.9)));
        assert!(v.tally(&cfg, false).is_none());
        v.record(ps[1], Some((Verdict::Valid, 0.8)));
        v.record(ps[2], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, false).unwrap();
        let VoteOutcome::Decided { verdict, mean_score, responses } = out else { panic!() };
        assert_eq!(verdict, Verdict::Valid);
        assert!((mean_score - 0.9).abs() < 1e-9);
        assert_eq!(responses, 3);
    }

    #[test]
    fn split_vote_is_inconclusive() {
        let cfg = QuorumConfig { agreement: 0.75, ..Default::default() };
        let ps = peers(4);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Valid, 1.0)));
        v.record(ps[1], Some((Verdict::Invalid, 0.0)));
        v.record(ps[2], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, false).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
    }

    #[test]
    fn empty_answers_dont_count_toward_quorum() {
        let cfg = QuorumConfig::default();
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], None);
        v.record(ps[1], None);
        v.record(ps[2], None);
        assert!(v.tally(&cfg, false).is_none(), "no verdicts yet");
        // Timeout path: force-tally.
        let out = v.tally(&cfg, true).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { responses: 3 }));
    }

    #[test]
    fn unasked_peer_ignored() {
        let cfg = QuorumConfig { responses_needed: 1, ..Default::default() };
        let ps = peers(3);
        let stranger = peers(4)[3];
        let mut v = VoteState::new(Nanos(0), ps);
        v.record(stranger, Some((Verdict::Invalid, 0.0)));
        assert_eq!(v.responses(), 0);
        assert!(v.tally(&cfg, false).is_none());
    }

    #[test]
    fn min_force_verdicts_blocks_lone_answer() {
        let cfg = QuorumConfig { min_force_verdicts: 2, ..Default::default() };
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Invalid, 0.0))); // a lone (possibly lying) voice
        let out = v.tally(&cfg, true).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
        // A second verdict satisfies the floor; a 1-1 split still fails
        // the agreement threshold, so no lie can be adopted.
        v.record(ps[1], Some((Verdict::Valid, 1.0)));
        let out = v.tally(&cfg, true).unwrap();
        assert!(matches!(out, VoteOutcome::Inconclusive { .. }));
    }

    #[test]
    fn majority_invalid_detected() {
        let cfg = QuorumConfig { responses_needed: 3, agreement: 0.6, ..Default::default() };
        let ps = peers(5);
        let mut v = VoteState::new(Nanos(0), ps.clone());
        v.record(ps[0], Some((Verdict::Invalid, 0.1)));
        v.record(ps[1], Some((Verdict::Invalid, 0.2)));
        v.record(ps[2], Some((Verdict::Valid, 0.9)));
        let out = v.tally(&cfg, false).unwrap();
        let VoteOutcome::Decided { verdict, .. } = out else { panic!() };
        assert_eq!(verdict, Verdict::Invalid);
    }
}

//! # PeersDB-RS
//!
//! A peer-to-peer data distribution layer for collaborative performance
//! modeling of distributed dataflow applications — a from-scratch Rust
//! reproduction of the system described in
//! *"Towards a Peer-to-Peer Data Distribution Layer for Efficient and
//! Collaborative Resource Optimization of Distributed Dataflow
//! Applications"* (IEEE BigData 2023).
//!
//! ## Architecture
//!
//! `ARCHITECTURE.md` at the repository root maps every paper section to
//! its module, inventories the fault-scenario bank, and documents the
//! seed-replay workflow. The short version:
//!
//! The crate is organized around **sans-io protocol cores**: every
//! protocol (Kademlia DHT, bitswap block exchange, IPFS-Log replication,
//! pubsub, collaborative validation) is a deterministic state machine that
//! consumes `(now, Event)` pairs and emits `Command`s. Two drivers run the
//! same cores:
//!
//! * [`sim`] — a discrete-event simulator with a region latency matrix,
//!   bandwidth/jitter/loss models and churn (the evaluation harness), and
//! * [`net::tcp`] — a threaded TCP driver for real deployments.
//!
//! The performance-modeling workflows (the downstream consumer that
//! motivates the layer) call AOT-compiled JAX/Pallas computations through
//! `runtime` (PJRT via the `xla` crate; behind the `pjrt` feature so the
//! data layer builds in offline environments); Python never runs at
//! request time.
//!
//! Evaluation is driven by the **scenario subsystem**
//! ([`sim::scenario`]): declarative, timed fault schedules (partitions,
//! regional outages, crash/restart churn, flash-crowd joins, root-peer
//! CPU strain, byzantine validators, GC pressure with deliberate
//! unpinning) executed against a simulated
//! cluster, with a cluster-wide invariant checker (log convergence,
//! quorum safety, DHT routing health, block availability, data
//! survival) asserted at
//! checkpoints and at quiesce. Scenario runs are deterministic: the same
//! seed reproduces the identical [`sim::SimStats`].
//!
//! ```text
//!  api (http/shell)      examples/, benches/
//!        │                     │
//!        ▼                     ▼
//!  peersdb::Node  ◄──── sim::Cluster / net::tcp::Swarm
//!   ├─ stores (contributions EventLog, validations DocumentStore)
//!   ├─ ipfs_log (Merkle-CRDT)      ├─ dht (Kademlia)
//!   ├─ bitswap (block exchange)    ├─ pubsub (floodsub)
//!   ├─ validation (quorum voting)  ├─ access (gate, private CIDs)
//!   └─ blockstore (content-addressed, chunked)
//!        │
//!  modeling ──► runtime (PJRT) ──► artifacts/*.hlo.txt (JAX+Pallas, AOT)
//! ```

// CI runs `cargo clippy --all-targets -- -D warnings`. The allows below
// are deliberate, codebase-wide idiom decisions, not suppressions of
// individual findings: `new()` constructors exist on most stores and
// engines without a `Default` (construction is always explicit here, and
// several types will grow required parameters), sans-io handlers thread
// `now`/`out` through and legitimately exceed the argument-count lint,
// index-based loops over fixed 32-byte arrays mirror the XOR-metric
// arithmetic they implement, and test/bench helpers use tuple-heavy
// types on purpose. Anything outside these four categories is a real
// finding and should be fixed, not added here.
#![allow(clippy::new_without_default)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::type_complexity)]

pub mod access;
pub mod api;
pub mod bitswap;
pub mod blockstore;
pub mod cid;
pub mod cli;
pub mod codec;
pub mod config;
pub mod dht;
pub mod ipfs_log;
pub mod metrics;
pub mod modeling;
pub mod net;
pub mod peersdb;
pub mod pubsub;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod stores;
pub mod testkit;
pub mod util;
pub mod validation;

//! Streaming summary statistics and percentile estimation used by the
//! experiment harnesses and the metrics module.

/// Collects samples and reports mean / min / max / percentiles.
///
/// Keeps all samples (experiments here are ≤ millions of points); for
/// percentile queries the buffer is sorted lazily and the sorted state is
/// cached until the next push.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
        self.sum += v;
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.sum / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` via nearest-rank interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = (p / 100.0) * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// One-line human summary (used by the bench harness tables).
    pub fn brief(&mut self) -> String {
        if self.is_empty() {
            return "n=0".into();
        }
        format!(
            "n={} mean={:.3} p50={:.3} p95={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.max()
        )
    }
}

/// Linear-regression slope of y on x (used to verify scaling behaviours,
/// e.g. validation cost vs data amount).
pub fn slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (x - mx) * (y - my))
        .sum::<f64>();
    let var = xs.iter().map(|x| (x - mx) * (x - mx)).sum::<f64>();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.29099).abs() < 1e-4);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        // push after sort invalidates cache
        s.push(1000.0);
        assert!((s.percentile(100.0) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn regression_slope() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        assert!((slope(&xs, &ys) - 3.0).abs() < 1e-9);
    }
}

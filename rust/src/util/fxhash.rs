//! A deterministic, non-cryptographic hasher (the rustc/Firefox "Fx"
//! multiply-rotate hash) for simulator-internal maps.
//!
//! `std`'s default `RandomState` seeds SipHash per process — fine for
//! DoS resistance, wasteful for the DES hot path, where every send does
//! a `PeerId → node index` lookup and every delivery a blocked-link
//! probe. Keys here are either uniformly random 32-byte ids or small
//! integers, so a two-instruction mix per word is plenty, and a fixed
//! seed keeps the whole simulator free of cross-process entropy (map
//! *iteration* is still never relied on for determinism — only
//! get/insert go through these types).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed by [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Word-at-a-time multiply-rotate hasher.
#[derive(Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, mut bytes: &[u8]) {
        while bytes.len() >= 8 {
            self.add(u64::from_le_bytes(bytes[..8].try_into().unwrap()));
            bytes = &bytes[8..];
        }
        if !bytes.is_empty() {
            let mut tail = [0u8; 8];
            tail[..bytes.len()].copy_from_slice(bytes);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let hash = |bytes: &[u8]| {
            let mut h = FxHasher::default();
            h.write(bytes);
            h.finish()
        };
        assert_eq!(hash(b"peer-id-bytes"), hash(b"peer-id-bytes"));
        assert_ne!(hash(b"a"), hash(b"b"));
    }

    #[test]
    fn map_basics() {
        let mut m: FxHashMap<(usize, usize), u32> = FxHashMap::default();
        m.insert((1, 2), 3);
        assert_eq!(m.get(&(1, 2)), Some(&3));
        assert_eq!(m.get(&(2, 1)), None);
        let mut s: FxHashSet<u64> = FxHashSet::default();
        assert!(s.insert(7));
        assert!(!s.insert(7));
    }
}

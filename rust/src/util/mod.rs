//! Small self-contained utilities: PRNG, hex, time, stats, logging.
//!
//! The offline crate set has no `rand`, `hex`, or `log`-backend crates, so
//! these are first-class modules. Everything here is deterministic and
//! allocation-light; the PRNG in particular is the seed root for all
//! simulation experiments.

pub mod bench;
pub mod bytes;
pub mod fxhash;
pub mod hex;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod time;

pub use bytes::Blob;
pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
pub use time::{Duration, Nanos};

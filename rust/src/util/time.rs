//! Virtual time primitives.
//!
//! All protocol cores are written against [`Nanos`], a monotonic virtual
//! timestamp in nanoseconds. The DES driver advances it discretely; the
//! TCP driver maps it to `std::time::Instant`.

/// A point in virtual time, in nanoseconds since simulation start.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Nanos(pub u64);

/// A span of virtual time, in nanoseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Duration(pub u64);

impl Nanos {
    pub const ZERO: Nanos = Nanos(0);

    #[inline]
    pub fn saturating_sub(self, other: Nanos) -> Duration {
        Duration(self.0.saturating_sub(other.0))
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    #[inline]
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    #[inline]
    pub fn from_secs(s: u64) -> Duration {
        Duration(s * 1_000_000_000)
    }

    #[inline]
    pub fn from_millis(ms: u64) -> Duration {
        Duration(ms * 1_000_000)
    }

    #[inline]
    pub fn from_micros(us: u64) -> Duration {
        Duration(us * 1_000)
    }

    #[inline]
    pub fn from_secs_f64(s: f64) -> Duration {
        Duration((s.max(0.0) * 1e9) as u64)
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    #[inline]
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
}

impl std::ops::Add<Duration> for Nanos {
    type Output = Nanos;
    #[inline]
    fn add(self, d: Duration) -> Nanos {
        Nanos(self.0 + d.0)
    }
}

impl std::ops::Add<Duration> for Duration {
    type Output = Duration;
    #[inline]
    fn add(self, d: Duration) -> Duration {
        Duration(self.0 + d.0)
    }
}

impl std::ops::Sub<Nanos> for Nanos {
    type Output = Duration;
    #[inline]
    fn sub(self, other: Nanos) -> Duration {
        Duration(self.0 - other.0)
    }
}

impl std::ops::Mul<u64> for Duration {
    type Output = Duration;
    #[inline]
    fn mul(self, k: u64) -> Duration {
        Duration(self.0 * k)
    }
}

impl std::fmt::Display for Nanos {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

impl std::fmt::Display for Duration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0 >= 1_000_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000_000 {
            write!(f, "{:.2}ms", self.as_millis_f64())
        } else {
            write!(f, "{}us", self.0 / 1000)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = Nanos(1_000) + Duration::from_micros(2);
        assert_eq!(t, Nanos(3_000));
        assert_eq!(t - Nanos(1_000), Duration(2_000));
        assert_eq!(Duration::from_millis(1) * 3, Duration(3_000_000));
    }

    #[test]
    fn display() {
        assert_eq!(format!("{}", Duration::from_millis(1500)), "1.500s");
        assert_eq!(format!("{}", Duration::from_micros(1500)), "1.50ms");
        assert_eq!(format!("{}", Duration(1500)), "1us");
    }

    #[test]
    fn saturating() {
        assert_eq!(Nanos(5).saturating_sub(Nanos(10)), Duration::ZERO);
    }
}

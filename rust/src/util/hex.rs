//! Hex encoding/decoding (lowercase).

const TABLE: &[u8; 16] = b"0123456789abcdef";

/// Encode bytes as lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(TABLE[(b >> 4) as usize] as char);
        s.push(TABLE[(b & 0xf) as usize] as char);
    }
    s
}

/// Decode a hex string; `None` on odd length or non-hex characters.
pub fn decode(s: &str) -> Option<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        let hi = val(pair[0])?;
        let lo = val(pair[1])?;
        out.push((hi << 4) | lo);
    }
    Some(out)
}

fn val(c: u8) -> Option<u8> {
    match c {
        b'0'..=b'9' => Some(c - b'0'),
        b'a'..=b'f' => Some(c - b'a' + 10),
        b'A'..=b'F' => Some(c - b'A' + 10),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data = [0u8, 1, 0x7f, 0x80, 0xff, 0xde, 0xad];
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn known_vector() {
        assert_eq!(encode(b"\x00\xff"), "00ff");
        assert_eq!(decode("deadBEEF").unwrap(), vec![0xde, 0xad, 0xbe, 0xef]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(decode("abc").is_none());
        assert!(decode("zz").is_none());
    }
}

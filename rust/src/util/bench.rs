//! Bench-harness toolkit: environment reporting (the Table I/II
//! stand-ins), aligned table printing, and wall-clock timing.
//!
//! criterion is not in the offline crate set, so the experiment benches
//! are `harness = false` binaries built on this module.

use std::time::Instant;

/// Print the testbed specification — our analogue of the paper's
/// Table I / Table II hardware & software tables.
pub fn print_environment(title: &str) {
    println!("== {title} ==");
    let cpu = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .map(|l| l.split(':').nth(1).unwrap_or("?").trim().to_string())
        })
        .unwrap_or_else(|| "unknown".into());
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(0);
    let mem_gb = std::fs::read_to_string("/proc/meminfo")
        .ok()
        .and_then(|s| {
            s.lines().find(|l| l.starts_with("MemTotal")).and_then(|l| {
                l.split_whitespace()
                    .nth(1)
                    .and_then(|kb| kb.parse::<f64>().ok())
                    .map(|kb| kb / 1048576.0)
            })
        })
        .unwrap_or(f64::NAN);
    println!("  CPU      : {cpu} ({cores} vcores)");
    println!("  Memory   : {mem_gb:.0} GB RAM");
    println!("  OS       : {}", std::env::consts::OS);
    println!(
        "  Software : rustc 1.95 / peersdb {} / xla 0.1.6 (PJRT CPU)",
        env!("CARGO_PKG_VERSION")
    );
    println!("  Network  : simulated (see DESIGN.md §Substitutions)");
    println!();
}

/// Scale factor for long benches: `PEERSDB_BENCH_SCALE` (default 1.0).
pub fn scale() -> f64 {
    std::env::var("PEERSDB_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

pub fn scaled(n: usize) -> usize {
    ((n as f64) * scale()).round().max(1.0) as usize
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", cols.join("  "));
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
        println!();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Repeat-measure a closure for micro-benchmarks; returns ns/iter stats.
pub fn bench_ns(label: &str, mut iters: u64, mut f: impl FnMut()) -> f64 {
    if iters == 0 {
        iters = 1;
    }
    // Warmup.
    for _ in 0..iters.min(100) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("  {label:<44} {:>12.0} ns/iter  ({:.2} M/s)", ns, 1e3 / ns);
    ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["region", "mean", "max"]);
        t.row(&["asia-east2".into(), "0.42".into(), "3.1".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    fn timing_positive() {
        let (_, dt) = timed(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(dt >= 0.002);
    }
}

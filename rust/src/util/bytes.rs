//! Refcounted immutable byte buffers — the zero-copy block plane.
//!
//! # Ownership model
//!
//! A [`Blob`] is an `Arc<[u8]>` newtype: one heap allocation, shared by
//! reference count, never mutated after construction. That immutability
//! is what makes sharing sound across the layers that handle block
//! payloads:
//!
//! * the **blockstore** keeps a `Blob` per block (`BlockMeta.data`);
//! * the **bitswap server** answers a `Want` by cloning the stored
//!   `Blob` into `Msg::Block` — a refcount bump, not a byte copy;
//! * the **simulated wire** moves the message (and thus the same
//!   allocation) through the event queue;
//! * the **fetching client** verifies the payload against its CID and
//!   stores the very same allocation via `BlockStore::put_trusted`.
//!
//! A block is therefore copied into memory exactly once (at `put` /
//! decode time) and hashed for verification exactly once per transfer,
//! no matter how many protocol layers it crosses. Content addressing
//! stays sound because nothing can mutate the shared bytes: a `Blob`
//! hands out only `&[u8]`.
//!
//! Decoding from a real wire ([`Decode`]) necessarily copies once, from
//! the receive buffer into a fresh allocation; everything after that is
//! again by refcount. `Clone` is O(1); equality compares contents (with
//! an identity fast path); [`Blob::ptr_eq`] observes sharing directly,
//! which the zero-copy property tests use.

use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use std::sync::Arc;

/// Immutable, cheaply clonable byte buffer. See the module docs for the
/// ownership model.
#[derive(Clone)]
pub struct Blob(Arc<[u8]>);

impl Blob {
    /// The empty blob (no allocation is shared, but none is needed).
    pub fn empty() -> Blob {
        Blob(Arc::from(&[][..]))
    }

    /// True when both handles share the same allocation (O(1) clones).
    pub fn ptr_eq(a: &Blob, b: &Blob) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

impl Default for Blob {
    fn default() -> Self {
        Blob::empty()
    }
}

impl std::ops::Deref for Blob {
    type Target = [u8];
    #[inline]
    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl AsRef<[u8]> for Blob {
    #[inline]
    fn as_ref(&self) -> &[u8] {
        &self.0
    }
}

impl From<Vec<u8>> for Blob {
    fn from(v: Vec<u8>) -> Blob {
        Blob(Arc::from(v))
    }
}

impl From<&[u8]> for Blob {
    fn from(s: &[u8]) -> Blob {
        Blob(Arc::from(s))
    }
}

impl<const N: usize> From<&[u8; N]> for Blob {
    fn from(s: &[u8; N]) -> Blob {
        Blob(Arc::from(&s[..]))
    }
}

impl PartialEq for Blob {
    fn eq(&self, other: &Blob) -> bool {
        Blob::ptr_eq(self, other) || self[..] == other[..]
    }
}
impl Eq for Blob {}

impl PartialEq<[u8]> for Blob {
    fn eq(&self, other: &[u8]) -> bool {
        self[..] == *other
    }
}

impl PartialEq<Vec<u8>> for Blob {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Blob> for Vec<u8> {
    fn eq(&self, other: &Blob) -> bool {
        self[..] == other[..]
    }
}

impl std::hash::Hash for Blob {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self[..].hash(state);
    }
}

impl std::fmt::Debug for Blob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.len().min(8);
        write!(f, "Blob({} B, {}…)", self.len(), crate::util::hex::encode(&self[..n]))
    }
}

impl Encode for Blob {
    fn encode(&self, w: &mut Writer) {
        w.put_bytes(self);
    }
}

impl Decode for Blob {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        // The one unavoidable copy: receive buffer → owned allocation.
        Ok(Blob::from(r.get_bytes()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{from_bytes, to_bytes};

    #[test]
    fn clone_is_zero_copy() {
        let b = Blob::from(b"shared payload".to_vec());
        let c = b.clone();
        assert!(Blob::ptr_eq(&b, &c));
        assert_eq!(b, c);
    }

    #[test]
    fn equality_compares_contents() {
        let a = Blob::from(&b"same"[..]);
        let b = Blob::from(&b"same"[..]);
        assert!(!Blob::ptr_eq(&a, &b));
        assert_eq!(a, b);
        assert_ne!(a, Blob::from(&b"diff"[..]));
        assert_eq!(a, b"same".to_vec());
    }

    #[test]
    fn codec_roundtrip() {
        for data in [&b""[..], &b"x"[..], &[7u8; 300][..]] {
            let blob = Blob::from(data);
            let bytes = to_bytes(&blob);
            let back: Blob = from_bytes(&bytes).unwrap();
            assert_eq!(back, blob);
        }
    }

    #[test]
    fn derefs_as_slice() {
        let b = Blob::from(&b"abc"[..]);
        assert_eq!(b.len(), 3);
        assert_eq!(&b[1..], b"bc");
        assert!(!b.is_empty());
        assert!(Blob::empty().is_empty());
    }
}

//! Deterministic PRNG: xoshiro256++ seeded through SplitMix64.
//!
//! Used everywhere randomness is needed (peer ids, jitter, workload
//! generation, property tests) so that every experiment is reproducible
//! from a single `u64` seed.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (e.g. one per peer).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine
    /// for simulation jitter and synthetic noise).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a byte slice with random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random 32-byte array (peer ids, keys).
    pub fn bytes32(&mut self) -> [u8; 32] {
        let mut b = [0u8; 32];
        self.fill_bytes(&mut b);
        b
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range(xs.len() as u64) as usize])
        }
    }

    /// Exponential inter-arrival sample with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(5);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn fork_independent() {
        let mut a = Rng::new(21);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}

//! Minimal leveled logger writing to stderr.
//!
//! Controlled by the `PEERSDB_LOG` environment variable
//! (`error|warn|info|debug|trace`, default `warn`). Deliberately tiny: the
//! hot paths use metrics, not logs.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_level() -> u8 {
    let lvl = match std::env::var("PEERSDB_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True when messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = init_level();
    }
    (level as u8) <= cur
}

/// Override the level programmatically (tests, experiment harnesses).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {args}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($t)*),
        )
    };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($t)*),
        )
    };
}
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($t)*),
        )
    };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}

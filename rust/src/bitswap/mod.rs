//! Bitswap-style block exchange (client sessions).
//!
//! The transfer protocol of the data layer: a fetcher sprays `Want`
//! requests at known providers, receives `Block` or `DontHave`, verifies
//! content against the CID (tamper-resistance comes from content
//! addressing, §III-C), and rotates through candidates on timeout. The
//! *server* side is one match arm in the owning node: a `Want` is answered
//! from the blockstore through the access-control middleware.
//!
//! This module corresponds to the `bitswap-tuning` test plan the paper
//! adapts from Testground; `benches/sim_transfer.rs` and
//! `benches/sim_fuzz.rs` exercise it under the same knobs (file size,
//! latency, bandwidth, churn).

use crate::cid::Cid;
use crate::codec::bin::{bytes_len, varint_len, Decode, DecodeError, Encode, Reader, Writer};
use crate::net::{PeerId, WireSize};
use crate::util::time::{Duration, Nanos};
use crate::util::Blob;
use std::collections::{BTreeMap, HashMap};

/// Bitswap wire messages. Block payloads are refcounted [`Blob`]s: the
/// serving node moves its stored allocation onto the wire and the
/// fetching node stores the same allocation — zero payload copies.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Request the block `cid`.
    Want { req_id: u64, cid: Cid },
    /// The requested block.
    Block { req_id: u64, cid: Cid, data: Blob },
    /// Peer does not have (or will not serve) the block.
    DontHave { req_id: u64, cid: Cid },
}

impl Encode for Msg {
    fn encode(&self, w: &mut Writer) {
        match self {
            Msg::Want { req_id, cid } => {
                w.put_u8(0);
                w.put_varint(*req_id);
                cid.encode(w);
            }
            Msg::Block { req_id, cid, data } => {
                w.put_u8(1);
                w.put_varint(*req_id);
                cid.encode(w);
                w.put_bytes(data);
            }
            Msg::DontHave { req_id, cid } => {
                w.put_u8(2);
                w.put_varint(*req_id);
                cid.encode(w);
            }
        }
    }
}

impl Decode for Msg {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(match r.get_u8()? {
            0 => Msg::Want { req_id: r.get_varint()?, cid: Cid::decode(r)? },
            1 => Msg::Block {
                req_id: r.get_varint()?,
                cid: Cid::decode(r)?,
                data: Blob::decode(r)?,
            },
            2 => Msg::DontHave { req_id: r.get_varint()?, cid: Cid::decode(r)? },
            _ => return Err(DecodeError("bad bitswap tag")),
        })
    }
}

impl WireSize for Msg {
    /// Exact encoded length in O(1): tag + varint req_id + 33-byte CID
    /// (+ length-prefixed payload for `Block`). Property-tested against
    /// the real encoding in `tests/prop.rs`.
    fn wire_size(&self) -> usize {
        match self {
            Msg::Want { req_id, .. } | Msg::DontHave { req_id, .. } => 1 + varint_len(*req_id) + 33,
            Msg::Block { req_id, data, .. } => 1 + varint_len(*req_id) + 33 + bytes_len(data.len()),
        }
    }
}

/// Identifier of an in-flight fetch session. Ordered so engine state
/// keyed by it can be swept deterministically.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FetchId(pub u64);

/// Completion events drained by the owner.
#[derive(Clone, Debug)]
pub enum BitswapEvent {
    /// Block received and verified (the payload is the wire allocation,
    /// shared — not copied — into the event).
    Fetched { id: FetchId, cid: Cid, data: Blob, from: PeerId },
    /// All candidates exhausted without success.
    Exhausted { id: FetchId, cid: Cid },
}

/// Per-request transfer outcome, drained by the owning node alongside
/// [`BitswapEvent`]s and fed into its
/// [`PeerQuality`](crate::peersdb::PeerQuality) table.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Outcome {
    /// A verified block arrived from `peer`, `latency` after its Want
    /// was sent.
    Block { peer: PeerId, latency: Duration },
    /// `peer` answered `DontHave` — or served a block failing content
    /// verification, which scores the same: it cannot provide this
    /// content.
    DontHave { peer: PeerId },
    /// The request to `peer` timed out without any answer.
    Timeout { peer: PeerId },
}

#[derive(Clone, Debug)]
pub struct BitswapConfig {
    /// How many providers to ask concurrently per block.
    pub spray: usize,
    /// Per-request timeout.
    pub rpc_timeout: Duration,
}

impl Default for BitswapConfig {
    fn default() -> Self {
        BitswapConfig {
            spray: 2,
            rpc_timeout: Duration::from_secs(4),
        }
    }
}

struct Fetch {
    id: FetchId,
    cid: Cid,
    candidates: Vec<PeerId>,
    next_candidate: usize,
    /// req_id → (peer, sent_at)
    in_flight: HashMap<u64, (PeerId, Nanos)>,
}

/// Client-side bitswap engine. One per node.
pub struct Engine {
    cfg: BitswapConfig,
    next_req: u64,
    next_fetch: u64,
    /// Ordered: the timeout sweep in [`Engine::tick`] iterates this, and
    /// its emission order must be reproducible across runs.
    fetches: BTreeMap<FetchId, Fetch>,
    /// req_id → fetch
    req_index: HashMap<u64, FetchId>,
    pub events: Vec<BitswapEvent>,
    /// Per-request outcomes for the owner's peer-quality accounting,
    /// drained like `events`.
    pub outcomes: Vec<Outcome>,
    // Ledger / stats
    pub blocks_received: u64,
    pub bytes_received: u64,
    pub tamper_detected: u64,
    pub timeouts: u64,
}

pub type Sends = Vec<(PeerId, Msg)>;

impl Engine {
    pub fn new(cfg: BitswapConfig) -> Self {
        Engine {
            cfg,
            next_req: 1,
            next_fetch: 1,
            fetches: BTreeMap::new(),
            req_index: HashMap::new(),
            events: Vec::new(),
            outcomes: Vec::new(),
            blocks_received: 0,
            bytes_received: 0,
            tamper_detected: 0,
            timeouts: 0,
        }
    }

    /// Start fetching `cid` from the given provider candidates.
    pub fn fetch(
        &mut self,
        now: Nanos,
        cid: Cid,
        candidates: Vec<PeerId>,
        out: &mut Sends,
    ) -> FetchId {
        let id = FetchId(self.next_fetch);
        self.next_fetch += 1;
        // Dedupe while preserving order: a duplicate provider would
        // consume several `spray` slots on the same peer, silently
        // defeating the redundancy the config promises (late candidates
        // via `add_candidates` were always deduped; initial ones not).
        let mut deduped: Vec<PeerId> = Vec::with_capacity(candidates.len());
        for p in candidates {
            if !deduped.contains(&p) {
                deduped.push(p);
            }
        }
        self.fetches.insert(
            id,
            Fetch {
                id,
                cid,
                candidates: deduped,
                next_candidate: 0,
                in_flight: HashMap::new(),
            },
        );
        self.drive(now, id, out);
        id
    }

    /// Add provider candidates discovered later (e.g. from a DHT lookup).
    pub fn add_candidates(&mut self, now: Nanos, id: FetchId, peers: Vec<PeerId>, out: &mut Sends) {
        let Some(f) = self.fetches.get_mut(&id) else { return };
        for p in peers {
            if !f.candidates.contains(&p) {
                f.candidates.push(p);
            }
        }
        self.drive(now, id, out);
    }

    pub fn cancel(&mut self, id: FetchId) {
        if let Some(f) = self.fetches.remove(&id) {
            for req in f.in_flight.keys() {
                self.req_index.remove(req);
            }
        }
    }

    pub fn active_fetches(&self) -> usize {
        self.fetches.len()
    }

    /// Live request-index entries (diagnostic surface: leak regression
    /// tests assert this drops to zero when fetches are cancelled).
    pub fn req_index_len(&self) -> usize {
        self.req_index.len()
    }

    fn drive(&mut self, now: Nanos, id: FetchId, out: &mut Sends) {
        let Some(f) = self.fetches.get_mut(&id) else { return };
        // Issue Wants until `spray` are in flight or candidates run out.
        while f.in_flight.len() < self.cfg.spray && f.next_candidate < f.candidates.len() {
            let peer = f.candidates[f.next_candidate];
            f.next_candidate += 1;
            let req_id = self.next_req;
            self.next_req += 1;
            f.in_flight.insert(req_id, (peer, now));
            self.req_index.insert(req_id, id);
            out.push((peer, Msg::Want { req_id, cid: f.cid }));
        }
        if f.in_flight.is_empty() {
            // Nothing in flight and no candidates left.
            let cid = f.cid;
            self.fetches.remove(&id);
            self.events.push(BitswapEvent::Exhausted { id, cid });
        }
    }

    /// Handle a client-side message (`Block` / `DontHave`).
    pub fn on_msg(&mut self, now: Nanos, from: PeerId, msg: Msg, out: &mut Sends) {
        match msg {
            Msg::Block { req_id, cid, data } => {
                let Some(fid) = self.req_index.remove(&req_id) else { return };
                let Some(f) = self.fetches.get_mut(&fid) else { return };
                let sent = f.in_flight.remove(&req_id).map(|(_, sent)| sent);
                if !cid.verifies(&data) || cid != f.cid {
                    // Tampered or mismatched content: content addressing
                    // catches it; treat the peer as not having the block.
                    self.tamper_detected += 1;
                    self.outcomes.push(Outcome::DontHave { peer: from });
                    self.drive(now, fid, out);
                    return;
                }
                self.outcomes.push(Outcome::Block {
                    peer: from,
                    latency: sent.map(|s| now.saturating_sub(s)).unwrap_or(Duration::ZERO),
                });
                self.blocks_received += 1;
                self.bytes_received += data.len() as u64;
                // Cancel remaining in-flight requests for this fetch.
                let stale: Vec<u64> = f.in_flight.keys().copied().collect();
                for req in stale {
                    self.req_index.remove(&req);
                }
                let id = f.id;
                self.fetches.remove(&fid);
                self.events.push(BitswapEvent::Fetched { id, cid, data, from });
            }
            Msg::DontHave { req_id, .. } => {
                let Some(fid) = self.req_index.remove(&req_id) else { return };
                if let Some(f) = self.fetches.get_mut(&fid) {
                    f.in_flight.remove(&req_id);
                }
                self.outcomes.push(Outcome::DontHave { peer: from });
                self.drive(now, fid, out);
            }
            Msg::Want { .. } => {
                debug_assert!(false, "server-side Want must be handled by the node");
            }
        }
    }

    /// Expire timed-out requests (rotating to the next candidates).
    pub fn tick(&mut self, now: Nanos, out: &mut Sends) {
        let timeout = self.cfg.rpc_timeout;
        let mut to_drive = Vec::new();
        for (fid, f) in self.fetches.iter_mut() {
            let expired: Vec<u64> = f
                .in_flight
                .iter()
                .filter(|(_, (_, sent))| now.saturating_sub(*sent) >= timeout)
                .map(|(r, _)| *r)
                .collect();
            if !expired.is_empty() {
                for r in expired {
                    if let Some((peer, _)) = f.in_flight.remove(&r) {
                        // Timeout penalties are additive and commute, so
                        // the HashMap-ordered sweep within one fetch
                        // leaves the quality table deterministic.
                        self.outcomes.push(Outcome::Timeout { peer });
                    }
                    self.req_index.remove(&r);
                    self.timeouts += 1;
                }
                to_drive.push(*fid);
            }
        }
        for fid in to_drive {
            self.drive(now, fid, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup() -> (Engine, Vec<PeerId>, Cid, Blob) {
        let mut rng = Rng::new(1);
        let peers: Vec<PeerId> = (0..4).map(|_| PeerId::from_rng(&mut rng)).collect();
        let data = Blob::from(&b"performance trace"[..]);
        let cid = Cid::of_raw(&data);
        (Engine::new(BitswapConfig::default()), peers, cid, data)
    }

    #[test]
    fn msg_roundtrip() {
        let (_, _, cid, data) = setup();
        for m in [
            Msg::Want { req_id: 1, cid },
            Msg::Block { req_id: 2, cid, data: data.clone() },
            Msg::DontHave { req_id: 3, cid },
        ] {
            let b = crate::codec::to_bytes(&m);
            assert_eq!(crate::codec::from_bytes::<Msg>(&b).unwrap(), m);
            assert_eq!(m.wire_size(), b.len(), "wire_size must be exact");
        }
    }

    #[test]
    fn happy_path_fetch() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        let id = e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        assert_eq!(out.len(), 2); // spray = 2
        let (to, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        e.on_msg(Nanos(1), to, Msg::Block { req_id, cid, data: data.clone() }, &mut out);
        let ev = e.events.pop().unwrap();
        let BitswapEvent::Fetched { id: fid, data: got, .. } = ev else { panic!() };
        assert_eq!(fid, id);
        assert_eq!(got, data);
        assert_eq!(e.active_fetches(), 0);
    }

    #[test]
    fn fetched_event_shares_wire_allocation() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        let (to, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        e.on_msg(Nanos(1), to, Msg::Block { req_id, cid, data: data.clone() }, &mut out);
        let Some(BitswapEvent::Fetched { data: got, .. }) = e.events.pop() else { panic!() };
        // Wire payload → event without a byte copy.
        assert!(Blob::ptr_eq(&got, &data));
    }

    #[test]
    fn tampered_block_rejected_and_rotates() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        let (to, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        out.clear();
        e.on_msg(Nanos(1), to, Msg::Block { req_id, cid, data: b"EVIL".to_vec().into() }, &mut out);
        assert_eq!(e.tamper_detected, 1);
        // Rotated to candidate #3 (spray refilled).
        assert_eq!(out.len(), 1);
        // Real block from another peer succeeds.
        let (to2, Msg::Want { req_id: r2, .. }) = out[0].clone() else { panic!() };
        e.on_msg(Nanos(2), to2, Msg::Block { req_id: r2, cid, data }, &mut out);
        assert!(matches!(e.events.pop(), Some(BitswapEvent::Fetched { .. })));
    }

    #[test]
    fn dont_have_rotates_candidates() {
        let (mut e, peers, cid, _) = setup();
        let mut out = Sends::new();
        e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        let wants: Vec<(PeerId, u64)> = out
            .iter()
            .map(|(p, m)| {
                let Msg::Want { req_id, .. } = m else { panic!() };
                (*p, *req_id)
            })
            .collect();
        out.clear();
        for (p, r) in &wants {
            e.on_msg(Nanos(1), *p, Msg::DontHave { req_id: *r, cid }, &mut out);
        }
        // Both remaining candidates now queried.
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn exhaustion_reported() {
        let (mut e, peers, cid, _) = setup();
        let mut out = Sends::new();
        let id = e.fetch(Nanos(0), cid, peers[..1].to_vec(), &mut out);
        let (p, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        out.clear();
        e.on_msg(Nanos(1), p, Msg::DontHave { req_id, cid }, &mut out);
        assert!(out.is_empty());
        let ev = e.events.pop().unwrap();
        assert!(matches!(ev, BitswapEvent::Exhausted { id: i, .. } if i == id));
    }

    #[test]
    fn timeout_rotates() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        out.clear();
        e.tick(Nanos(5_000_000_000), &mut out); // past 4s timeout
        assert_eq!(e.timeouts, 2);
        assert_eq!(out.len(), 2); // rotated to candidates 3,4
        let (to, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        e.on_msg(Nanos(5_100_000_000), to, Msg::Block { req_id, cid, data }, &mut out);
        assert!(matches!(e.events.pop(), Some(BitswapEvent::Fetched { .. })));
    }

    #[test]
    fn duplicate_candidates_spray_distinct_peers() {
        let (mut e, peers, cid, _) = setup();
        let mut out = Sends::new();
        // The same provider listed twice must not consume both spray
        // slots: the initial candidate list is deduped like late ones.
        e.fetch(Nanos(0), cid, vec![peers[0], peers[0], peers[1]], &mut out);
        assert_eq!(out.len(), 2);
        let targets: Vec<PeerId> = out.iter().map(|(p, _)| *p).collect();
        assert_eq!(targets, vec![peers[0], peers[1]], "spray hits distinct peers");
    }

    #[test]
    fn cancel_clears_request_state_and_sends_nothing() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        let id = e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        assert_eq!(e.req_index_len(), 2);
        let (to, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        out.clear();
        e.cancel(id);
        assert_eq!(e.active_fetches(), 0);
        assert_eq!(e.req_index_len(), 0, "cancel must not leak req_index entries");
        // A straggler Block for the cancelled fetch is ignored: no event,
        // no send, no rotation.
        e.on_msg(Nanos(1), to, Msg::Block { req_id, cid, data }, &mut out);
        e.tick(Nanos(10_000_000_000), &mut out);
        assert!(out.is_empty());
        assert!(e.events.is_empty());
    }

    #[test]
    fn outcomes_record_block_latency_donthave_and_timeout() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        e.fetch(Nanos(0), cid, peers.clone(), &mut out);
        let (p0, Msg::Want { req_id: r0, .. }) = out[0].clone() else { panic!() };
        let (p1, Msg::Want { req_id: r1, .. }) = out[1].clone() else { panic!() };
        out.clear();
        e.on_msg(Nanos(250_000_000), p1, Msg::DontHave { req_id: r1, cid }, &mut out);
        assert_eq!(e.outcomes.pop(), Some(Outcome::DontHave { peer: p1 }));
        e.on_msg(Nanos(250_000_000), p0, Msg::Block { req_id: r0, cid, data: data.clone() }, &mut out);
        let Some(Outcome::Block { peer, latency }) = e.outcomes.pop() else { panic!() };
        assert_eq!(peer, p0);
        assert_eq!(latency, Duration::from_millis(250), "latency = now - sent_at");

        // Timeout outcome names the peer whose request expired.
        out.clear();
        e.outcomes.clear();
        e.fetch(Nanos(0), cid, peers[..1].to_vec(), &mut out);
        e.tick(Nanos(5_000_000_000), &mut out);
        assert_eq!(e.outcomes.pop(), Some(Outcome::Timeout { peer: peers[0] }));

        // A tampered block scores as DontHave: the peer cannot provide
        // this content.
        out.clear();
        e.outcomes.clear();
        e.fetch(Nanos(0), cid, peers[..1].to_vec(), &mut out);
        let (pt, Msg::Want { req_id: rt, .. }) = out[0].clone() else { panic!() };
        e.on_msg(Nanos(1), pt, Msg::Block { req_id: rt, cid, data: b"EVIL".to_vec().into() }, &mut out);
        assert_eq!(e.outcomes.pop(), Some(Outcome::DontHave { peer: pt }));
    }

    #[test]
    fn late_candidates_resume_exhausted_not_done() {
        let (mut e, peers, cid, data) = setup();
        let mut out = Sends::new();
        // Start with zero candidates: immediately exhausted.
        let id = e.fetch(Nanos(0), cid, vec![], &mut out);
        assert!(matches!(e.events.pop(), Some(BitswapEvent::Exhausted { .. })));
        // A new fetch with late candidates succeeds.
        let id2 = e.fetch(Nanos(1), cid, vec![], &mut out);
        assert!(matches!(e.events.pop(), Some(BitswapEvent::Exhausted { .. })));
        assert_ne!(id, id2);
        let id3 = e.fetch(Nanos(2), cid, peers[..1].to_vec(), &mut out);
        let (p, Msg::Want { req_id, .. }) = out[0].clone() else { panic!() };
        e.on_msg(Nanos(3), p, Msg::Block { req_id, cid, data }, &mut out);
        assert!(matches!(e.events.pop(), Some(BitswapEvent::Fetched { id, .. }) if id == id3));
    }
}

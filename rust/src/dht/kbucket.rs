//! LRU k-buckets and the routing table.

use crate::dht::key::Key;
use crate::net::PeerId;
use crate::util::time::Nanos;

/// Default bucket capacity (Kademlia's `k`).
pub const K: usize = 20;

#[derive(Clone, Debug)]
struct Contact {
    peer: PeerId,
    last_seen: Nanos,
}

/// One bucket. LRU is tracked by per-contact timestamps (not by vector
/// order): `touch` is an in-place timestamp update — this is the hottest
/// write in the whole DHT (every inbound RPC touches a bucket), so no
/// element shifting happens on it. When full, the stalest contact is
/// evicted in favour of fresh ones (the classic implementation pings it
/// first; in our deployments liveness is tracked by the peersdb layer,
/// so eviction is optimistic).
#[derive(Clone, Debug, Default)]
pub struct KBucket {
    contacts: Vec<Contact>,
}

impl KBucket {
    pub fn len(&self) -> usize {
        self.contacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.contacts.is_empty()
    }

    pub fn contains(&self, peer: &PeerId) -> bool {
        self.contacts.iter().any(|c| &c.peer == peer)
    }

    pub fn touch(&mut self, peer: PeerId, now: Nanos) {
        if let Some(c) = self.contacts.iter_mut().find(|c| c.peer == peer) {
            c.last_seen = now;
        } else if self.contacts.len() < K {
            self.contacts.push(Contact { peer, last_seen: now });
        } else {
            // Optimistic eviction of the least-recently-seen contact.
            // Ties break on peer id — the same order [`KBucket::stalest`]
            // reports, so the eviction victim is always predictable.
            let stalest = self
                .contacts
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| (c.last_seen, c.peer))
                .map(|(i, _)| i)
                .unwrap();
            self.contacts[stalest] = Contact { peer, last_seen: now };
        }
    }

    pub fn remove(&mut self, peer: &PeerId) {
        self.contacts.retain(|c| &c.peer != peer);
    }

    pub fn peers(&self) -> impl Iterator<Item = PeerId> + '_ {
        self.contacts.iter().map(|c| c.peer)
    }

    /// The least-recently-seen contact — the next eviction victim.
    /// Uses the same `(last_seen, peer)` order as [`KBucket::touch`]'s
    /// eviction, so the prediction holds even under timestamp ties.
    pub fn stalest(&self) -> Option<PeerId> {
        self.contacts
            .iter()
            .min_by_key(|c| (c.last_seen, c.peer))
            .map(|c| c.peer)
    }
}

/// The routing table: 256 buckets indexed by XOR-distance prefix.
pub struct RoutingTable {
    own: Key,
    buckets: Vec<KBucket>,
}

impl RoutingTable {
    pub fn new(own: Key) -> Self {
        RoutingTable {
            own,
            buckets: vec![KBucket::default(); 256],
        }
    }

    pub fn own_key(&self) -> Key {
        self.own
    }

    /// Record contact with a peer (inserts or refreshes).
    pub fn touch(&mut self, peer: PeerId, now: Nanos) {
        if let Some(i) = self.own.bucket_index(&Key::from_peer(peer)) {
            self.buckets[i].touch(peer, now);
        }
    }

    pub fn remove(&mut self, peer: &PeerId) {
        if let Some(i) = self.own.bucket_index(&Key::from_peer(*peer)) {
            self.buckets[i].remove(peer);
        }
    }

    pub fn contains(&self, peer: &PeerId) -> bool {
        self.own
            .bucket_index(&Key::from_peer(*peer))
            .map(|i| self.buckets[i].contains(peer))
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `n` closest known peers to `target`, sorted by distance.
    pub fn closest(&self, target: &Key, n: usize) -> Vec<PeerId> {
        let total: usize = self.buckets.iter().map(|b| b.len()).sum();
        let mut all: Vec<(crate::dht::key::Distance, PeerId)> = Vec::with_capacity(total);
        for b in &self.buckets {
            for p in b.peers() {
                all.push((target.distance(&Key::from_peer(p)), p));
            }
        }
        if all.len() > n {
            // Partition the n closest to the front, then order just them.
            all.select_nth_unstable_by(n - 1, |a, b| a.0.cmp(&b.0));
            all.truncate(n);
        }
        all.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        all.into_iter().map(|(_, p)| p).collect()
    }

    /// All peers currently in the table.
    pub fn peers(&self) -> Vec<PeerId> {
        self.buckets.iter().flat_map(|b| b.peers()).collect()
    }

    /// Structural invariants, asserted by scenario harnesses and property
    /// tests after arbitrary touch/remove interleavings:
    ///
    /// 1. no bucket exceeds `K` contacts,
    /// 2. the own id never appears in the table,
    /// 3. every contact sits in the bucket its XOR distance selects,
    /// 4. no peer appears twice.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for (i, b) in self.buckets.iter().enumerate() {
            if b.len() > K {
                return Err(format!("bucket {i} over capacity ({} > {K})", b.len()));
            }
            for p in b.peers() {
                match self.own.bucket_index(&Key::from_peer(p)) {
                    None => return Err(format!("own id {p:?} stored in bucket {i}")),
                    Some(j) if j != i => {
                        return Err(format!("{p:?} in bucket {i}, belongs in {j}"))
                    }
                    Some(_) => {}
                }
                if !seen.insert(p) {
                    return Err(format!("duplicate contact {p:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn peers(n: usize, seed: u64) -> Vec<PeerId> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    #[test]
    fn touch_inserts_and_refreshes() {
        let mut rng = Rng::new(1);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let ps = peers(10, 2);
        for (i, p) in ps.iter().enumerate() {
            rt.touch(*p, Nanos(i as u64));
        }
        assert_eq!(rt.len(), 10);
        for p in &ps {
            assert!(rt.contains(p));
        }
        rt.touch(ps[0], Nanos(100)); // refresh — no duplicate
        assert_eq!(rt.len(), 10);
    }

    #[test]
    fn bucket_eviction_when_full() {
        let mut b = KBucket::default();
        let ps = peers(K + 5, 3);
        for (i, p) in ps.iter().enumerate() {
            b.touch(*p, Nanos(i as u64));
        }
        assert_eq!(b.len(), K);
        // The oldest 5 were evicted.
        for p in &ps[..5] {
            assert!(!b.contains(p));
        }
        assert!(b.contains(&ps[K + 4]));
    }

    #[test]
    fn closest_returns_sorted() {
        let mut rng = Rng::new(4);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let ps = peers(200, 5);
        for p in &ps {
            rt.touch(*p, Nanos(0));
        }
        let target = Key(rng.bytes32());
        let cl = rt.closest(&target, 20);
        assert_eq!(cl.len(), 20);
        for w in cl.windows(2) {
            assert!(
                target.distance(&Key::from_peer(w[0])) <= target.distance(&Key::from_peer(w[1]))
            );
        }
        // Brute-force check against the peers the table actually retained
        // (with 200 random peers, bucket eviction is expected).
        let retained = rt.peers();
        let brute = retained
            .iter()
            .min_by_key(|p| target.distance(&Key::from_peer(**p)))
            .unwrap();
        assert_eq!(cl[0], *brute);
    }

    #[test]
    fn own_id_never_inserted() {
        let mut rng = Rng::new(6);
        let me = PeerId::from_rng(&mut rng);
        let mut rt = RoutingTable::new(Key::from_peer(me));
        rt.touch(me, Nanos(0));
        assert_eq!(rt.len(), 0);
    }

    #[test]
    fn remove_works() {
        let mut rng = Rng::new(7);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let ps = peers(5, 8);
        for p in &ps {
            rt.touch(*p, Nanos(0));
        }
        rt.remove(&ps[2]);
        assert!(!rt.contains(&ps[2]));
        assert_eq!(rt.len(), 4);
    }
}

//! LRU k-buckets and the routing table, plus the `pending_verify`
//! first-contact tier used by distance-verified routing updates
//! (`DhtConfig::verify_peers`): peers known only by hearsay — or peers
//! that stopped answering — wait here until they answer an RPC
//! themselves, instead of occupying bucket slots on an attacker's word.

use crate::dht::key::Key;
use crate::net::PeerId;
use crate::util::time::{Duration, Nanos};
use std::collections::BTreeMap;

/// Default bucket capacity (Kademlia's `k`).
pub const K: usize = 20;

/// Capacity of the `pending_verify` tier (see
/// [`RoutingTable::quarantine`]); when full, the entry farthest from the
/// own id is displaced — the *close* unverified peers are the ones an
/// eclipse targets, so they are the ones worth re-verifying.
pub const QUARANTINE_CAP: usize = 4 * K;

#[derive(Clone, Debug)]
struct Contact {
    peer: PeerId,
    last_seen: Nanos,
}

/// One bucket. LRU is tracked by per-contact timestamps (not by vector
/// order): `touch` is an in-place timestamp update — this is the hottest
/// write in the whole DHT (every inbound RPC touches a bucket), so no
/// element shifting happens on it. When full, the stalest contact is
/// evicted in favour of fresh ones (the classic implementation pings it
/// first; in our deployments liveness is tracked by the peersdb layer,
/// so eviction is optimistic).
#[derive(Clone, Debug, Default)]
pub struct KBucket {
    contacts: Vec<Contact>,
}

impl KBucket {
    pub fn len(&self) -> usize {
        self.contacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.contacts.is_empty()
    }

    pub fn contains(&self, peer: &PeerId) -> bool {
        self.contacts.iter().any(|c| &c.peer == peer)
    }

    pub fn touch(&mut self, peer: PeerId, now: Nanos) {
        if let Some(c) = self.contacts.iter_mut().find(|c| c.peer == peer) {
            c.last_seen = now;
        } else if self.contacts.len() < K {
            self.contacts.push(Contact { peer, last_seen: now });
        } else {
            // Optimistic eviction of the least-recently-seen contact.
            // Ties break on peer id — the same order [`KBucket::stalest`]
            // reports, so the eviction victim is always predictable.
            let stalest = self
                .contacts
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| (c.last_seen, c.peer))
                .map(|(i, _)| i)
                .unwrap();
            self.contacts[stalest] = Contact { peer, last_seen: now };
        }
    }

    pub fn remove(&mut self, peer: &PeerId) {
        self.contacts.retain(|c| &c.peer != peer);
    }

    pub fn peers(&self) -> impl Iterator<Item = PeerId> + '_ {
        self.contacts.iter().map(|c| c.peer)
    }

    /// The least-recently-seen contact — the next eviction victim.
    /// Uses the same `(last_seen, peer)` order as [`KBucket::touch`]'s
    /// eviction, so the prediction holds even under timestamp ties.
    pub fn stalest(&self) -> Option<PeerId> {
        self.contacts
            .iter()
            .min_by_key(|c| (c.last_seen, c.peer))
            .map(|c| c.peer)
    }
}

/// Re-verification bookkeeping for one quarantined peer.
#[derive(Clone, Copy, Debug)]
struct VerifyState {
    /// Earliest instant the next verification attempt may go out.
    next_attempt: Nanos,
    /// Attempts made so far (drives the exponential backoff).
    failures: u32,
    /// `true` when this peer once sat in a bucket and was demoted on
    /// timeout (as opposed to pure hearsay). Demoted peers are the
    /// eclipse-recovery lifeline, so hearsay can never displace them.
    demoted: bool,
}

/// The routing table: 256 buckets indexed by XOR-distance prefix, plus
/// the bounded `pending_verify` quarantine tier (empty — and free —
/// unless the engine runs with `verify_peers` on).
pub struct RoutingTable {
    own: Key,
    buckets: Vec<KBucket>,
    /// Peers known but not yet admitted: hearsay first contacts and
    /// timed-out demotions, awaiting a successful verification RPC.
    /// Ordered map so verification-ping emission is deterministic.
    pending_verify: BTreeMap<PeerId, VerifyState>,
}

impl RoutingTable {
    pub fn new(own: Key) -> Self {
        RoutingTable {
            own,
            buckets: vec![KBucket::default(); 256],
            pending_verify: BTreeMap::new(),
        }
    }

    pub fn own_key(&self) -> Key {
        self.own
    }

    /// Record contact with a peer (inserts or refreshes). A quarantined
    /// peer being touched has been verified by the caller — it leaves
    /// the `pending_verify` tier as it enters its bucket (the emptiness
    /// guard keeps this branch-only on the verify-off hot path).
    pub fn touch(&mut self, peer: PeerId, now: Nanos) {
        if !self.pending_verify.is_empty() {
            self.pending_verify.remove(&peer);
        }
        if let Some(i) = self.own.bucket_index(&Key::from_peer(peer)) {
            self.buckets[i].touch(peer, now);
        }
    }

    /// Hold `peer` in the `pending_verify` tier until it answers an RPC:
    /// the first-contact quarantine behind distance-verified routing
    /// updates. No-op (returning `false`) for the own id, peers already
    /// in a bucket, and peers already quarantined. `not_before` gates
    /// the first verification attempt (used to pause just-demoted
    /// peers); `demoted` records provenance — a peer evicted from a
    /// bucket on timeout, versus pure hearsay.
    ///
    /// At capacity, displacement is **provenance-aware**: hearsay may
    /// only displace farther hearsay (a newcomer farther than every
    /// hearsay entry is dropped), while a demoted peer displaces the
    /// farthest hearsay entry outright and competes with other demoted
    /// entries by distance. An attacker flooding forged names near the
    /// own id therefore churns the hearsay sub-pool at worst — it can
    /// never flush a demoted (once-verified) peer out of
    /// re-verification, which is what the eclipse recovery depends on.
    pub fn quarantine(&mut self, peer: PeerId, not_before: Nanos, demoted: bool) -> bool {
        if self.own.bucket_index(&Key::from_peer(peer)).is_none()
            || self.contains(&peer)
            || self.pending_verify.contains_key(&peer)
        {
            return false;
        }
        if self.pending_verify.len() >= QUARANTINE_CAP {
            let dist = |p: &PeerId| self.own.distance(&Key::from_peer(*p));
            let hearsay_victim = self
                .pending_verify
                .iter()
                .filter(|(_, st)| !st.demoted)
                .map(|(p, _)| *p)
                .max_by_key(dist);
            let victim = match hearsay_victim {
                // Hearsay vs hearsay and demoted vs demoted compete by
                // distance; demoted vs hearsay always wins.
                Some(v) if demoted || dist(&peer) < dist(&v) => v,
                Some(_) => return false,
                None if demoted => {
                    let farthest = *self
                        .pending_verify
                        .keys()
                        .max_by_key(|p| dist(*p))
                        .expect("tier is non-empty at capacity");
                    if dist(&peer) >= dist(&farthest) {
                        return false;
                    }
                    farthest
                }
                None => return false,
            };
            self.pending_verify.remove(&victim);
        }
        self.pending_verify
            .insert(peer, VerifyState { next_attempt: not_before, failures: 0, demoted });
        true
    }

    /// Whether `peer` currently sits in the `pending_verify` tier.
    pub fn is_quarantined(&self, peer: &PeerId) -> bool {
        self.pending_verify.contains_key(peer)
    }

    /// Number of quarantined peers (diagnostics).
    pub fn quarantined_len(&self) -> usize {
        self.pending_verify.len()
    }

    /// Quarantined peers due a verification attempt at `now`, in id
    /// order. Each returned peer's backoff is bumped — the next attempt
    /// is scheduled `base × 2^min(failures, 3)` ahead — so the caller
    /// just sends one ping per returned peer. A peer that answers is
    /// promoted by [`RoutingTable::touch`]; one that never answers is
    /// retried forever at the capped backoff (an eclipse must therefore
    /// keep its victims unreachable *permanently* to keep them out).
    pub fn due_for_verify(&mut self, now: Nanos, base: Duration) -> Vec<PeerId> {
        let mut due = Vec::new();
        for (peer, st) in self.pending_verify.iter_mut() {
            if st.next_attempt <= now {
                due.push(*peer);
                let backoff = Duration(base.0 << st.failures.min(3));
                st.failures = st.failures.saturating_add(1);
                st.next_attempt = now + backoff;
            }
        }
        due
    }

    pub fn remove(&mut self, peer: &PeerId) {
        if let Some(i) = self.own.bucket_index(&Key::from_peer(*peer)) {
            self.buckets[i].remove(peer);
        }
    }

    pub fn contains(&self, peer: &PeerId) -> bool {
        self.own
            .bucket_index(&Key::from_peer(*peer))
            .map(|i| self.buckets[i].contains(peer))
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `n` closest known peers to `target`, sorted by distance.
    pub fn closest(&self, target: &Key, n: usize) -> Vec<PeerId> {
        let total: usize = self.buckets.iter().map(|b| b.len()).sum();
        let mut all: Vec<(crate::dht::key::Distance, PeerId)> = Vec::with_capacity(total);
        for b in &self.buckets {
            for p in b.peers() {
                all.push((target.distance(&Key::from_peer(p)), p));
            }
        }
        if all.len() > n {
            // Partition the n closest to the front, then order just them.
            all.select_nth_unstable_by(n - 1, |a, b| a.0.cmp(&b.0));
            all.truncate(n);
        }
        all.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        all.into_iter().map(|(_, p)| p).collect()
    }

    /// All peers currently in the table.
    pub fn peers(&self) -> Vec<PeerId> {
        self.buckets.iter().flat_map(|b| b.peers()).collect()
    }

    /// Structural invariants, asserted by scenario harnesses and property
    /// tests after arbitrary touch/remove interleavings:
    ///
    /// 1. no bucket exceeds `K` contacts,
    /// 2. the own id never appears in the table,
    /// 3. every contact sits in the bucket its XOR distance selects,
    /// 4. no peer appears twice,
    /// 5. the `pending_verify` tier respects its capacity and is
    ///    disjoint from the buckets (a peer is verified or not — never
    ///    both).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for (i, b) in self.buckets.iter().enumerate() {
            if b.len() > K {
                return Err(format!("bucket {i} over capacity ({} > {K})", b.len()));
            }
            for p in b.peers() {
                match self.own.bucket_index(&Key::from_peer(p)) {
                    None => return Err(format!("own id {p:?} stored in bucket {i}")),
                    Some(j) if j != i => {
                        return Err(format!("{p:?} in bucket {i}, belongs in {j}"))
                    }
                    Some(_) => {}
                }
                if !seen.insert(p) {
                    return Err(format!("duplicate contact {p:?}"));
                }
            }
        }
        if self.pending_verify.len() > QUARANTINE_CAP {
            return Err(format!(
                "pending_verify over capacity ({} > {QUARANTINE_CAP})",
                self.pending_verify.len()
            ));
        }
        for p in self.pending_verify.keys() {
            if seen.contains(p) {
                return Err(format!("{p:?} is both tabled and quarantined"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn peers(n: usize, seed: u64) -> Vec<PeerId> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    #[test]
    fn touch_inserts_and_refreshes() {
        let mut rng = Rng::new(1);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let ps = peers(10, 2);
        for (i, p) in ps.iter().enumerate() {
            rt.touch(*p, Nanos(i as u64));
        }
        assert_eq!(rt.len(), 10);
        for p in &ps {
            assert!(rt.contains(p));
        }
        rt.touch(ps[0], Nanos(100)); // refresh — no duplicate
        assert_eq!(rt.len(), 10);
    }

    #[test]
    fn bucket_eviction_when_full() {
        let mut b = KBucket::default();
        let ps = peers(K + 5, 3);
        for (i, p) in ps.iter().enumerate() {
            b.touch(*p, Nanos(i as u64));
        }
        assert_eq!(b.len(), K);
        // The oldest 5 were evicted.
        for p in &ps[..5] {
            assert!(!b.contains(p));
        }
        assert!(b.contains(&ps[K + 4]));
    }

    #[test]
    fn closest_returns_sorted() {
        let mut rng = Rng::new(4);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let ps = peers(200, 5);
        for p in &ps {
            rt.touch(*p, Nanos(0));
        }
        let target = Key(rng.bytes32());
        let cl = rt.closest(&target, 20);
        assert_eq!(cl.len(), 20);
        for w in cl.windows(2) {
            assert!(
                target.distance(&Key::from_peer(w[0])) <= target.distance(&Key::from_peer(w[1]))
            );
        }
        // Brute-force check against the peers the table actually retained
        // (with 200 random peers, bucket eviction is expected).
        let retained = rt.peers();
        let brute = retained
            .iter()
            .min_by_key(|p| target.distance(&Key::from_peer(**p)))
            .unwrap();
        assert_eq!(cl[0], *brute);
    }

    #[test]
    fn own_id_never_inserted() {
        let mut rng = Rng::new(6);
        let me = PeerId::from_rng(&mut rng);
        let mut rt = RoutingTable::new(Key::from_peer(me));
        rt.touch(me, Nanos(0));
        assert_eq!(rt.len(), 0);
    }

    #[test]
    fn quarantine_holds_until_touch_promotes() {
        let mut rng = Rng::new(9);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let p = PeerId::from_rng(&mut rng);
        assert!(rt.quarantine(p, Nanos(10), false));
        assert!(!rt.quarantine(p, Nanos(10), true), "double quarantine is a no-op");
        assert!(rt.is_quarantined(&p));
        assert!(!rt.contains(&p));
        rt.check_invariants().unwrap();
        // Not due before `not_before`; due (with backoff bump) after.
        assert!(rt.due_for_verify(Nanos(5), Duration::from_secs(4)).is_empty());
        assert_eq!(rt.due_for_verify(Nanos(10), Duration::from_secs(4)), vec![p]);
        assert!(
            rt.due_for_verify(Nanos(11), Duration::from_secs(4)).is_empty(),
            "backoff postpones the next attempt"
        );
        // Touch = verified: bucket in, tier out.
        rt.touch(p, Nanos(12));
        assert!(rt.contains(&p));
        assert!(!rt.is_quarantined(&p));
        rt.check_invariants().unwrap();
    }

    #[test]
    fn quarantine_rejects_tabled_peers_and_own_id() {
        let mut rng = Rng::new(10);
        let me = PeerId::from_rng(&mut rng);
        let mut rt = RoutingTable::new(Key::from_peer(me));
        assert!(!rt.quarantine(me, Nanos(0), true), "own id never quarantined");
        let p = PeerId::from_rng(&mut rng);
        rt.touch(p, Nanos(0));
        assert!(!rt.quarantine(p, Nanos(1), false), "tabled peers need no verification");
        assert_eq!(rt.quarantined_len(), 0);
    }

    #[test]
    fn quarantine_capacity_keeps_the_closest() {
        let mut rng = Rng::new(11);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let mut pool = peers(QUARANTINE_CAP + 10, 12);
        for p in &pool {
            rt.quarantine(*p, Nanos(0), false);
        }
        assert_eq!(rt.quarantined_len(), QUARANTINE_CAP);
        rt.check_invariants().unwrap();
        // The retained set is exactly the CAP closest to the own id.
        pool.sort_by_key(|p| own.distance(&Key::from_peer(*p)));
        for p in &pool[..QUARANTINE_CAP] {
            assert!(rt.is_quarantined(p), "close peer displaced");
        }
        for p in &pool[QUARANTINE_CAP..] {
            assert!(!rt.is_quarantined(p), "far peer retained");
        }
    }

    #[test]
    fn hearsay_cannot_displace_demoted_peers() {
        // The displacement attack the provenance rule exists to stop: an
        // attacker nominating forged names arbitrarily close to the own
        // id must never flush a demoted (once-verified) peer out of the
        // re-verification tier.
        let mut rng = Rng::new(14);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let demoted = peers(5, 15);
        for p in &demoted {
            assert!(rt.quarantine(*p, Nanos(0), true));
        }
        // Fill the rest of the tier with hearsay, then flood far more.
        let flood = peers(3 * QUARANTINE_CAP, 16);
        for p in &flood {
            rt.quarantine(*p, Nanos(0), false);
        }
        assert_eq!(rt.quarantined_len(), QUARANTINE_CAP);
        for p in &demoted {
            assert!(rt.is_quarantined(p), "hearsay flood displaced a demoted peer");
        }
        rt.check_invariants().unwrap();
        // A demoted newcomer, however, always earns a slot over hearsay…
        let late = peers(1, 17)[0];
        assert!(rt.quarantine(late, Nanos(0), true));
        assert!(rt.is_quarantined(&late));
        // …without touching the other demoted entries.
        for p in &demoted {
            assert!(rt.is_quarantined(p));
        }
        assert_eq!(rt.quarantined_len(), QUARANTINE_CAP);
    }

    #[test]
    fn due_for_verify_backs_off_exponentially() {
        let mut rng = Rng::new(13);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let p = PeerId::from_rng(&mut rng);
        rt.quarantine(p, Nanos(0), true);
        let base = Duration::from_secs(4);
        let mut t = Nanos(0);
        // Attempts at +4, +8, +16, +32, then capped at +32 forever.
        for expect in [4u64, 8, 16, 32, 32, 32] {
            assert_eq!(rt.due_for_verify(t, base), vec![p]);
            let next = Nanos(t.0 + expect * 1_000_000_000);
            assert!(rt.due_for_verify(Nanos(next.0 - 1), base).is_empty());
            t = next;
        }
    }

    #[test]
    fn remove_works() {
        let mut rng = Rng::new(7);
        let own = Key(rng.bytes32());
        let mut rt = RoutingTable::new(own);
        let ps = peers(5, 8);
        for p in &ps {
            rt.touch(*p, Nanos(0));
        }
        rt.remove(&ps[2]);
        assert!(!rt.contains(&ps[2]));
        assert_eq!(rt.len(), 4);
    }
}

//! Sans-io Kademlia engine: iterative lookups, provider records, RPC
//! timeout handling.
//!
//! The engine is transport-agnostic: it consumes RPCs and emits
//! `(PeerId, Rpc)` pairs; the owning node wraps them into its wire
//! message. Completed lookups surface as [`DhtEvent`]s drained by the
//! owner after each call.
//!
//! The iterative-lookup state machine itself lives in
//! [`crate::dht::lookup`]; the engine maps request ids to
//! `(lookup, path)` pairs, turns [`lookup::Drive`] verdicts into sends,
//! and owns the two eclipse-hardening defenses configured on
//! [`DhtConfig`]:
//!
//! * **disjoint-path lookups** ([`DhtConfig::lookup_paths`]) — every
//!   lookup fans out over d paths that never share queried peers;
//! * **distance-verified routing updates** ([`DhtConfig::verify_peers`])
//!   — closer-peer candidates must be strictly closer to the target
//!   than the peer reporting them, and hearsay peers are quarantined in
//!   the routing table's `pending_verify` tier (periodically pinged;
//!   admitted only once they answer an RPC themselves). Peers whose RPCs
//!   time out are demoted back into that tier rather than forgotten, so
//!   an eclipse that relies on making honest peers *look* dead has to
//!   keep them unreachable forever — the engine re-verifies and
//!   re-admits them as soon as connectivity returns.
//!
//! Both defenses default off; with `lookup_paths = 1` and
//! `verify_peers = false` the engine is RPC-for-RPC identical to the
//! pre-extraction implementation (property-tested against a legacy
//! reference in `tests/prop.rs`), which is what keeps every recorded
//! scenario replay bit-identical.

use crate::codec::bin::{varint_len, Decode, DecodeError, Encode, Reader, Writer};
use crate::dht::kbucket::{RoutingTable, K};
use crate::dht::key::Key;
use crate::dht::lookup::{self, LookupConfig, LookupKind, LookupState};
use crate::net::{PeerId, WireSize};
use crate::util::time::{Duration, Nanos};
use std::collections::{BTreeMap, HashMap};

/// Kademlia RPC messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Rpc {
    Ping { req_id: u64 },
    Pong { req_id: u64 },
    /// Return the k closest peers to `target` you know.
    FindNode { req_id: u64, target: Key },
    FindNodeReply { req_id: u64, closer: Vec<PeerId> },
    /// Return known providers of `key`, plus closer peers.
    GetProviders { req_id: u64, key: Key },
    GetProvidersReply { req_id: u64, providers: Vec<PeerId>, closer: Vec<PeerId> },
    /// Store a provider record: `provider` serves the object at `key`.
    AddProvider { key: Key, provider: PeerId },
    /// Withdraw the *sender's* provider record for `key` (a deliberate
    /// unpin): the record is keyed by the requesting peer, so nobody can
    /// retract anyone else's announcement. Without withdrawal a record
    /// lingers until its TTL, and availability-repair probes would keep
    /// counting holders that garbage-collected the data long ago.
    RemoveProvider { key: Key },
}

impl Encode for Rpc {
    fn encode(&self, w: &mut Writer) {
        match self {
            Rpc::Ping { req_id } => {
                w.put_u8(0);
                w.put_varint(*req_id);
            }
            Rpc::Pong { req_id } => {
                w.put_u8(1);
                w.put_varint(*req_id);
            }
            Rpc::FindNode { req_id, target } => {
                w.put_u8(2);
                w.put_varint(*req_id);
                target.encode(w);
            }
            Rpc::FindNodeReply { req_id, closer } => {
                w.put_u8(3);
                w.put_varint(*req_id);
                closer.encode(w);
            }
            Rpc::GetProviders { req_id, key } => {
                w.put_u8(4);
                w.put_varint(*req_id);
                key.encode(w);
            }
            Rpc::GetProvidersReply { req_id, providers, closer } => {
                w.put_u8(5);
                w.put_varint(*req_id);
                providers.encode(w);
                closer.encode(w);
            }
            Rpc::AddProvider { key, provider } => {
                w.put_u8(6);
                key.encode(w);
                provider.encode(w);
            }
            Rpc::RemoveProvider { key } => {
                w.put_u8(7);
                key.encode(w);
            }
        }
    }
}

impl Decode for Rpc {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(match r.get_u8()? {
            0 => Rpc::Ping { req_id: r.get_varint()? },
            1 => Rpc::Pong { req_id: r.get_varint()? },
            2 => Rpc::FindNode { req_id: r.get_varint()?, target: Key::decode(r)? },
            3 => Rpc::FindNodeReply { req_id: r.get_varint()?, closer: Vec::decode(r)? },
            4 => Rpc::GetProviders { req_id: r.get_varint()?, key: Key::decode(r)? },
            5 => Rpc::GetProvidersReply {
                req_id: r.get_varint()?,
                providers: Vec::decode(r)?,
                closer: Vec::decode(r)?,
            },
            6 => Rpc::AddProvider { key: Key::decode(r)?, provider: PeerId::decode(r)? },
            7 => Rpc::RemoveProvider { key: Key::decode(r)? },
            _ => return Err(DecodeError("bad dht rpc tag")),
        })
    }
}

impl WireSize for Rpc {
    /// Exact encoded length in O(1): tag + varint req_id, 32-byte keys
    /// and peer ids, varint-prefixed peer lists. Property-tested against
    /// the real encoding in `tests/prop.rs`.
    fn wire_size(&self) -> usize {
        match self {
            Rpc::Ping { req_id } | Rpc::Pong { req_id } => 1 + varint_len(*req_id),
            Rpc::FindNode { req_id, .. } | Rpc::GetProviders { req_id, .. } => {
                1 + varint_len(*req_id) + 32
            }
            Rpc::FindNodeReply { req_id, closer } => {
                1 + varint_len(*req_id) + varint_len(closer.len() as u64) + closer.len() * 32
            }
            Rpc::GetProvidersReply { req_id, providers, closer } => {
                1 + varint_len(*req_id)
                    + varint_len(providers.len() as u64)
                    + providers.len() * 32
                    + varint_len(closer.len() as u64)
                    + closer.len() * 32
            }
            Rpc::AddProvider { .. } => 1 + 32 + 32,
            Rpc::RemoveProvider { .. } => 1 + 32,
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct DhtConfig {
    /// Lookup parallelism (Kademlia α), per lookup path.
    pub alpha: usize,
    /// Result-set size (Kademlia k).
    pub k: usize,
    /// Single RPC timeout.
    pub rpc_timeout: Duration,
    /// Provider-record lifetime.
    pub provider_ttl: Duration,
    /// Stop a provider lookup early after this many providers (0 = full).
    pub providers_needed: usize,
    /// Number of disjoint lookup paths (d). With the default 1 every
    /// lookup is the classic single-path iterative walk; with d > 1 the
    /// candidate frontier is dealt into d independent paths that never
    /// share queried peers, merging results only at termination — a
    /// colluding minority cannot poison every path (eclipse hardening;
    /// see [`crate::dht::lookup`]).
    pub lookup_paths: usize,
    /// Distance-verified routing updates (default off): reject
    /// closer-peer candidates that are not strictly closer to the target
    /// than the replying peer, and never admit hearsay peers into the
    /// routing table until they answer an RPC themselves — first contact
    /// goes to the table's `pending_verify` tier and is verified by a
    /// ping. Timed-out peers are demoted back to that tier (and
    /// periodically re-verified) instead of forgotten.
    pub verify_peers: bool,
    /// Base interval between verification pings for one quarantined
    /// peer; doubles per failed attempt, capped at 8× (only used when
    /// [`DhtConfig::verify_peers`] is on).
    pub verify_retry: Duration,
}

impl Default for DhtConfig {
    fn default() -> Self {
        DhtConfig {
            alpha: 3,
            k: K,
            rpc_timeout: Duration::from_secs(2),
            provider_ttl: Duration::from_secs(60 * 60),
            providers_needed: 3,
            lookup_paths: 1,
            verify_peers: false,
            verify_retry: Duration::from_secs(4),
        }
    }
}

/// Identifier for an in-flight iterative lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LookupId(pub u64);

/// Completion events surfaced to the engine owner.
#[derive(Clone, Debug)]
pub enum DhtEvent {
    /// A FIND_NODE lookup finished with the k closest peers found.
    LookupDone { id: LookupId, target: Key, closest: Vec<PeerId> },
    /// A GET_PROVIDERS lookup finished (providers may be empty).
    ProvidersDone { id: LookupId, key: Key, providers: Vec<PeerId>, closest: Vec<PeerId> },
}

struct PendingRpc {
    /// The lookup and path this request belongs to, if any.
    lookup: Option<(LookupId, usize)>,
    peer: PeerId,
    sent_at: Nanos,
}

/// Provider record with expiry.
struct ProviderRecord {
    expires: Nanos,
}

/// The Kademlia engine. One per node.
///
/// Iterated collections (pending RPCs, provider sets) are ordered maps:
/// timeout sweeps and provider replies must not depend on hash-map
/// iteration order, or two runs of the same seed would diverge.
pub struct Engine {
    own: PeerId,
    pub table: RoutingTable,
    cfg: DhtConfig,
    next_req: u64,
    next_lookup: u64,
    pending: BTreeMap<u64, PendingRpc>,
    lookups: HashMap<LookupId, LookupState>,
    /// key → provider → record
    providers: HashMap<Key, BTreeMap<PeerId, ProviderRecord>>,
    /// Completed-lookup events for the owner to drain.
    pub events: Vec<DhtEvent>,
    /// RPC counters (for experiment metrics).
    pub rpcs_sent: u64,
    pub rpcs_timed_out: u64,
    /// Adversarial wire-layer hook (eclipse-attack scenarios): when set,
    /// every *served* `FindNodeReply`/`GetProvidersReply` lists exactly
    /// these colluding peers instead of the honest routing-table view.
    /// Client-side behaviour (lookups this engine runs) is unchanged —
    /// the attacker lies to others, not to itself.
    forge: Option<Vec<PeerId>>,
    /// Replies whose contents were forged (attack-visibility metric).
    pub replies_forged: u64,
    /// Paths started by disjoint-path lookups (d ≥ 2); zero while the
    /// defense is off, so legacy stats stay untouched.
    pub lookup_paths_started: u64,
    /// Closer-peer candidates rejected by distance verification.
    pub closer_peers_rejected: u64,
    /// Peers that entered the routing table's `pending_verify` tier
    /// (hearsay first contacts plus timed-out demotions).
    pub unverified_peers_quarantined: u64,
}

/// Outgoing RPCs accumulate here; the node wraps them in its wire type.
pub type Sends = Vec<(PeerId, Rpc)>;

impl Engine {
    pub fn new(own: PeerId, cfg: DhtConfig) -> Self {
        Engine {
            own,
            table: RoutingTable::new(Key::from_peer(own)),
            cfg,
            next_req: 1,
            next_lookup: 1,
            pending: BTreeMap::new(),
            lookups: HashMap::new(),
            providers: HashMap::new(),
            events: Vec::new(),
            rpcs_sent: 0,
            rpcs_timed_out: 0,
            forge: None,
            replies_forged: 0,
            lookup_paths_started: 0,
            closer_peers_rejected: 0,
            unverified_peers_quarantined: 0,
        }
    }

    pub fn own_id(&self) -> PeerId {
        self.own
    }

    /// Install (or with `None` clear) the forged colluder set: while set,
    /// every reply this engine serves to a `FindNode`/`GetProviders`
    /// request claims the colluders are the closest peers / providers.
    /// This is the byzantine wire-wrapping hook behind the
    /// `adversarial-eclipse` scenario (`sim::bank`).
    pub fn set_forgery(&mut self, colluders: Option<Vec<PeerId>>) {
        self.forge = colluders;
    }

    /// Whether this engine currently forges its replies.
    pub fn is_forging(&self) -> bool {
        self.forge.is_some()
    }

    /// The forged peer list for a reply to `from`, if forging is active.
    fn forged_peers(&mut self, from: PeerId) -> Option<Vec<PeerId>> {
        let lie: Vec<PeerId> =
            self.forge.as_ref()?.iter().copied().filter(|p| *p != from).collect();
        self.replies_forged += 1;
        Some(lie)
    }

    fn send(
        &mut self,
        to: PeerId,
        rpc: Rpc,
        lookup: Option<(LookupId, usize)>,
        now: Nanos,
        out: &mut Sends,
    ) {
        if let Some(req_id) = match &rpc {
            Rpc::Ping { req_id }
            | Rpc::FindNode { req_id, .. }
            | Rpc::GetProviders { req_id, .. } => Some(*req_id),
            _ => None,
        } {
            self.pending.insert(req_id, PendingRpc { lookup, peer: to, sent_at: now });
        }
        self.rpcs_sent += 1;
        out.push((to, rpc));
    }

    fn fresh_req(&mut self) -> u64 {
        let id = self.next_req;
        self.next_req += 1;
        id
    }

    /// Whether `rpc` answers a request we sent to `from` (still pending).
    /// Under [`DhtConfig::verify_peers`] this is the one way a peer
    /// proves itself: it answered an RPC of ours.
    fn is_pending_reply(&self, from: PeerId, rpc: &Rpc) -> bool {
        let req_id = match rpc {
            Rpc::Pong { req_id }
            | Rpc::FindNodeReply { req_id, .. }
            | Rpc::GetProvidersReply { req_id, .. } => *req_id,
            _ => return false,
        };
        self.pending.get(&req_id).is_some_and(|p| p.peer == from)
    }

    /// Quarantine `peer` in the routing table's `pending_verify` tier
    /// (no-op if it is already tabled or quarantined), counting first
    /// admissions. `demoted` marks a once-tabled peer evicted on
    /// timeout — the tier never lets hearsay displace those.
    fn quarantine(&mut self, peer: PeerId, not_before: Nanos, demoted: bool) {
        if self.table.quarantine(peer, not_before, demoted) {
            self.unverified_peers_quarantined += 1;
        }
    }

    // ----- server side -----------------------------------------------------

    /// Handle an inbound RPC; may emit replies and lookup progress.
    pub fn on_rpc(&mut self, now: Nanos, from: PeerId, rpc: Rpc, out: &mut Sends) {
        if !self.cfg.verify_peers {
            self.table.touch(from, now);
        } else if self.table.contains(&from) || self.is_pending_reply(from, &rpc) {
            // Already verified, or proving itself right now by answering
            // one of our RPCs: (re)admit and refresh.
            self.table.touch(from, now);
        } else {
            // First contact from an unverified peer: serve it, but keep
            // it out of the routing table until it answers a ping.
            self.quarantine(from, now, false);
        }
        match rpc {
            Rpc::Ping { req_id } => {
                out.push((from, Rpc::Pong { req_id }));
            }
            Rpc::Pong { req_id } => {
                // Sender-checked: only the peer we pinged can settle the
                // request — a third party echoing a guessed req_id must
                // not burn the pending entry (it would silently cancel a
                // verification ping and strand the real peer).
                if self.pending.get(&req_id).is_some_and(|p| p.peer == from) {
                    self.pending.remove(&req_id);
                }
            }
            Rpc::FindNode { req_id, target } => {
                let closer = match self.forged_peers(from) {
                    Some(lie) => lie,
                    None => {
                        let mut closer = self.table.closest(&target, self.cfg.k);
                        closer.retain(|p| *p != from);
                        closer
                    }
                };
                out.push((from, Rpc::FindNodeReply { req_id, closer }));
            }
            Rpc::GetProviders { req_id, key } => {
                self.expire_providers(now, &key);
                let (providers, closer) = match self.forged_peers(from) {
                    Some(lie) => (lie.clone(), lie),
                    None => {
                        let providers: Vec<PeerId> = self
                            .providers
                            .get(&key)
                            .map(|m| m.keys().copied().collect())
                            .unwrap_or_default();
                        let mut closer = self.table.closest(&key, self.cfg.k);
                        closer.retain(|p| *p != from);
                        (providers, closer)
                    }
                };
                out.push((from, Rpc::GetProvidersReply { req_id, providers, closer }));
            }
            Rpc::AddProvider { key, provider } => {
                self.add_provider_record(now, key, provider);
            }
            Rpc::RemoveProvider { key } => {
                // Sender-keyed: `from` can only ever retract itself.
                self.remove_provider_record(&key, from);
            }
            Rpc::FindNodeReply { req_id, closer } => {
                self.on_reply(now, from, req_id, Vec::new(), closer, out);
            }
            Rpc::GetProvidersReply { req_id, providers, closer } => {
                self.on_reply(now, from, req_id, providers, closer, out);
            }
        }
    }

    fn add_provider_record(&mut self, now: Nanos, key: Key, provider: PeerId) {
        self.providers
            .entry(key)
            .or_default()
            .insert(provider, ProviderRecord { expires: now + self.cfg.provider_ttl });
    }

    fn remove_provider_record(&mut self, key: &Key, provider: PeerId) {
        if let Some(m) = self.providers.get_mut(key) {
            m.remove(&provider);
            if m.is_empty() {
                self.providers.remove(key);
            }
        }
    }

    fn expire_providers(&mut self, now: Nanos, key: &Key) {
        if let Some(m) = self.providers.get_mut(key) {
            m.retain(|_, r| r.expires > now);
            if m.is_empty() {
                self.providers.remove(key);
            }
        }
    }

    /// Providers currently recorded locally for `key`.
    pub fn local_providers(&self, key: &Key) -> Vec<PeerId> {
        self.providers
            .get(key)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }

    // ----- client side ------------------------------------------------------

    /// Seed the routing table (bootstrap peers learned out of band).
    /// Seeds are trusted first contacts: they bypass `pending_verify`.
    pub fn add_seed(&mut self, now: Nanos, peer: PeerId) {
        self.table.touch(peer, now);
    }

    /// Record a peer learned by hearsay from a message body (e.g. a
    /// join-handshake sample list): admitted directly when verification
    /// is off — identical to [`Engine::add_seed`] — but quarantined for
    /// a verification ping under [`DhtConfig::verify_peers`], so a
    /// single crafted message can never stuff the routing table.
    pub fn add_hearsay(&mut self, now: Nanos, peer: PeerId) {
        if peer == self.own {
            return;
        }
        if self.cfg.verify_peers {
            self.quarantine(peer, now, false);
        } else {
            self.table.touch(peer, now);
        }
    }

    /// Start an iterative FIND_NODE lookup toward `target`.
    pub fn find_node(&mut self, now: Nanos, target: Key, out: &mut Sends) -> LookupId {
        self.start_lookup(now, target, LookupKind::FindNode, false, out)
    }

    /// Start an iterative GET_PROVIDERS lookup for `key`. Stops early
    /// once `providers_needed` providers are known — the fetch-oriented
    /// flavor ("enough candidates to start pulling blocks").
    pub fn find_providers(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.start_lookup(now, key, LookupKind::GetProviders, false, out)
    }

    /// Start an exhaustive GET_PROVIDERS lookup for `key`: never stops
    /// early at `providers_needed`, so the result reflects every record
    /// held by the k closest reachable peers. This is the provider-
    /// *count* probe behind availability repair — an early-exit count
    /// would saturate at `providers_needed` and under-report exactly
    /// when the repair decision needs precision.
    pub fn find_providers_full(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.start_lookup(now, key, LookupKind::GetProviders, true, out)
    }

    /// Announce ourselves as a provider: records locally and walks the
    /// DHT to store the record on the k closest peers to `key`.
    pub fn provide(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.add_provider_record(now, key, self.own);
        // The completion handler sends AddProvider to the found peers.
        self.start_lookup(now, key, LookupKind::FindNode, false, out)
    }

    /// Withdraw our own provider record for `key` (deliberate unpin):
    /// drops the local record immediately and walks the DHT so the
    /// completion handler can send [`Rpc::RemoveProvider`] to the k
    /// closest peers (via [`Engine::announce_withdrawal`], the mirror of
    /// [`Engine::announce_provider`]).
    pub fn withdraw(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.remove_provider_record(&key, self.own);
        self.start_lookup(now, key, LookupKind::FindNode, false, out)
    }

    fn start_lookup(
        &mut self,
        now: Nanos,
        target: Key,
        kind: LookupKind,
        full: bool,
        out: &mut Sends,
    ) -> LookupId {
        let id = LookupId(self.next_lookup);
        self.next_lookup += 1;
        let paths = self.cfg.lookup_paths.max(1);
        if paths > 1 {
            self.lookup_paths_started += paths as u64;
        }
        let cfg = LookupConfig {
            alpha: self.cfg.alpha,
            k: self.cfg.k,
            providers_needed: self.cfg.providers_needed,
            paths,
            verify_distance: self.cfg.verify_peers,
        };
        let seeds = self.table.closest(&target, self.cfg.k);
        let lk = LookupState::new(self.own, kind, target, full, cfg, seeds);
        self.lookups.insert(id, lk);
        for path in 0..paths {
            self.drive_path(now, id, path, out);
        }
        id
    }

    fn on_reply(
        &mut self,
        now: Nanos,
        from: PeerId,
        req_id: u64,
        providers: Vec<PeerId>,
        closer: Vec<PeerId>,
        out: &mut Sends,
    ) {
        // Sender-checked consumption: a reply settles a pending request
        // only when it comes from the peer the request went to; a late
        // reply to an expired RPC, or a spoofed req_id from a third
        // party, is ignored without touching the entry.
        match self.pending.get(&req_id) {
            Some(p) if p.peer == from => {}
            _ => return,
        }
        let pending = self.pending.remove(&req_id).expect("checked above");
        // Under verification, only hearsay that passes the same
        // strictly-closer rule the shortlist applies
        // ([`lookup::strictly_closer`] — one authoritative predicate)
        // earns a quarantine slot and a verification ping; forged
        // lateral names cost the attacker a rejection counter, nothing
        // more. When the reply's lookup is already gone (a late reply
        // inside the timeout window) there is no target to judge
        // against, so no hearsay is quarantined at all.
        let target = pending
            .lookup
            .and_then(|(lid, _)| self.lookups.get(&lid))
            .map(|lk| lk.target());
        for p in &closer {
            if *p == self.own {
                continue;
            }
            if self.cfg.verify_peers {
                if target.is_some_and(|t| lookup::strictly_closer(&t, from, *p)) {
                    // Hearsay: quarantine until the peer answers an RPC
                    // itself (a no-op for already-verified peers).
                    self.quarantine(*p, now, false);
                }
            } else {
                self.table.touch(*p, now);
            }
        }
        let Some((lookup_id, path)) = pending.lookup else { return };
        let Some(lk) = self.lookups.get_mut(&lookup_id) else { return };
        self.closer_peers_rejected += lk.on_reply(path, from, providers, &closer);
        self.drive_path(now, lookup_id, path, out);
    }

    /// Turn one path's [`lookup::Drive`] verdict into sends or the
    /// completion event.
    fn drive_path(&mut self, now: Nanos, id: LookupId, path: usize, out: &mut Sends) {
        let Some(lk) = self.lookups.get_mut(&id) else { return };
        let (kind, target) = (lk.kind(), lk.target());
        match lk.drive(path) {
            lookup::Drive::Wait => {}
            lookup::Drive::Query(peers) => {
                for peer in peers {
                    let req_id = self.fresh_req();
                    let rpc = match kind {
                        LookupKind::FindNode => Rpc::FindNode { req_id, target },
                        LookupKind::GetProviders => Rpc::GetProviders { req_id, key: target },
                    };
                    self.send(peer, rpc, Some((id, path)), now, out);
                }
            }
            lookup::Drive::Done => {
                let lk = self.lookups.remove(&id).expect("lookup exists");
                let (closest, providers) = lk.result();
                let ev = match kind {
                    LookupKind::FindNode => DhtEvent::LookupDone { id, target, closest },
                    LookupKind::GetProviders => {
                        DhtEvent::ProvidersDone { id, key: target, providers, closest }
                    }
                };
                self.events.push(ev);
            }
        }
    }

    /// Expire timed-out RPCs; called from a periodic tick. Under
    /// [`DhtConfig::verify_peers`] this also sends verification pings to
    /// quarantined peers that are due a (re-)verification attempt.
    pub fn tick(&mut self, now: Nanos, out: &mut Sends) {
        let timeout = self.cfg.rpc_timeout;
        let expired: Vec<u64> = self
            .pending
            .iter()
            .filter(|(_, p)| now.saturating_sub(p.sent_at) >= timeout)
            .map(|(id, _)| *id)
            .collect();
        for req_id in expired {
            let p = self.pending.remove(&req_id).unwrap();
            self.rpcs_timed_out += 1;
            // Demoted provenance is earned by actually having been in
            // the table: a queried-but-never-tabled name (e.g. accepted
            // hearsay that never answered) re-enters quarantine as plain
            // hearsay, so forged ids can never buy the protected tier.
            let was_tabled = self.table.contains(&p.peer);
            self.table.remove(&p.peer); // unresponsive peer
            if self.cfg.verify_peers {
                // Demote, don't forget: the peer may be a victim of the
                // network rather than dead. It re-enters the table the
                // moment it answers a verification ping.
                self.quarantine(p.peer, now + self.cfg.verify_retry, was_tabled);
            }
            if let Some((lid, path)) = p.lookup {
                if let Some(lk) = self.lookups.get_mut(&lid) {
                    lk.on_timeout(path);
                    // peer stays marked queried → we move on
                    self.drive_path(now, lid, path, out);
                }
            }
        }
        if self.cfg.verify_peers {
            for peer in self.table.due_for_verify(now, self.cfg.verify_retry) {
                let req_id = self.fresh_req();
                self.send(peer, Rpc::Ping { req_id }, None, now, out);
            }
        }
    }

    /// After a `provide` lookup completes, push AddProvider records to
    /// the closest peers (call with the `LookupDone` closest set).
    pub fn announce_provider(&mut self, key: Key, closest: &[PeerId], out: &mut Sends) {
        for p in closest.iter().take(self.cfg.k) {
            self.rpcs_sent += 1;
            out.push((*p, Rpc::AddProvider { key, provider: self.own }));
        }
    }

    /// After a [`Engine::withdraw`] lookup completes, ask the closest
    /// peers to drop our provider record for `key` (call with the
    /// `LookupDone` closest set).
    pub fn announce_withdrawal(&mut self, key: Key, closest: &[PeerId], out: &mut Sends) {
        for p in closest.iter().take(self.cfg.k) {
            self.rpcs_sent += 1;
            out.push((*p, Rpc::RemoveProvider { key }));
        }
    }

    /// Number of active lookups (diagnostics).
    pub fn active_lookups(&self) -> usize {
        self.lookups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Drive a set of engines to quiescence by synchronously routing RPCs.
    fn settle(
        engines: &mut HashMap<PeerId, Engine>,
        mut queue: Vec<(PeerId, PeerId, Rpc)>,
        now: Nanos,
    ) {
        let mut hops = 0;
        while let Some((from, to, rpc)) = queue.pop() {
            hops += 1;
            assert!(hops < 1_000_000, "rpc storm");
            let mut out = Sends::new();
            if let Some(e) = engines.get_mut(&to) {
                e.on_rpc(now, from, rpc, &mut out);
            }
            for (next_to, next_rpc) in out {
                queue.push((to, next_to, next_rpc));
            }
        }
    }

    fn mk_engines(n: usize, seed: u64) -> (Vec<PeerId>, HashMap<PeerId, Engine>) {
        let mut rng = Rng::new(seed);
        let ids: Vec<PeerId> = (0..n).map(|_| PeerId::from_rng(&mut rng)).collect();
        let engines: HashMap<PeerId, Engine> = ids
            .iter()
            .map(|id| (*id, Engine::new(*id, DhtConfig::default())))
            .collect();
        (ids, engines)
    }

    /// Fully-meshed routing tables for small-n tests.
    fn mesh(ids: &[PeerId], engines: &mut HashMap<PeerId, Engine>, now: Nanos) {
        for a in ids {
            for b in ids {
                if a != b {
                    engines.get_mut(a).unwrap().add_seed(now, *b);
                }
            }
        }
    }

    #[test]
    fn rpc_roundtrip_encoding() {
        let mut rng = Rng::new(1);
        let rpcs = vec![
            Rpc::Ping { req_id: 7 },
            Rpc::FindNode { req_id: 9, target: Key(rng.bytes32()) },
            Rpc::GetProvidersReply {
                req_id: 11,
                providers: vec![PeerId::from_rng(&mut rng)],
                closer: vec![PeerId::from_rng(&mut rng), PeerId::from_rng(&mut rng)],
            },
            Rpc::AddProvider { key: Key(rng.bytes32()), provider: PeerId::from_rng(&mut rng) },
            Rpc::RemoveProvider { key: Key(rng.bytes32()) },
        ];
        for rpc in rpcs {
            let b = crate::codec::to_bytes(&rpc);
            assert_eq!(crate::codec::from_bytes::<Rpc>(&b).unwrap(), rpc);
        }
    }

    #[test]
    fn find_node_converges_to_global_closest() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(50, 42);
        // Star topology: everyone knows the root, the root knows everyone
        // (the paper's bootstrap shape). Lookups must iterate through the
        // root to reach the true closest peers.
        let root = ids[1];
        for a in ids.iter().skip(2) {
            engines.get_mut(a).unwrap().add_seed(now, root);
            engines.get_mut(&root).unwrap().add_seed(now, *a);
        }
        engines.get_mut(&ids[0]).unwrap().add_seed(now, root);
        engines.get_mut(&root).unwrap().add_seed(now, ids[0]);
        let mut rng = Rng::new(99);
        let target = Key(rng.bytes32());
        let origin = ids[0];
        let mut out = Sends::new();
        let lid = engines.get_mut(&origin).unwrap().find_node(now, target, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (origin, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&origin).unwrap().events.pop().expect("lookup done");
        let DhtEvent::LookupDone { id, closest, .. } = ev else {
            panic!("wrong event");
        };
        assert_eq!(id, lid);
        // The found closest must equal the brute-force k closest among the
        // peers reachable through the root (its table may have evicted a
        // few under k-bucket pressure — that is correct Kademlia behaviour).
        let mut universe = engines.get(&root).unwrap().table.peers();
        universe.push(root);
        universe.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let top: Vec<PeerId> = universe.into_iter().filter(|p| *p != origin).take(5).collect();
        assert_eq!(&closest[..5], &top[..]);
    }

    #[test]
    fn multipath_find_node_converges_with_disjoint_paths() {
        // The same star-topology convergence claim, under 3-path
        // disjoint lookups: the merged result must still be the true
        // closest set even though no peer is queried by two paths.
        let now = Nanos(0);
        let mut rng = Rng::new(41);
        let ids: Vec<PeerId> = (0..30).map(|_| PeerId::from_rng(&mut rng)).collect();
        let cfg = DhtConfig { lookup_paths: 3, ..DhtConfig::default() };
        let mut engines: HashMap<PeerId, Engine> =
            ids.iter().map(|id| (*id, Engine::new(*id, cfg.clone()))).collect();
        let root = ids[1];
        for a in ids.iter().skip(2) {
            engines.get_mut(a).unwrap().add_seed(now, root);
            engines.get_mut(&root).unwrap().add_seed(now, *a);
        }
        engines.get_mut(&ids[0]).unwrap().add_seed(now, root);
        engines.get_mut(&root).unwrap().add_seed(now, ids[0]);
        let target = Key(rng.bytes32());
        let origin = ids[0];
        let mut out = Sends::new();
        engines.get_mut(&origin).unwrap().find_node(now, target, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (origin, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let e = engines.get_mut(&origin).unwrap();
        assert_eq!(e.lookup_paths_started, 3);
        let ev = e.events.pop().expect("lookup done");
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!("wrong event") };
        let mut universe = engines.get(&root).unwrap().table.peers();
        universe.push(root);
        universe.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let top: Vec<PeerId> = universe.into_iter().filter(|p| *p != origin).take(5).collect();
        assert_eq!(&closest[..5], &top[..]);
    }

    #[test]
    fn provider_records_roundtrip() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(20, 7);
        mesh(&ids, &mut engines, now);
        let mut rng = Rng::new(5);
        let key = Key(rng.bytes32());
        let provider = ids[3];

        // Provider announces.
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().provide(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&provider).unwrap().events.pop().unwrap();
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!() };
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().announce_provider(key, &closest, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(&mut engines, queue, now);

        // Another peer finds the provider.
        let seeker = ids[10];
        let mut out = Sends::new();
        engines.get_mut(&seeker).unwrap().find_providers(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (seeker, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&seeker).unwrap().events.pop().expect("providers done");
        let DhtEvent::ProvidersDone { providers, .. } = ev else { panic!() };
        assert!(providers.contains(&provider), "provider not found");
    }

    /// Announce `provider` for `key` across the mesh (provide lookup +
    /// AddProvider fan-out), settling all traffic.
    fn announce(engines: &mut HashMap<PeerId, Engine>, provider: PeerId, key: Key, now: Nanos) {
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().provide(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(engines, queue, now);
        let ev = engines.get_mut(&provider).unwrap().events.pop().unwrap();
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!() };
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().announce_provider(key, &closest, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(engines, queue, now);
    }

    #[test]
    fn full_provider_lookup_ignores_early_exit() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(20, 77);
        // Fetch-oriented lookups may stop after a single provider…
        for e in engines.values_mut() {
            e.cfg.providers_needed = 1;
        }
        mesh(&ids, &mut engines, now);
        let mut rng = Rng::new(6);
        let key = Key(rng.bytes32());
        for &p in &[ids[2], ids[7], ids[11]] {
            announce(&mut engines, p, key, now);
        }
        let seeker = ids[15];
        let mut out = Sends::new();
        engines.get_mut(&seeker).unwrap().find_providers_full(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (seeker, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&seeker).unwrap().events.pop().expect("providers done");
        let DhtEvent::ProvidersDone { providers, .. } = ev else { panic!() };
        // …but the exhaustive count probe must see all three records.
        for p in [ids[2], ids[7], ids[11]] {
            assert!(providers.contains(&p), "full lookup missed a provider");
        }
    }

    #[test]
    fn withdrawal_removes_only_the_senders_record() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(12, 23);
        mesh(&ids, &mut engines, now);
        let mut rng = Rng::new(4);
        let key = Key(rng.bytes32());
        let (keeper, leaver) = (ids[3], ids[5]);
        announce(&mut engines, keeper, key, now);
        announce(&mut engines, leaver, key, now);
        // `leaver` withdraws: walk the DHT, then fan out RemoveProvider.
        let mut out = Sends::new();
        engines.get_mut(&leaver).unwrap().withdraw(now, key, &mut out);
        assert!(engines.get(&leaver).unwrap().local_providers(&key).is_empty());
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (leaver, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&leaver).unwrap().events.pop().unwrap();
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!() };
        let mut out = Sends::new();
        engines.get_mut(&leaver).unwrap().announce_withdrawal(key, &closest, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (leaver, to, rpc)).collect();
        settle(&mut engines, queue, now);
        // A fresh exhaustive lookup sees the keeper, not the leaver.
        let seeker = ids[9];
        let mut out = Sends::new();
        engines.get_mut(&seeker).unwrap().find_providers_full(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (seeker, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&seeker).unwrap().events.pop().expect("providers done");
        let DhtEvent::ProvidersDone { providers, .. } = ev else { panic!() };
        assert!(providers.contains(&keeper), "withdrawal must not touch other records");
        assert!(!providers.contains(&leaver), "withdrawn record still served");
    }

    #[test]
    fn remove_provider_is_sender_keyed() {
        let mut rng = Rng::new(19);
        let own = PeerId::from_rng(&mut rng);
        let (a, b) = (PeerId::from_rng(&mut rng), PeerId::from_rng(&mut rng));
        let mut e = Engine::new(own, DhtConfig::default());
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        e.on_rpc(Nanos(0), a, Rpc::AddProvider { key, provider: a }, &mut out);
        e.on_rpc(Nanos(0), b, Rpc::AddProvider { key, provider: b }, &mut out);
        // b tries to scrub the key: only b's own record can go.
        e.on_rpc(Nanos(1), b, Rpc::RemoveProvider { key }, &mut out);
        assert_eq!(e.local_providers(&key), vec![a]);
        e.on_rpc(Nanos(2), a, Rpc::RemoveProvider { key }, &mut out);
        assert!(e.local_providers(&key).is_empty());
    }

    #[test]
    fn provider_records_expire() {
        let mut rng = Rng::new(8);
        let own = PeerId::from_rng(&mut rng);
        let other = PeerId::from_rng(&mut rng);
        let cfg = DhtConfig { provider_ttl: Duration::from_secs(10), ..Default::default() };
        let mut e = Engine::new(own, cfg);
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        e.on_rpc(Nanos(0), other, Rpc::AddProvider { key, provider: other }, &mut out);
        assert_eq!(e.local_providers(&key), vec![other]);
        // After expiry, a GetProviders finds nothing.
        let t = Nanos(11_000_000_000);
        e.on_rpc(t, other, Rpc::GetProviders { req_id: 1, key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::GetProvidersReply { providers, .. } = reply else { panic!() };
        assert!(providers.is_empty());
    }

    #[test]
    fn forged_replies_substitute_peer_lists() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(6, 31);
        mesh(&ids, &mut engines, now);
        let attacker = ids[0];
        let colluders = vec![ids[1], ids[2]];
        engines.get_mut(&attacker).unwrap().set_forgery(Some(colluders.clone()));
        let seeker = ids[5];
        let mut rng = Rng::new(9);
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        engines
            .get_mut(&attacker)
            .unwrap()
            .on_rpc(now, seeker, Rpc::GetProviders { req_id: 1, key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::GetProvidersReply { providers, closer, .. } = reply else { panic!() };
        assert_eq!(providers, colluders, "forged providers");
        assert_eq!(closer, colluders, "forged closer set");
        // FindNode is forged too; a requesting colluder is filtered out.
        let mut out = Sends::new();
        engines
            .get_mut(&attacker)
            .unwrap()
            .on_rpc(now, ids[1], Rpc::FindNode { req_id: 2, target: key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::FindNodeReply { closer, .. } = reply else { panic!() };
        assert_eq!(closer, vec![ids[2]]);
        let e = engines.get_mut(&attacker).unwrap();
        assert_eq!(e.replies_forged, 2);
        // Clearing the forgery restores honest replies.
        e.set_forgery(None);
        assert!(!e.is_forging());
        let mut out = Sends::new();
        e.on_rpc(now, seeker, Rpc::FindNode { req_id: 3, target: key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::FindNodeReply { closer, .. } = reply else { panic!() };
        assert!(closer.len() > 2, "honest reply must reflect the real table");
        assert_eq!(engines.get(&attacker).unwrap().replies_forged, 2);
    }

    #[test]
    fn timeout_expires_pending_and_continues() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(5, 3);
        mesh(&ids, &mut engines, now);
        let origin = ids[0];
        let mut rng = Rng::new(12);
        let target = Key(rng.bytes32());
        let mut out = Sends::new();
        engines.get_mut(&origin).unwrap().find_node(now, target, &mut out);
        assert!(!out.is_empty());
        // Drop all outgoing RPCs (peers never reply), then tick past the
        // timeout: the lookup must still complete (with no external info).
        let later = Nanos(3_000_000_000);
        let mut out2 = Sends::new();
        // Several rounds: each timeout round may re-query more candidates.
        for i in 0..10 {
            let t = Nanos(later.0 + i * 3_000_000_000);
            engines.get_mut(&origin).unwrap().tick(t, &mut out2);
        }
        let e = engines.get_mut(&origin).unwrap();
        assert!(e.rpcs_timed_out > 0);
        assert!(
            e.events.iter().any(|ev| matches!(ev, DhtEvent::LookupDone { .. })),
            "lookup did not terminate after timeouts"
        );
    }

    #[test]
    fn ping_pong_clears_pending() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(2, 21);
        let (a, b) = (ids[0], ids[1]);
        let mut out = Sends::new();
        let req_id = {
            let e = engines.get_mut(&a).unwrap();
            let id = e.fresh_req();
            e.send(b, Rpc::Ping { req_id: id }, None, now, &mut out);
            id
        };
        let (_, ping) = out.pop().unwrap();
        let mut out2 = Sends::new();
        engines.get_mut(&b).unwrap().on_rpc(now, a, ping, &mut out2);
        let (_, pong) = out2.pop().unwrap();
        assert_eq!(pong, Rpc::Pong { req_id });
        let mut out3 = Sends::new();
        engines.get_mut(&a).unwrap().on_rpc(now, b, pong, &mut out3);
        assert!(engines.get_mut(&a).unwrap().pending.is_empty());
    }

    fn verify_cfg() -> DhtConfig {
        DhtConfig { verify_peers: true, ..DhtConfig::default() }
    }

    #[test]
    fn hearsay_is_quarantined_until_it_answers() {
        // An unverified stranger's *request* must not place it in the
        // routing table; answering our verification ping must.
        let mut rng = Rng::new(51);
        let own = PeerId::from_rng(&mut rng);
        let stranger = PeerId::from_rng(&mut rng);
        let mut e = Engine::new(own, verify_cfg());
        let mut out = Sends::new();
        let key = Key(rng.bytes32());
        e.on_rpc(Nanos(0), stranger, Rpc::GetProviders { req_id: 1, key }, &mut out);
        assert!(!out.is_empty(), "the request is still served");
        assert!(!e.table.contains(&stranger), "stranger admitted without verification");
        assert!(e.table.is_quarantined(&stranger));
        assert_eq!(e.unverified_peers_quarantined, 1);
        // The tick emits a verification ping…
        let mut out = Sends::new();
        e.tick(Nanos(1), &mut out);
        let Some((to, Rpc::Ping { req_id })) = out.pop() else {
            panic!("expected a verification ping")
        };
        assert_eq!(to, stranger);
        // …and the pong admits the peer.
        let mut out = Sends::new();
        e.on_rpc(Nanos(2), stranger, Rpc::Pong { req_id }, &mut out);
        assert!(e.table.contains(&stranger));
        assert!(!e.table.is_quarantined(&stranger));
    }

    #[test]
    fn timeout_demotes_to_quarantine_and_reverifies() {
        // The recovery mechanism behind `bank::defended_eclipse`: a peer
        // evicted on timeout is demoted to pending_verify, re-pinged, and
        // re-admitted the moment connectivity returns.
        let mut rng = Rng::new(52);
        let own = PeerId::from_rng(&mut rng);
        let peer = PeerId::from_rng(&mut rng);
        let mut e = Engine::new(own, verify_cfg());
        e.add_seed(Nanos(0), peer);
        assert!(e.table.contains(&peer));
        let target = Key(rng.bytes32());
        let mut out = Sends::new();
        e.find_node(Nanos(0), target, &mut out);
        assert_eq!(out.len(), 1, "one candidate to query");
        // The peer never answers: past the timeout it leaves the table
        // but lands in quarantine instead of being forgotten.
        let mut out = Sends::new();
        e.tick(Nanos(2_000_000_000), &mut out);
        assert!(!e.table.contains(&peer));
        assert!(e.table.is_quarantined(&peer));
        assert_eq!(e.unverified_peers_quarantined, 1);
        // After the retry interval a verification ping goes out; the
        // answer restores the peer into the table.
        let mut out = Sends::new();
        e.tick(Nanos(8_000_000_000), &mut out);
        let ping = out.iter().find_map(|(to, rpc)| match rpc {
            Rpc::Ping { req_id } if *to == peer => Some(*req_id),
            _ => None,
        });
        let req_id = ping.expect("re-verification ping");
        let mut out = Sends::new();
        e.on_rpc(Nanos(8_100_000_000), peer, Rpc::Pong { req_id }, &mut out);
        assert!(e.table.contains(&peer), "verified peer re-admitted");
        assert!(!e.table.is_quarantined(&peer));
    }

    #[test]
    fn spoofed_reply_cannot_burn_a_pending_request() {
        // A third party echoing a guessed req_id must not consume the
        // pending entry — otherwise an attacker could cancel every
        // verification ping and keep honest peers quarantined forever.
        let mut rng = Rng::new(54);
        let own = PeerId::from_rng(&mut rng);
        let (b, c) = (PeerId::from_rng(&mut rng), PeerId::from_rng(&mut rng));
        let mut e = Engine::new(own, verify_cfg());
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        e.on_rpc(Nanos(0), b, Rpc::GetProviders { req_id: 9, key }, &mut out);
        assert!(e.table.is_quarantined(&b));
        let mut out = Sends::new();
        e.tick(Nanos(1), &mut out);
        let Some((to, Rpc::Ping { req_id })) = out.pop() else {
            panic!("verification ping expected")
        };
        assert_eq!(to, b);
        // The attacker races the pong under b's req_id.
        let mut out = Sends::new();
        e.on_rpc(Nanos(2), c, Rpc::Pong { req_id }, &mut out);
        assert!(!e.table.contains(&b), "b must not be admitted by someone else's pong");
        assert!(!e.table.contains(&c), "the spoofer earns nothing");
        // The pending entry survived, so b's genuine answer still lands.
        let mut out = Sends::new();
        e.on_rpc(Nanos(3), b, Rpc::Pong { req_id }, &mut out);
        assert!(e.table.contains(&b), "the real peer is verified");
        assert!(e.pending.is_empty(), "the genuine pong settles the request");
    }

    #[test]
    fn distance_verification_rejects_and_skips_lateral_hearsay() {
        let mut rng = Rng::new(53);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        // Rank a pool by distance to the target to pick the roles.
        let mut pool: Vec<PeerId> = (0..9).map(|_| PeerId::from_rng(&mut rng)).collect();
        pool.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let (closer, replier, farther) = (pool[0], pool[4], pool[8]);
        let mut e = Engine::new(own, verify_cfg());
        e.add_seed(Nanos(0), replier);
        let mut out = Sends::new();
        e.find_node(Nanos(0), target, &mut out);
        let Some((to, Rpc::FindNode { req_id, .. })) = out.pop() else { panic!() };
        assert_eq!(to, replier);
        let reply = Rpc::FindNodeReply { req_id, closer: vec![farther, closer] };
        let mut out = Sends::new();
        e.on_rpc(Nanos(1), replier, reply, &mut out);
        assert_eq!(e.closer_peers_rejected, 1, "the lateral candidate is rejected");
        // Only the strictly-closer candidate is chased…
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, closer);
        // …and neither hearsay peer entered the table. The surviving
        // candidate waits in quarantine; the rejected lateral one does
        // not even earn a verification ping.
        assert!(!e.table.contains(&farther) && !e.table.contains(&closer));
        assert!(e.table.is_quarantined(&closer));
        assert!(!e.table.is_quarantined(&farther), "lateral hearsay must not draw pings");
        // The replier answered our RPC, so it *is* (re)admitted.
        assert!(e.table.contains(&replier));
    }
}

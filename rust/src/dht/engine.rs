//! Sans-io Kademlia engine: iterative lookups, provider records, RPC
//! timeout handling.
//!
//! The engine is transport-agnostic: it consumes RPCs and emits
//! `(PeerId, Rpc)` pairs; the owning node wraps them into its wire
//! message. Completed lookups surface as [`DhtEvent`]s drained by the
//! owner after each call.

use crate::codec::bin::{varint_len, Decode, DecodeError, Encode, Reader, Writer};
use crate::dht::kbucket::{RoutingTable, K};
use crate::dht::key::Key;
use crate::net::{PeerId, WireSize};
use crate::util::time::{Duration, Nanos};
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Kademlia RPC messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Rpc {
    Ping { req_id: u64 },
    Pong { req_id: u64 },
    /// Return the k closest peers to `target` you know.
    FindNode { req_id: u64, target: Key },
    FindNodeReply { req_id: u64, closer: Vec<PeerId> },
    /// Return known providers of `key`, plus closer peers.
    GetProviders { req_id: u64, key: Key },
    GetProvidersReply { req_id: u64, providers: Vec<PeerId>, closer: Vec<PeerId> },
    /// Store a provider record: `provider` serves the object at `key`.
    AddProvider { key: Key, provider: PeerId },
    /// Withdraw the *sender's* provider record for `key` (a deliberate
    /// unpin): the record is keyed by the requesting peer, so nobody can
    /// retract anyone else's announcement. Without withdrawal a record
    /// lingers until its TTL, and availability-repair probes would keep
    /// counting holders that garbage-collected the data long ago.
    RemoveProvider { key: Key },
}

impl Encode for Rpc {
    fn encode(&self, w: &mut Writer) {
        match self {
            Rpc::Ping { req_id } => {
                w.put_u8(0);
                w.put_varint(*req_id);
            }
            Rpc::Pong { req_id } => {
                w.put_u8(1);
                w.put_varint(*req_id);
            }
            Rpc::FindNode { req_id, target } => {
                w.put_u8(2);
                w.put_varint(*req_id);
                target.encode(w);
            }
            Rpc::FindNodeReply { req_id, closer } => {
                w.put_u8(3);
                w.put_varint(*req_id);
                closer.encode(w);
            }
            Rpc::GetProviders { req_id, key } => {
                w.put_u8(4);
                w.put_varint(*req_id);
                key.encode(w);
            }
            Rpc::GetProvidersReply { req_id, providers, closer } => {
                w.put_u8(5);
                w.put_varint(*req_id);
                providers.encode(w);
                closer.encode(w);
            }
            Rpc::AddProvider { key, provider } => {
                w.put_u8(6);
                key.encode(w);
                provider.encode(w);
            }
            Rpc::RemoveProvider { key } => {
                w.put_u8(7);
                key.encode(w);
            }
        }
    }
}

impl Decode for Rpc {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(match r.get_u8()? {
            0 => Rpc::Ping { req_id: r.get_varint()? },
            1 => Rpc::Pong { req_id: r.get_varint()? },
            2 => Rpc::FindNode { req_id: r.get_varint()?, target: Key::decode(r)? },
            3 => Rpc::FindNodeReply { req_id: r.get_varint()?, closer: Vec::decode(r)? },
            4 => Rpc::GetProviders { req_id: r.get_varint()?, key: Key::decode(r)? },
            5 => Rpc::GetProvidersReply {
                req_id: r.get_varint()?,
                providers: Vec::decode(r)?,
                closer: Vec::decode(r)?,
            },
            6 => Rpc::AddProvider { key: Key::decode(r)?, provider: PeerId::decode(r)? },
            7 => Rpc::RemoveProvider { key: Key::decode(r)? },
            _ => return Err(DecodeError("bad dht rpc tag")),
        })
    }
}

impl WireSize for Rpc {
    /// Exact encoded length in O(1): tag + varint req_id, 32-byte keys
    /// and peer ids, varint-prefixed peer lists. Property-tested against
    /// the real encoding in `tests/prop.rs`.
    fn wire_size(&self) -> usize {
        match self {
            Rpc::Ping { req_id } | Rpc::Pong { req_id } => 1 + varint_len(*req_id),
            Rpc::FindNode { req_id, .. } | Rpc::GetProviders { req_id, .. } => {
                1 + varint_len(*req_id) + 32
            }
            Rpc::FindNodeReply { req_id, closer } => {
                1 + varint_len(*req_id) + varint_len(closer.len() as u64) + closer.len() * 32
            }
            Rpc::GetProvidersReply { req_id, providers, closer } => {
                1 + varint_len(*req_id)
                    + varint_len(providers.len() as u64)
                    + providers.len() * 32
                    + varint_len(closer.len() as u64)
                    + closer.len() * 32
            }
            Rpc::AddProvider { .. } => 1 + 32 + 32,
            Rpc::RemoveProvider { .. } => 1 + 32,
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct DhtConfig {
    /// Lookup parallelism (Kademlia α).
    pub alpha: usize,
    /// Result-set size (Kademlia k).
    pub k: usize,
    /// Single RPC timeout.
    pub rpc_timeout: Duration,
    /// Provider-record lifetime.
    pub provider_ttl: Duration,
    /// Stop a provider lookup early after this many providers (0 = full).
    pub providers_needed: usize,
}

impl Default for DhtConfig {
    fn default() -> Self {
        DhtConfig {
            alpha: 3,
            k: K,
            rpc_timeout: Duration::from_secs(2),
            provider_ttl: Duration::from_secs(60 * 60),
            providers_needed: 3,
        }
    }
}

/// Identifier for an in-flight iterative lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LookupId(pub u64);

/// Completion events surfaced to the engine owner.
#[derive(Clone, Debug)]
pub enum DhtEvent {
    /// A FIND_NODE lookup finished with the k closest peers found.
    LookupDone { id: LookupId, target: Key, closest: Vec<PeerId> },
    /// A GET_PROVIDERS lookup finished (providers may be empty).
    ProvidersDone { id: LookupId, key: Key, providers: Vec<PeerId>, closest: Vec<PeerId> },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LookupKind {
    FindNode,
    GetProviders,
}

struct Lookup {
    kind: LookupKind,
    target: Key,
    /// Candidates by distance; value = queried?
    shortlist: BTreeMap<[u8; 32], (PeerId, bool)>,
    in_flight: usize,
    providers: BTreeSet<PeerId>,
    /// Exhaustive provider lookup: ignore the `providers_needed` early
    /// exit and walk the full k-closest set. Used by provider-*count*
    /// probes (availability repair), where "enough to fetch from" and
    /// "how many exist" are different questions.
    full: bool,
    done: bool,
}

impl Lookup {
    fn insert_candidate(&mut self, target: &Key, peer: PeerId) {
        let d = target.distance(&Key::from_peer(peer)).0;
        self.shortlist.entry(d).or_insert((peer, false));
    }
}

struct PendingRpc {
    lookup: Option<LookupId>,
    peer: PeerId,
    sent_at: Nanos,
}

/// Provider record with expiry.
struct ProviderRecord {
    expires: Nanos,
}

/// The Kademlia engine. One per node.
///
/// Iterated collections (pending RPCs, provider sets) are ordered maps:
/// timeout sweeps and provider replies must not depend on hash-map
/// iteration order, or two runs of the same seed would diverge.
pub struct Engine {
    own: PeerId,
    pub table: RoutingTable,
    cfg: DhtConfig,
    next_req: u64,
    next_lookup: u64,
    pending: BTreeMap<u64, PendingRpc>,
    lookups: HashMap<LookupId, Lookup>,
    /// key → provider → record
    providers: HashMap<Key, BTreeMap<PeerId, ProviderRecord>>,
    /// Completed-lookup events for the owner to drain.
    pub events: Vec<DhtEvent>,
    /// RPC counters (for experiment metrics).
    pub rpcs_sent: u64,
    pub rpcs_timed_out: u64,
    /// Adversarial wire-layer hook (eclipse-attack scenarios): when set,
    /// every *served* `FindNodeReply`/`GetProvidersReply` lists exactly
    /// these colluding peers instead of the honest routing-table view.
    /// Client-side behaviour (lookups this engine runs) is unchanged —
    /// the attacker lies to others, not to itself.
    forge: Option<Vec<PeerId>>,
    /// Replies whose contents were forged (attack-visibility metric).
    pub replies_forged: u64,
}

/// Outgoing RPCs accumulate here; the node wraps them in its wire type.
pub type Sends = Vec<(PeerId, Rpc)>;

impl Engine {
    pub fn new(own: PeerId, cfg: DhtConfig) -> Self {
        Engine {
            own,
            table: RoutingTable::new(Key::from_peer(own)),
            cfg,
            next_req: 1,
            next_lookup: 1,
            pending: BTreeMap::new(),
            lookups: HashMap::new(),
            providers: HashMap::new(),
            events: Vec::new(),
            rpcs_sent: 0,
            rpcs_timed_out: 0,
            forge: None,
            replies_forged: 0,
        }
    }

    pub fn own_id(&self) -> PeerId {
        self.own
    }

    /// Install (or with `None` clear) the forged colluder set: while set,
    /// every reply this engine serves to a `FindNode`/`GetProviders`
    /// request claims the colluders are the closest peers / providers.
    /// This is the byzantine wire-wrapping hook behind the
    /// `adversarial-eclipse` scenario (`sim::bank`).
    pub fn set_forgery(&mut self, colluders: Option<Vec<PeerId>>) {
        self.forge = colluders;
    }

    /// Whether this engine currently forges its replies.
    pub fn is_forging(&self) -> bool {
        self.forge.is_some()
    }

    /// The forged peer list for a reply to `from`, if forging is active.
    fn forged_peers(&mut self, from: PeerId) -> Option<Vec<PeerId>> {
        let lie: Vec<PeerId> =
            self.forge.as_ref()?.iter().copied().filter(|p| *p != from).collect();
        self.replies_forged += 1;
        Some(lie)
    }

    fn send(
        &mut self,
        to: PeerId,
        rpc: Rpc,
        lookup: Option<LookupId>,
        now: Nanos,
        out: &mut Sends,
    ) {
        if let Some(req_id) = match &rpc {
            Rpc::Ping { req_id }
            | Rpc::FindNode { req_id, .. }
            | Rpc::GetProviders { req_id, .. } => Some(*req_id),
            _ => None,
        } {
            self.pending.insert(req_id, PendingRpc { lookup, peer: to, sent_at: now });
        }
        self.rpcs_sent += 1;
        out.push((to, rpc));
    }

    fn fresh_req(&mut self) -> u64 {
        let id = self.next_req;
        self.next_req += 1;
        id
    }

    // ----- server side -----------------------------------------------------

    /// Handle an inbound RPC; may emit replies and lookup progress.
    pub fn on_rpc(&mut self, now: Nanos, from: PeerId, rpc: Rpc, out: &mut Sends) {
        self.table.touch(from, now);
        match rpc {
            Rpc::Ping { req_id } => {
                out.push((from, Rpc::Pong { req_id }));
            }
            Rpc::Pong { req_id } => {
                self.pending.remove(&req_id);
            }
            Rpc::FindNode { req_id, target } => {
                let closer = match self.forged_peers(from) {
                    Some(lie) => lie,
                    None => {
                        let mut closer = self.table.closest(&target, self.cfg.k);
                        closer.retain(|p| *p != from);
                        closer
                    }
                };
                out.push((from, Rpc::FindNodeReply { req_id, closer }));
            }
            Rpc::GetProviders { req_id, key } => {
                self.expire_providers(now, &key);
                let (providers, closer) = match self.forged_peers(from) {
                    Some(lie) => (lie.clone(), lie),
                    None => {
                        let providers: Vec<PeerId> = self
                            .providers
                            .get(&key)
                            .map(|m| m.keys().copied().collect())
                            .unwrap_or_default();
                        let mut closer = self.table.closest(&key, self.cfg.k);
                        closer.retain(|p| *p != from);
                        (providers, closer)
                    }
                };
                out.push((from, Rpc::GetProvidersReply { req_id, providers, closer }));
            }
            Rpc::AddProvider { key, provider } => {
                self.add_provider_record(now, key, provider);
            }
            Rpc::RemoveProvider { key } => {
                // Sender-keyed: `from` can only ever retract itself.
                self.remove_provider_record(&key, from);
            }
            Rpc::FindNodeReply { req_id, closer } => {
                self.on_reply(now, from, req_id, Vec::new(), closer, out);
            }
            Rpc::GetProvidersReply { req_id, providers, closer } => {
                self.on_reply(now, from, req_id, providers, closer, out);
            }
        }
    }

    fn add_provider_record(&mut self, now: Nanos, key: Key, provider: PeerId) {
        self.providers
            .entry(key)
            .or_default()
            .insert(provider, ProviderRecord { expires: now + self.cfg.provider_ttl });
    }

    fn remove_provider_record(&mut self, key: &Key, provider: PeerId) {
        if let Some(m) = self.providers.get_mut(key) {
            m.remove(&provider);
            if m.is_empty() {
                self.providers.remove(key);
            }
        }
    }

    fn expire_providers(&mut self, now: Nanos, key: &Key) {
        if let Some(m) = self.providers.get_mut(key) {
            m.retain(|_, r| r.expires > now);
            if m.is_empty() {
                self.providers.remove(key);
            }
        }
    }

    /// Providers currently recorded locally for `key`.
    pub fn local_providers(&self, key: &Key) -> Vec<PeerId> {
        self.providers
            .get(key)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }

    // ----- client side ------------------------------------------------------

    /// Seed the routing table (bootstrap peers learned out of band).
    pub fn add_seed(&mut self, now: Nanos, peer: PeerId) {
        self.table.touch(peer, now);
    }

    /// Start an iterative FIND_NODE lookup toward `target`.
    pub fn find_node(&mut self, now: Nanos, target: Key, out: &mut Sends) -> LookupId {
        self.start_lookup(now, target, LookupKind::FindNode, false, out)
    }

    /// Start an iterative GET_PROVIDERS lookup for `key`. Stops early
    /// once `providers_needed` providers are known — the fetch-oriented
    /// flavor ("enough candidates to start pulling blocks").
    pub fn find_providers(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.start_lookup(now, key, LookupKind::GetProviders, false, out)
    }

    /// Start an exhaustive GET_PROVIDERS lookup for `key`: never stops
    /// early at `providers_needed`, so the result reflects every record
    /// held by the k closest reachable peers. This is the provider-
    /// *count* probe behind availability repair — an early-exit count
    /// would saturate at `providers_needed` and under-report exactly
    /// when the repair decision needs precision.
    pub fn find_providers_full(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.start_lookup(now, key, LookupKind::GetProviders, true, out)
    }

    /// Announce ourselves as a provider: records locally and walks the
    /// DHT to store the record on the k closest peers to `key`.
    pub fn provide(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.add_provider_record(now, key, self.own);
        // The completion handler sends AddProvider to the found peers.
        self.start_lookup(now, key, LookupKind::FindNode, false, out)
    }

    /// Withdraw our own provider record for `key` (deliberate unpin):
    /// drops the local record immediately and walks the DHT so the
    /// completion handler can send [`Rpc::RemoveProvider`] to the k
    /// closest peers (via [`Engine::announce_withdrawal`], the mirror of
    /// [`Engine::announce_provider`]).
    pub fn withdraw(&mut self, now: Nanos, key: Key, out: &mut Sends) -> LookupId {
        self.remove_provider_record(&key, self.own);
        self.start_lookup(now, key, LookupKind::FindNode, false, out)
    }

    fn start_lookup(
        &mut self,
        now: Nanos,
        target: Key,
        kind: LookupKind,
        full: bool,
        out: &mut Sends,
    ) -> LookupId {
        let id = LookupId(self.next_lookup);
        self.next_lookup += 1;
        let mut lk = Lookup {
            kind,
            target,
            shortlist: BTreeMap::new(),
            in_flight: 0,
            providers: BTreeSet::new(),
            full,
            done: false,
        };
        for p in self.table.closest(&target, self.cfg.k) {
            lk.insert_candidate(&target, p);
        }
        self.lookups.insert(id, lk);
        self.drive_lookup(now, id, out);
        id
    }

    fn on_reply(
        &mut self,
        now: Nanos,
        from: PeerId,
        req_id: u64,
        providers: Vec<PeerId>,
        closer: Vec<PeerId>,
        out: &mut Sends,
    ) {
        let Some(pending) = self.pending.remove(&req_id) else {
            return; // late reply to an expired RPC
        };
        for p in &closer {
            if *p != self.own {
                self.table.touch(*p, now);
            }
        }
        let Some(lookup_id) = pending.lookup else { return };
        let Some(lk) = self.lookups.get_mut(&lookup_id) else { return };
        if lk.done {
            return;
        }
        lk.in_flight = lk.in_flight.saturating_sub(1);
        let target = lk.target;
        // Mark the replier as queried (it is already in the shortlist).
        let d = target.distance(&Key::from_peer(from)).0;
        if let Some(entry) = lk.shortlist.get_mut(&d) {
            entry.1 = true;
        }
        for p in closer {
            if p != self.own {
                lk.insert_candidate(&target, p);
            }
        }
        for p in providers {
            lk.providers.insert(p);
        }
        self.drive_lookup(now, lookup_id, out);
    }

    /// Issue queries up to α parallelism; detect completion.
    fn drive_lookup(&mut self, now: Nanos, id: LookupId, out: &mut Sends) {
        let Some(lk) = self.lookups.get_mut(&id) else { return };
        if lk.done {
            return;
        }
        let kind = lk.kind;
        let target = lk.target;

        // Early exit for provider lookups with enough providers (never
        // taken by exhaustive provider-count probes).
        let enough_providers = kind == LookupKind::GetProviders
            && !lk.full
            && self.cfg.providers_needed > 0
            && lk.providers.len() >= self.cfg.providers_needed;

        // Completion: the k closest candidates have all been queried and
        // nothing is in flight.
        let k_closest_all_queried = lk
            .shortlist
            .values()
            .take(self.cfg.k)
            .all(|(_, queried)| *queried);
        if enough_providers || (k_closest_all_queried && lk.in_flight == 0) {
            lk.done = true;
            let closest: Vec<PeerId> = lk
                .shortlist
                .values()
                .take(self.cfg.k)
                .map(|(p, _)| *p)
                .collect();
            let providers: Vec<PeerId> = lk.providers.iter().copied().collect();
            let ev = match kind {
                LookupKind::FindNode => DhtEvent::LookupDone { id, target, closest },
                LookupKind::GetProviders => {
                    DhtEvent::ProvidersDone { id, key: target, providers, closest }
                }
            };
            self.lookups.remove(&id);
            self.events.push(ev);
            return;
        }

        // Query the next unqueried candidates among the k closest.
        let mut to_query = Vec::new();
        {
            let lk = self.lookups.get_mut(&id).unwrap();
            for (_, (peer, queried)) in lk.shortlist.iter_mut().take(self.cfg.k) {
                if lk.in_flight + to_query.len() >= self.cfg.alpha {
                    break;
                }
                if !*queried {
                    *queried = true; // mark queried-on-send
                    to_query.push(*peer);
                }
            }
            lk.in_flight += to_query.len();
        }
        for peer in to_query {
            let req_id = self.fresh_req();
            let rpc = match kind {
                LookupKind::FindNode => Rpc::FindNode { req_id, target },
                LookupKind::GetProviders => Rpc::GetProviders { req_id, key: target },
            };
            self.send(peer, rpc, Some(id), now, out);
        }
    }

    /// Expire timed-out RPCs; called from a periodic tick.
    pub fn tick(&mut self, now: Nanos, out: &mut Sends) {
        let timeout = self.cfg.rpc_timeout;
        let expired: Vec<u64> = self
            .pending
            .iter()
            .filter(|(_, p)| now.saturating_sub(p.sent_at) >= timeout)
            .map(|(id, _)| *id)
            .collect();
        for req_id in expired {
            let p = self.pending.remove(&req_id).unwrap();
            self.rpcs_timed_out += 1;
            self.table.remove(&p.peer); // unresponsive peer
            if let Some(lid) = p.lookup {
                if let Some(lk) = self.lookups.get_mut(&lid) {
                    lk.in_flight = lk.in_flight.saturating_sub(1);
                    // peer stays marked queried → we move on
                    self.drive_lookup(now, lid, out);
                }
            }
        }
    }

    /// After a `provide` lookup completes, push AddProvider records to
    /// the closest peers (call with the `LookupDone` closest set).
    pub fn announce_provider(&mut self, key: Key, closest: &[PeerId], out: &mut Sends) {
        for p in closest.iter().take(self.cfg.k) {
            self.rpcs_sent += 1;
            out.push((*p, Rpc::AddProvider { key, provider: self.own }));
        }
    }

    /// After a [`Engine::withdraw`] lookup completes, ask the closest
    /// peers to drop our provider record for `key` (call with the
    /// `LookupDone` closest set).
    pub fn announce_withdrawal(&mut self, key: Key, closest: &[PeerId], out: &mut Sends) {
        for p in closest.iter().take(self.cfg.k) {
            self.rpcs_sent += 1;
            out.push((*p, Rpc::RemoveProvider { key }));
        }
    }

    /// Number of active lookups (diagnostics).
    pub fn active_lookups(&self) -> usize {
        self.lookups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Drive a set of engines to quiescence by synchronously routing RPCs.
    fn settle(
        engines: &mut HashMap<PeerId, Engine>,
        mut queue: Vec<(PeerId, PeerId, Rpc)>,
        now: Nanos,
    ) {
        let mut hops = 0;
        while let Some((from, to, rpc)) = queue.pop() {
            hops += 1;
            assert!(hops < 1_000_000, "rpc storm");
            let mut out = Sends::new();
            if let Some(e) = engines.get_mut(&to) {
                e.on_rpc(now, from, rpc, &mut out);
            }
            for (next_to, next_rpc) in out {
                queue.push((to, next_to, next_rpc));
            }
        }
    }

    fn mk_engines(n: usize, seed: u64) -> (Vec<PeerId>, HashMap<PeerId, Engine>) {
        let mut rng = Rng::new(seed);
        let ids: Vec<PeerId> = (0..n).map(|_| PeerId::from_rng(&mut rng)).collect();
        let engines: HashMap<PeerId, Engine> = ids
            .iter()
            .map(|id| (*id, Engine::new(*id, DhtConfig::default())))
            .collect();
        (ids, engines)
    }

    /// Fully-meshed routing tables for small-n tests.
    fn mesh(ids: &[PeerId], engines: &mut HashMap<PeerId, Engine>, now: Nanos) {
        for a in ids {
            for b in ids {
                if a != b {
                    engines.get_mut(a).unwrap().add_seed(now, *b);
                }
            }
        }
    }

    #[test]
    fn rpc_roundtrip_encoding() {
        let mut rng = Rng::new(1);
        let rpcs = vec![
            Rpc::Ping { req_id: 7 },
            Rpc::FindNode { req_id: 9, target: Key(rng.bytes32()) },
            Rpc::GetProvidersReply {
                req_id: 11,
                providers: vec![PeerId::from_rng(&mut rng)],
                closer: vec![PeerId::from_rng(&mut rng), PeerId::from_rng(&mut rng)],
            },
            Rpc::AddProvider { key: Key(rng.bytes32()), provider: PeerId::from_rng(&mut rng) },
            Rpc::RemoveProvider { key: Key(rng.bytes32()) },
        ];
        for rpc in rpcs {
            let b = crate::codec::to_bytes(&rpc);
            assert_eq!(crate::codec::from_bytes::<Rpc>(&b).unwrap(), rpc);
        }
    }

    #[test]
    fn find_node_converges_to_global_closest() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(50, 42);
        // Star topology: everyone knows the root, the root knows everyone
        // (the paper's bootstrap shape). Lookups must iterate through the
        // root to reach the true closest peers.
        let root = ids[1];
        for a in ids.iter().skip(2) {
            engines.get_mut(a).unwrap().add_seed(now, root);
            engines.get_mut(&root).unwrap().add_seed(now, *a);
        }
        engines.get_mut(&ids[0]).unwrap().add_seed(now, root);
        engines.get_mut(&root).unwrap().add_seed(now, ids[0]);
        let mut rng = Rng::new(99);
        let target = Key(rng.bytes32());
        let origin = ids[0];
        let mut out = Sends::new();
        let lid = engines.get_mut(&origin).unwrap().find_node(now, target, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (origin, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&origin).unwrap().events.pop().expect("lookup done");
        let DhtEvent::LookupDone { id, closest, .. } = ev else {
            panic!("wrong event");
        };
        assert_eq!(id, lid);
        // The found closest must equal the brute-force k closest among the
        // peers reachable through the root (its table may have evicted a
        // few under k-bucket pressure — that is correct Kademlia behaviour).
        let mut universe = engines.get(&root).unwrap().table.peers();
        universe.push(root);
        universe.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let top: Vec<PeerId> = universe.into_iter().filter(|p| *p != origin).take(5).collect();
        assert_eq!(&closest[..5], &top[..]);
    }

    #[test]
    fn provider_records_roundtrip() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(20, 7);
        mesh(&ids, &mut engines, now);
        let mut rng = Rng::new(5);
        let key = Key(rng.bytes32());
        let provider = ids[3];

        // Provider announces.
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().provide(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&provider).unwrap().events.pop().unwrap();
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!() };
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().announce_provider(key, &closest, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(&mut engines, queue, now);

        // Another peer finds the provider.
        let seeker = ids[10];
        let mut out = Sends::new();
        engines.get_mut(&seeker).unwrap().find_providers(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (seeker, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&seeker).unwrap().events.pop().expect("providers done");
        let DhtEvent::ProvidersDone { providers, .. } = ev else { panic!() };
        assert!(providers.contains(&provider), "provider not found");
    }

    /// Announce `provider` for `key` across the mesh (provide lookup +
    /// AddProvider fan-out), settling all traffic.
    fn announce(engines: &mut HashMap<PeerId, Engine>, provider: PeerId, key: Key, now: Nanos) {
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().provide(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(engines, queue, now);
        let ev = engines.get_mut(&provider).unwrap().events.pop().unwrap();
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!() };
        let mut out = Sends::new();
        engines.get_mut(&provider).unwrap().announce_provider(key, &closest, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (provider, to, rpc)).collect();
        settle(engines, queue, now);
    }

    #[test]
    fn full_provider_lookup_ignores_early_exit() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(20, 77);
        // Fetch-oriented lookups may stop after a single provider…
        for e in engines.values_mut() {
            e.cfg.providers_needed = 1;
        }
        mesh(&ids, &mut engines, now);
        let mut rng = Rng::new(6);
        let key = Key(rng.bytes32());
        for &p in &[ids[2], ids[7], ids[11]] {
            announce(&mut engines, p, key, now);
        }
        let seeker = ids[15];
        let mut out = Sends::new();
        engines.get_mut(&seeker).unwrap().find_providers_full(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (seeker, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&seeker).unwrap().events.pop().expect("providers done");
        let DhtEvent::ProvidersDone { providers, .. } = ev else { panic!() };
        // …but the exhaustive count probe must see all three records.
        for p in [ids[2], ids[7], ids[11]] {
            assert!(providers.contains(&p), "full lookup missed a provider");
        }
    }

    #[test]
    fn withdrawal_removes_only_the_senders_record() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(12, 23);
        mesh(&ids, &mut engines, now);
        let mut rng = Rng::new(4);
        let key = Key(rng.bytes32());
        let (keeper, leaver) = (ids[3], ids[5]);
        announce(&mut engines, keeper, key, now);
        announce(&mut engines, leaver, key, now);
        // `leaver` withdraws: walk the DHT, then fan out RemoveProvider.
        let mut out = Sends::new();
        engines.get_mut(&leaver).unwrap().withdraw(now, key, &mut out);
        assert!(engines.get(&leaver).unwrap().local_providers(&key).is_empty());
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (leaver, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&leaver).unwrap().events.pop().unwrap();
        let DhtEvent::LookupDone { closest, .. } = ev else { panic!() };
        let mut out = Sends::new();
        engines.get_mut(&leaver).unwrap().announce_withdrawal(key, &closest, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (leaver, to, rpc)).collect();
        settle(&mut engines, queue, now);
        // A fresh exhaustive lookup sees the keeper, not the leaver.
        let seeker = ids[9];
        let mut out = Sends::new();
        engines.get_mut(&seeker).unwrap().find_providers_full(now, key, &mut out);
        let queue: Vec<_> = out.into_iter().map(|(to, rpc)| (seeker, to, rpc)).collect();
        settle(&mut engines, queue, now);
        let ev = engines.get_mut(&seeker).unwrap().events.pop().expect("providers done");
        let DhtEvent::ProvidersDone { providers, .. } = ev else { panic!() };
        assert!(providers.contains(&keeper), "withdrawal must not touch other records");
        assert!(!providers.contains(&leaver), "withdrawn record still served");
    }

    #[test]
    fn remove_provider_is_sender_keyed() {
        let mut rng = Rng::new(19);
        let own = PeerId::from_rng(&mut rng);
        let (a, b) = (PeerId::from_rng(&mut rng), PeerId::from_rng(&mut rng));
        let mut e = Engine::new(own, DhtConfig::default());
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        e.on_rpc(Nanos(0), a, Rpc::AddProvider { key, provider: a }, &mut out);
        e.on_rpc(Nanos(0), b, Rpc::AddProvider { key, provider: b }, &mut out);
        // b tries to scrub the key: only b's own record can go.
        e.on_rpc(Nanos(1), b, Rpc::RemoveProvider { key }, &mut out);
        assert_eq!(e.local_providers(&key), vec![a]);
        e.on_rpc(Nanos(2), a, Rpc::RemoveProvider { key }, &mut out);
        assert!(e.local_providers(&key).is_empty());
    }

    #[test]
    fn provider_records_expire() {
        let mut rng = Rng::new(8);
        let own = PeerId::from_rng(&mut rng);
        let other = PeerId::from_rng(&mut rng);
        let cfg = DhtConfig { provider_ttl: Duration::from_secs(10), ..Default::default() };
        let mut e = Engine::new(own, cfg);
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        e.on_rpc(Nanos(0), other, Rpc::AddProvider { key, provider: other }, &mut out);
        assert_eq!(e.local_providers(&key), vec![other]);
        // After expiry, a GetProviders finds nothing.
        let t = Nanos(11_000_000_000);
        e.on_rpc(t, other, Rpc::GetProviders { req_id: 1, key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::GetProvidersReply { providers, .. } = reply else { panic!() };
        assert!(providers.is_empty());
    }

    #[test]
    fn forged_replies_substitute_peer_lists() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(6, 31);
        mesh(&ids, &mut engines, now);
        let attacker = ids[0];
        let colluders = vec![ids[1], ids[2]];
        engines.get_mut(&attacker).unwrap().set_forgery(Some(colluders.clone()));
        let seeker = ids[5];
        let mut rng = Rng::new(9);
        let key = Key(rng.bytes32());
        let mut out = Sends::new();
        engines
            .get_mut(&attacker)
            .unwrap()
            .on_rpc(now, seeker, Rpc::GetProviders { req_id: 1, key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::GetProvidersReply { providers, closer, .. } = reply else { panic!() };
        assert_eq!(providers, colluders, "forged providers");
        assert_eq!(closer, colluders, "forged closer set");
        // FindNode is forged too; a requesting colluder is filtered out.
        let mut out = Sends::new();
        engines
            .get_mut(&attacker)
            .unwrap()
            .on_rpc(now, ids[1], Rpc::FindNode { req_id: 2, target: key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::FindNodeReply { closer, .. } = reply else { panic!() };
        assert_eq!(closer, vec![ids[2]]);
        let e = engines.get_mut(&attacker).unwrap();
        assert_eq!(e.replies_forged, 2);
        // Clearing the forgery restores honest replies.
        e.set_forgery(None);
        assert!(!e.is_forging());
        let mut out = Sends::new();
        e.on_rpc(now, seeker, Rpc::FindNode { req_id: 3, target: key }, &mut out);
        let (_, reply) = out.pop().unwrap();
        let Rpc::FindNodeReply { closer, .. } = reply else { panic!() };
        assert!(closer.len() > 2, "honest reply must reflect the real table");
        assert_eq!(engines.get(&attacker).unwrap().replies_forged, 2);
    }

    #[test]
    fn timeout_expires_pending_and_continues() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(5, 3);
        mesh(&ids, &mut engines, now);
        let origin = ids[0];
        let mut rng = Rng::new(12);
        let target = Key(rng.bytes32());
        let mut out = Sends::new();
        engines.get_mut(&origin).unwrap().find_node(now, target, &mut out);
        assert!(!out.is_empty());
        // Drop all outgoing RPCs (peers never reply), then tick past the
        // timeout: the lookup must still complete (with no external info).
        let later = Nanos(3_000_000_000);
        let mut out2 = Sends::new();
        // Several rounds: each timeout round may re-query more candidates.
        for i in 0..10 {
            let t = Nanos(later.0 + i * 3_000_000_000);
            engines.get_mut(&origin).unwrap().tick(t, &mut out2);
        }
        let e = engines.get_mut(&origin).unwrap();
        assert!(e.rpcs_timed_out > 0);
        assert!(
            e.events.iter().any(|ev| matches!(ev, DhtEvent::LookupDone { .. })),
            "lookup did not terminate after timeouts"
        );
    }

    #[test]
    fn ping_pong_clears_pending() {
        let now = Nanos(0);
        let (ids, mut engines) = mk_engines(2, 21);
        let (a, b) = (ids[0], ids[1]);
        let mut out = Sends::new();
        let req_id = {
            let e = engines.get_mut(&a).unwrap();
            let id = e.fresh_req();
            e.send(b, Rpc::Ping { req_id: id }, None, now, &mut out);
            id
        };
        let (_, ping) = out.pop().unwrap();
        let mut out2 = Sends::new();
        engines.get_mut(&b).unwrap().on_rpc(now, a, ping, &mut out2);
        let (_, pong) = out2.pop().unwrap();
        assert_eq!(pong, Rpc::Pong { req_id });
        let mut out3 = Sends::new();
        engines.get_mut(&a).unwrap().on_rpc(now, b, pong, &mut out3);
        assert!(engines.get_mut(&a).unwrap().pending.is_empty());
    }
}

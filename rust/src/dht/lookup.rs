//! The sans-io iterative-lookup state machine behind [`crate::dht::Engine`].
//!
//! Extracted from the engine so the lookup logic — candidate shortlists,
//! α-parallel query selection, timeout accounting, termination — is a
//! self-contained, property-testable value with **no knowledge of RPCs,
//! request ids, or timers**. The engine owns the wire concerns: it maps
//! replies and timeouts back to `(lookup, path)` pairs and turns the
//! [`Drive`] verdicts this module returns into actual `FindNode` /
//! `GetProviders` sends. `find_node`, `find_providers`,
//! `find_providers_full`, `provide`, and `withdraw` all instantiate this
//! one machine.
//!
//! ## Disjoint-path lookups (eclipse hardening)
//!
//! With [`LookupConfig::paths`] = d > 1 the seed candidates are dealt
//! round-robin (by distance rank) into d *independent* paths. Every path
//! runs the classic iterative algorithm on its own shortlist, but a
//! global claim set guarantees the per-path **queried** sets stay
//! pairwise disjoint: once any path has queried a peer, no sibling path
//! will ever query it (a sibling that ranks the peer in its own top-k
//! simply skips it, as if it had been queried). Results merge only at
//! termination — the k closest candidates over the union of all path
//! shortlists, and the union of all provider records seen. A colluding
//! minority that owns one path's frontier therefore cannot poison the
//! merged result unless it owns *every* path (S/Kademlia's d-disjoint
//! lookup argument).
//!
//! With `paths = 1` the machine is, step for step, the exact algorithm
//! the engine inlined before the extraction: same selection order, same
//! termination condition, same results — property-tested against a
//! line-for-line reference of the legacy code in `tests/prop.rs`, which
//! is what keeps every pre-refactor scenario replay bit-identical.
//!
//! ## Distance-verified candidates (the other half of the hardening)
//!
//! With [`LookupConfig::verify_distance`] set, a closer-peer candidate
//! from a reply is accepted only if it is *strictly closer* to the
//! target than the peer that reported it. An honest Kademlia hop always
//! makes progress toward the target, so the filter costs convergence
//! nothing, while a forged reply pointing "sideways" at colluders no
//! longer plants them in the shortlist. Rejections are counted and
//! surfaced by [`LookupState::on_reply`] so the engine can export the
//! `closer_peers_rejected` metric.

use crate::dht::key::Key;
use crate::net::PeerId;
use std::collections::{BTreeMap, BTreeSet};

/// What kind of RPC an iterative lookup issues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LookupKind {
    FindNode,
    GetProviders,
}

/// Lookup-shape knobs, snapshotted from
/// [`crate::dht::DhtConfig`] when the lookup starts.
#[derive(Clone, Copy, Debug)]
pub struct LookupConfig {
    /// Per-path query parallelism (Kademlia α).
    pub alpha: usize,
    /// Result-set size (Kademlia k).
    pub k: usize,
    /// Stop a provider lookup early once this many providers are known
    /// (0 = never; exhaustive lookups ignore it regardless).
    pub providers_needed: usize,
    /// Number of disjoint lookup paths (d). 1 = the classic single-path
    /// iterative lookup.
    pub paths: usize,
    /// Reject closer-peer candidates that are not strictly closer to the
    /// target than the peer reporting them.
    pub verify_distance: bool,
}

/// The distance-verification rule, shared by the shortlist admission
/// filter ([`LookupState::on_reply`]) and the engine's hearsay
/// quarantine gate so the two can never drift: a candidate learned from
/// `from` is admissible for `target` only when it is *strictly closer*
/// to the target than `from` itself.
pub fn strictly_closer(target: &Key, from: PeerId, candidate: PeerId) -> bool {
    target.distance(&Key::from_peer(candidate)) < target.distance(&Key::from_peer(from))
}

/// What the engine should do after driving a path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Drive {
    /// The whole lookup (every path) finished; read
    /// [`LookupState::result`] and drop the state.
    Done,
    /// Send a query to each of these peers, attributed to the driven
    /// path (order matters: it is the distance order requests go out in,
    /// which request-id assignment — and thus replay determinism —
    /// depends on).
    Query(Vec<PeerId>),
    /// Nothing to do until a reply or timeout arrives.
    Wait,
}

/// One independent lookup path: a distance-ordered candidate shortlist
/// plus in-flight accounting.
#[derive(Default)]
struct Path {
    /// Candidates keyed by XOR distance to the target; value =
    /// `(peer, queried?)`. A peer claimed by a sibling path is marked
    /// queried without ever being sent to.
    shortlist: BTreeMap<[u8; 32], (PeerId, bool)>,
    in_flight: usize,
    /// Peers this path actually sent a query to (diagnostics + the
    /// disjointness property; a strict subset of the `queried` marks).
    queried: BTreeSet<PeerId>,
}

/// A multi-path iterative lookup in progress. See the module docs for
/// the state-machine contract.
pub struct LookupState {
    own: PeerId,
    kind: LookupKind,
    target: Key,
    /// Exhaustive provider lookup: ignore the `providers_needed` early
    /// exit and walk the full k-closest set (provider-*count* probes).
    full: bool,
    alpha: usize,
    k: usize,
    providers_needed: usize,
    verify_distance: bool,
    paths: Vec<Path>,
    /// Peers queried by *some* path — the disjointness guarantee.
    claimed: BTreeSet<PeerId>,
    /// Union of provider records seen on any path.
    providers: BTreeSet<PeerId>,
    done: bool,
}

impl LookupState {
    /// Start a lookup. `seeds` is the distance-ordered candidate list
    /// (the caller's k closest known peers to `target`); candidates are
    /// dealt round-robin across `cfg.paths` paths so every path starts
    /// from a different slice of the neighborhood.
    pub fn new(
        own: PeerId,
        kind: LookupKind,
        target: Key,
        full: bool,
        cfg: LookupConfig,
        seeds: Vec<PeerId>,
    ) -> LookupState {
        let paths = cfg.paths.max(1);
        let mut lk = LookupState {
            own,
            kind,
            target,
            full,
            alpha: cfg.alpha,
            k: cfg.k,
            providers_needed: cfg.providers_needed,
            verify_distance: cfg.verify_distance,
            paths: (0..paths).map(|_| Path::default()).collect(),
            claimed: BTreeSet::new(),
            providers: BTreeSet::new(),
            done: false,
        };
        for (rank, peer) in seeds.into_iter().enumerate() {
            lk.insert_candidate(rank % paths, peer);
        }
        lk
    }

    pub fn kind(&self) -> LookupKind {
        self.kind
    }

    pub fn target(&self) -> Key {
        self.target
    }

    /// Number of paths this lookup runs.
    pub fn paths(&self) -> usize {
        self.paths.len()
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Peers path `pi` actually queried (sent a request to), in id
    /// order. Pairwise disjoint across paths by construction.
    pub fn queried(&self, pi: usize) -> Vec<PeerId> {
        self.paths[pi].queried.iter().copied().collect()
    }

    /// Path `pi`'s current k-closest candidate view (merged into the
    /// final result at termination).
    pub fn path_closest(&self, pi: usize) -> Vec<PeerId> {
        self.paths[pi].shortlist.values().take(self.k).map(|(p, _)| *p).collect()
    }

    /// The merged result: the k closest candidates over the union of all
    /// path shortlists, plus the union of all provider records seen.
    /// Meaningful once [`LookupState::is_done`]; harmless earlier.
    pub fn result(&self) -> (Vec<PeerId>, Vec<PeerId>) {
        let mut merged: BTreeMap<[u8; 32], PeerId> = BTreeMap::new();
        for path in &self.paths {
            for (d, (peer, _)) in &path.shortlist {
                merged.entry(*d).or_insert(*peer);
            }
        }
        let closest: Vec<PeerId> = merged.into_values().take(self.k).collect();
        let providers: Vec<PeerId> = self.providers.iter().copied().collect();
        (closest, providers)
    }

    fn insert_candidate(&mut self, pi: usize, peer: PeerId) {
        if peer == self.own {
            return;
        }
        let d = self.target.distance(&Key::from_peer(peer)).0;
        self.paths[pi].shortlist.entry(d).or_insert((peer, false));
    }

    /// Feed a reply that arrived for a query path `pi` sent to `from`.
    /// Marks the replier answered, merges `providers`, and admits the
    /// `closer` candidates into the path's shortlist (minus self, and —
    /// under distance verification — minus candidates not strictly
    /// closer to the target than `from`). Returns how many candidates
    /// the distance filter rejected. Call [`LookupState::drive`] for the
    /// same path afterwards.
    pub fn on_reply(
        &mut self,
        pi: usize,
        from: PeerId,
        providers: Vec<PeerId>,
        closer: &[PeerId],
    ) -> u64 {
        if self.done {
            return 0;
        }
        let from_dist = self.target.distance(&Key::from_peer(from));
        {
            let path = &mut self.paths[pi];
            path.in_flight = path.in_flight.saturating_sub(1);
            // Mark the replier as queried (it is already in the shortlist).
            if let Some(entry) = path.shortlist.get_mut(&from_dist.0) {
                entry.1 = true;
            }
        }
        let mut rejected = 0;
        for &p in closer {
            if p == self.own {
                continue;
            }
            if self.verify_distance && !strictly_closer(&self.target, from, p) {
                rejected += 1;
                continue;
            }
            self.insert_candidate(pi, p);
        }
        for p in providers {
            self.providers.insert(p);
        }
        rejected
    }

    /// A query path `pi` sent has timed out: the peer stays marked
    /// queried (we move on), only the in-flight slot frees up. Call
    /// [`LookupState::drive`] for the same path afterwards.
    pub fn on_timeout(&mut self, pi: usize) {
        if self.done {
            return;
        }
        let path = &mut self.paths[pi];
        path.in_flight = path.in_flight.saturating_sub(1);
    }

    /// Advance path `pi`: detect whole-lookup completion, otherwise pick
    /// the next unqueried candidates among the path's k closest, up to α
    /// in flight. Candidates already claimed by a sibling path are
    /// marked off (never re-queried) and selection continues past them.
    pub fn drive(&mut self, pi: usize) -> Drive {
        if self.done {
            return Drive::Wait;
        }
        loop {
            if self.complete() {
                self.done = true;
                return Drive::Done;
            }
            let (to_query, marked_claimed) = self.select(pi);
            if !to_query.is_empty() {
                return Drive::Query(to_query);
            }
            if !marked_claimed {
                return Drive::Wait;
            }
            // Claimed-elsewhere candidates were marked off without a
            // send; that may have completed the path — re-check.
        }
    }

    /// Whole-lookup termination: enough providers (fetch-oriented
    /// provider lookups only), or every path has its k closest
    /// candidates queried with nothing in flight.
    fn complete(&self) -> bool {
        let enough_providers = self.kind == LookupKind::GetProviders
            && !self.full
            && self.providers_needed > 0
            && self.providers.len() >= self.providers_needed;
        enough_providers
            || self.paths.iter().all(|p| {
                p.in_flight == 0 && p.shortlist.values().take(self.k).all(|(_, queried)| *queried)
            })
    }

    /// Query selection for one path; returns the peers to send to and
    /// whether any sibling-claimed candidate was marked off.
    fn select(&mut self, pi: usize) -> (Vec<PeerId>, bool) {
        let LookupState { paths, claimed, alpha, k, .. } = self;
        let path = &mut paths[pi];
        let in_flight = path.in_flight;
        let mut to_query = Vec::new();
        let mut marked_claimed = false;
        for (_, (peer, queried)) in path.shortlist.iter_mut().take(*k) {
            if in_flight + to_query.len() >= *alpha {
                break;
            }
            if *queried {
                continue;
            }
            if claimed.contains(peer) {
                // A sibling path already queried this peer; disjointness
                // forbids a second query, so mark it off for this path.
                *queried = true;
                marked_claimed = true;
                continue;
            }
            *queried = true; // mark queried-on-send
            claimed.insert(*peer);
            to_query.push(*peer);
        }
        path.in_flight += to_query.len();
        for p in &to_query {
            path.queried.insert(*p);
        }
        (to_query, marked_claimed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg(paths: usize) -> LookupConfig {
        LookupConfig { alpha: 3, k: 20, providers_needed: 3, paths, verify_distance: false }
    }

    fn peers(n: usize, rng: &mut Rng) -> Vec<PeerId> {
        (0..n).map(|_| PeerId::from_rng(rng)).collect()
    }

    #[test]
    fn empty_seed_completes_immediately() {
        let mut rng = Rng::new(1);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        for d in [1, 3] {
            let mut lk =
                LookupState::new(own, LookupKind::FindNode, target, false, cfg(d), Vec::new());
            assert_eq!(lk.drive(0), Drive::Done);
            assert!(lk.is_done());
            let (closest, providers) = lk.result();
            assert!(closest.is_empty() && providers.is_empty());
        }
    }

    #[test]
    fn single_path_queries_in_distance_order_up_to_alpha() {
        let mut rng = Rng::new(2);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        let mut seeds = peers(8, &mut rng);
        seeds.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let mut lk =
            LookupState::new(own, LookupKind::FindNode, target, false, cfg(1), seeds.clone());
        let Drive::Query(q) = lk.drive(0) else { panic!("expected queries") };
        assert_eq!(q, seeds[..3].to_vec(), "first α queries go to the closest seeds");
        // Replies without new candidates walk the rest of the shortlist.
        let mut outstanding: Vec<PeerId> = q;
        while let Some(peer) = outstanding.pop() {
            lk.on_reply(0, peer, Vec::new(), &[]);
            match lk.drive(0) {
                Drive::Query(more) => outstanding.extend(more),
                Drive::Done => break,
                Drive::Wait => {}
            }
        }
        assert!(lk.is_done());
        let (closest, _) = lk.result();
        assert_eq!(closest, seeds, "all seeds ranked in the merged result");
        assert_eq!(lk.queried(0), {
            let mut s = seeds.clone();
            s.sort();
            s
        });
    }

    #[test]
    fn provider_early_exit_skips_remaining_candidates() {
        let mut rng = Rng::new(3);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        let mut seeds = peers(10, &mut rng);
        seeds.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let provs = peers(3, &mut rng);
        let mut lk =
            LookupState::new(own, LookupKind::GetProviders, target, false, cfg(1), seeds.clone());
        let Drive::Query(q) = lk.drive(0) else { panic!() };
        lk.on_reply(0, q[0], provs.clone(), &[]);
        assert_eq!(lk.drive(0), Drive::Done, "3 providers satisfy providers_needed");
        let (_, got) = lk.result();
        let mut want = provs;
        want.sort();
        assert_eq!(got, want);
        // The exhaustive flavor ignores the early exit.
        let mut full =
            LookupState::new(own, LookupKind::GetProviders, target, true, cfg(1), seeds);
        let Drive::Query(q) = full.drive(0) else { panic!() };
        full.on_reply(0, q[0], peers(4, &mut rng), &[]);
        assert_ne!(full.drive(0), Drive::Done, "full lookup keeps walking");
    }

    #[test]
    fn sibling_claim_is_skipped_not_requeried() {
        // Path 1 learns (via a reply) a candidate path 0 already queried:
        // it must mark the candidate off without a second query, and the
        // lookup must still terminate (no deadlock on claimed peers).
        let mut rng = Rng::new(4);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        let mut seeds = peers(2, &mut rng);
        seeds.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let (s0, s1) = (seeds[0], seeds[1]);
        let mut lk = LookupState::new(own, LookupKind::FindNode, target, false, cfg(2), seeds);
        let Drive::Query(q0) = lk.drive(0) else { panic!() };
        assert_eq!(q0, vec![s0]);
        let Drive::Query(q1) = lk.drive(1) else { panic!() };
        assert_eq!(q1, vec![s1]);
        // s1's reply names s0 — already claimed by path 0.
        lk.on_reply(1, s1, Vec::new(), &[s0]);
        assert_eq!(lk.drive(1), Drive::Wait, "path 1 marks s0 off; path 0 still in flight");
        lk.on_reply(0, s0, Vec::new(), &[]);
        assert_eq!(lk.drive(0), Drive::Done);
        assert_eq!(lk.queried(0), vec![s0]);
        assert_eq!(lk.queried(1), vec![s1], "s0 was never re-queried by path 1");
    }

    #[test]
    fn distance_verification_rejects_lateral_candidates() {
        let mut rng = Rng::new(5);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        // Rank a pool by distance to target: replier in the middle,
        // one candidate closer, one farther.
        let mut pool = peers(9, &mut rng);
        pool.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let (closer, replier, farther) = (pool[0], pool[4], pool[8]);
        let mut c = cfg(1);
        c.verify_distance = true;
        let mut lk =
            LookupState::new(own, LookupKind::FindNode, target, false, c, vec![replier]);
        let Drive::Query(q) = lk.drive(0) else { panic!() };
        assert_eq!(q, vec![replier]);
        let rejected = lk.on_reply(0, replier, Vec::new(), &[farther, closer, replier]);
        // `farther` is lateral hearsay; `replier` itself is not strictly
        // closer than itself either. Only `closer` survives.
        assert_eq!(rejected, 2);
        let Drive::Query(q) = lk.drive(0) else { panic!("must chase the accepted candidate") };
        assert_eq!(q, vec![closer]);
    }

    #[test]
    fn multipath_seeds_deal_round_robin_and_results_merge() {
        let mut rng = Rng::new(6);
        let own = PeerId::from_rng(&mut rng);
        let target = Key(rng.bytes32());
        let mut seeds = peers(9, &mut rng);
        seeds.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
        let mut lk =
            LookupState::new(own, LookupKind::FindNode, target, false, cfg(3), seeds.clone());
        assert_eq!(lk.paths(), 3);
        assert_eq!(lk.path_closest(0), vec![seeds[0], seeds[3], seeds[6]]);
        assert_eq!(lk.path_closest(1), vec![seeds[1], seeds[4], seeds[7]]);
        assert_eq!(lk.path_closest(2), vec![seeds[2], seeds[5], seeds[8]]);
        let mut outstanding: Vec<(usize, PeerId)> = Vec::new();
        for pi in 0..3 {
            if let Drive::Query(q) = lk.drive(pi) {
                outstanding.extend(q.into_iter().map(|p| (pi, p)));
            }
        }
        while let Some((pi, peer)) = outstanding.pop() {
            lk.on_reply(pi, peer, Vec::new(), &[]);
            if let Drive::Query(more) = lk.drive(pi) {
                outstanding.extend(more.into_iter().map(|p| (pi, p)));
            }
        }
        assert!(lk.is_done());
        let (closest, _) = lk.result();
        assert_eq!(closest, seeds, "merged result covers every path's slice, in distance order");
        // Disjointness: each seed was queried by exactly its own path.
        for a in 0..3 {
            for b in (a + 1)..3 {
                let qa = lk.queried(a);
                assert!(
                    !lk.queried(b).iter().any(|p| qa.contains(p)),
                    "paths {a} and {b} share a queried peer"
                );
            }
        }
    }
}

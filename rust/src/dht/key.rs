//! 256-bit DHT keys and the XOR metric (Maymounkov & Mazières, 2002).

use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use crate::net::PeerId;

/// A point in the 256-bit Kademlia key space. Peers live at the hash of
/// their id; content lives at its CID hash.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Key(pub [u8; 32]);

impl Key {
    pub fn from_peer(id: PeerId) -> Key {
        // Peer ids are already uniformly random 256-bit values.
        Key(id.0)
    }

    pub fn from_cid(cid: &crate::cid::Cid) -> Key {
        Key(cid.key())
    }

    /// XOR distance to another key.
    pub fn distance(&self, other: &Key) -> Distance {
        let mut d = [0u8; 32];
        for i in 0..32 {
            d[i] = self.0[i] ^ other.0[i];
        }
        Distance(d)
    }

    /// Index of the k-bucket this key falls into relative to `self`:
    /// 255 − (leading zero bits of the distance); `None` for self.
    pub fn bucket_index(&self, other: &Key) -> Option<usize> {
        let d = self.distance(other);
        let lz = d.leading_zeros();
        if lz == 256 {
            None
        } else {
            Some(255 - lz)
        }
    }
}

impl std::fmt::Debug for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Key({})", crate::util::hex::encode(&self.0[..4]))
    }
}

impl Encode for Key {
    fn encode(&self, w: &mut Writer) {
        w.put_raw(&self.0);
    }
}

impl Decode for Key {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Key(r.get_raw(32)?.try_into().unwrap()))
    }
}

/// An XOR distance; ordered big-endian (smaller = closer).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Distance(pub [u8; 32]);

impl Distance {
    pub fn leading_zeros(&self) -> usize {
        let mut n = 0;
        for &b in &self.0 {
            if b == 0 {
                n += 8;
            } else {
                n += b.leading_zeros() as usize;
                break;
            }
        }
        n
    }
}

impl std::fmt::Debug for Distance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Distance(2^{})", 256 - self.leading_zeros())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn k(byte: u8) -> Key {
        let mut b = [0u8; 32];
        b[0] = byte;
        Key(b)
    }

    #[test]
    fn distance_is_metric() {
        let mut rng = Rng::new(1);
        let a = Key(rng.bytes32());
        let b = Key(rng.bytes32());
        let c = Key(rng.bytes32());
        // identity
        assert_eq!(a.distance(&a).leading_zeros(), 256);
        // symmetry
        assert_eq!(a.distance(&b), b.distance(&a));
        // triangle inequality under XOR: d(a,c) <= d(a,b) XOR d(b,c) — the
        // XOR relation itself: d(a,b) ^ d(b,c) == d(a,c)
        let ab = a.distance(&b);
        let bc = b.distance(&c);
        let ac = a.distance(&c);
        let mut x = [0u8; 32];
        for i in 0..32 {
            x[i] = ab.0[i] ^ bc.0[i];
        }
        assert_eq!(Distance(x), ac);
    }

    #[test]
    fn bucket_indices() {
        let origin = k(0);
        assert_eq!(origin.bucket_index(&k(0x80)), Some(255));
        assert_eq!(origin.bucket_index(&k(0x01)), Some(248));
        assert_eq!(origin.bucket_index(&origin), None);
        let mut low = [0u8; 32];
        low[31] = 1;
        assert_eq!(origin.bucket_index(&Key(low)), Some(0));
    }

    #[test]
    fn ordering_matches_closeness() {
        let origin = k(0);
        assert!(origin.distance(&k(1)) < origin.distance(&k(2)));
        assert!(origin.distance(&k(2)) < origin.distance(&k(0xff)));
    }
}

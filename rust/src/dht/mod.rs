//! Kademlia distributed hash table.
//!
//! The peer- and provider-discovery substrate (the paper's IPFS nodes use
//! exactly this: "IPFS … leverages the Kademlia Distributed Hash Table to
//! facilitate the discovery of network addresses pertaining to peer nodes
//! and the IPFS objects hosted by said peers").
//!
//! Implemented from scratch: XOR metric over 256-bit keys ([`key`]),
//! LRU k-buckets plus the `pending_verify` first-contact tier
//! ([`kbucket`]), a self-contained iterative-lookup state machine with
//! optional disjoint paths ([`lookup`]), and a sans-io engine
//! ([`engine`]) running iterative `FIND_NODE` / `GET_PROVIDERS` lookups
//! with α-parallelism and provider-record storage with expiry.

pub mod engine;
pub mod kbucket;
pub mod key;
pub mod lookup;

pub use engine::{DhtConfig, DhtEvent, Engine, LookupId, Rpc};
pub use key::Key;

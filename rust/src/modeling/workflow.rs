//! The §III-D performance-modeling workflow.
//!
//! "Whenever a new performance model shall be trained …, the
//! contributions store is consulted and the required performance data is
//! retrieved by their CIDs … optionally pre-filtered according to further
//! criteria, or based on their data validity … The gathered data
//! contributions can additionally be joined with performance data which
//! is only locally available, and eventually used for training and
//! employment of a performance model."
//!
//! This module implements exactly that pipeline against a [`Node`] and an
//! AOT-compiled [`PerfModel`], plus the evaluation harness that compares
//! **collaborative** vs **local-only** modeling — the paper's motivating
//! benefit.

use crate::modeling::datagen::{parse_contribution, TraceRow};
#[cfg(feature = "pjrt")]
use crate::modeling::features::{encode_batch, DIM};
use crate::peersdb::Node;
#[cfg(feature = "pjrt")]
use crate::runtime::batching::padded_batches;
#[cfg(feature = "pjrt")]
use crate::runtime::PerfModel;
use crate::stores::documents::Verdict;
use crate::util::Rng;
#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Assemble training rows from a node's replicated contributions
/// (skipping any the validations store flags as invalid), joined with
/// locally-held private files.
pub fn assemble_from_node(
    node: &Node,
    workload: Option<&str>,
    private_cids: &[crate::cid::Cid],
) -> Vec<TraceRow> {
    let mut rows = Vec::new();
    for c in node.query_contributions(|c| workload.map(|w| c.workload == w).unwrap_or(true)) {
        if node.verdict(&c.data_cid) == Some(Verdict::Invalid) {
            continue; // §III-D: filter by data validity
        }
        if let Some(file) = node.get_file(&c.data_cid) {
            if let Some(mut parsed) = parse_contribution(&file) {
                rows.append(&mut parsed);
            }
        }
    }
    for cid in private_cids {
        if let Some(file) = node.get_file(cid) {
            if let Some(mut parsed) = parse_contribution(&file) {
                rows.append(&mut parsed);
            }
        }
    }
    rows
}

/// Outcome of one train+evaluate run.
#[cfg(feature = "pjrt")]
#[derive(Clone, Debug)]
pub struct Report {
    pub train_rows: usize,
    pub test_rows: usize,
    pub epochs: usize,
    pub first_epoch_loss: f32,
    pub final_epoch_loss: f32,
    /// RMSE in ln(runtime) space on held-out rows.
    pub rmse_log: f64,
    /// Mean absolute percentage error on runtimes.
    pub mape: f64,
}

/// Train the model on `train` and evaluate on `test`.
#[cfg(feature = "pjrt")]
pub fn train_and_eval(
    model: &mut PerfModel,
    train: &[TraceRow],
    test: &[TraceRow],
    epochs: usize,
    lr: f32,
    rng: &mut Rng,
) -> Result<Report> {
    model.reset()?;
    let mut train = train.to_vec();
    let (mut first, mut last) = (f32::NAN, f32::NAN);
    for epoch in 0..epochs {
        rng.shuffle(&mut train);
        let (xs, ys) = encode_batch(&train);
        let mut epoch_loss = 0.0;
        let batches = padded_batches(&xs, &ys, DIM, model.meta.batch);
        for (bx, by, bm) in &batches {
            epoch_loss += model.train_step(bx, by, bm, lr)?;
        }
        epoch_loss /= batches.len().max(1) as f32;
        if epoch == 0 {
            first = epoch_loss;
        }
        last = epoch_loss;
    }
    let (rmse_log, mape) = evaluate(model, test)?;
    Ok(Report {
        train_rows: train.len(),
        test_rows: test.len(),
        epochs,
        first_epoch_loss: first,
        final_epoch_loss: last,
        rmse_log,
        mape,
    })
}

/// Evaluate RMSE (log space) and MAPE (runtime space) on held-out rows.
#[cfg(feature = "pjrt")]
pub fn evaluate(model: &PerfModel, test: &[TraceRow]) -> Result<(f64, f64)> {
    let (xs, ys) = encode_batch(test);
    let mut se = 0.0f64;
    let mut ape = 0.0f64;
    let mut n = 0.0f64;
    for (bx, by, bm) in padded_batches(&xs, &ys, DIM, model.meta.batch) {
        let preds = model.predict(&bx)?;
        for i in 0..model.meta.batch {
            if bm[i] > 0.0 {
                let d = (preds[i] - by[i]) as f64;
                se += d * d;
                let rt_true = (by[i] as f64).exp();
                let rt_pred = (preds[i] as f64).exp();
                ape += ((rt_pred - rt_true) / rt_true).abs();
                n += 1.0;
            }
        }
    }
    Ok(((se / n).sqrt(), ape / n))
}

/// Train/test split by deterministic shuffle.
pub fn split(rows: &[TraceRow], test_frac: f64, rng: &mut Rng) -> (Vec<TraceRow>, Vec<TraceRow>) {
    let mut rows = rows.to_vec();
    rng.shuffle(&mut rows);
    let n_test = ((rows.len() as f64) * test_frac) as usize;
    let test = rows.split_off(rows.len() - n_test);
    (rows, test)
}

/// The collaboration experiment: compare a model trained only on one
/// peer's local data against one trained on everything the distribution
/// layer replicated. Returns (local report, collaborative report).
#[cfg(feature = "pjrt")]
pub fn collaboration_benefit(
    model: &mut PerfModel,
    local_rows: &[TraceRow],
    collaborative_rows: &[TraceRow],
    test_rows: &[TraceRow],
    epochs: usize,
    lr: f32,
    seed: u64,
) -> Result<(Report, Report)> {
    let mut rng = Rng::new(seed);
    let local = train_and_eval(model, local_rows, test_rows, epochs, lr, &mut rng)?;
    let mut rng = Rng::new(seed);
    let collab = train_and_eval(model, collaborative_rows, test_rows, epochs, lr, &mut rng)?;
    Ok((local, collab))
}

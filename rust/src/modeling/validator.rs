//! Model-backed data validation: the AOT k-NN novelty scorer as a
//! [`Validator`].
//!
//! §III-C calls for validation routines "composed of actions that
//! validate data quality as well as the benefit for performance
//! modeling". [`ModelValidator`] implements both stages:
//!
//! 1. structural checks (gzip/json/schema/ranges — [`StatsValidator`]),
//! 2. a learned novelty score: each row's distance to its k nearest
//!    neighbours in a trusted reference set, computed by the AOT-compiled
//!    `knn_score` artifact via PJRT.
//!
//! The PJRT executable runs on a dedicated *model-server thread* (PJRT
//! handles are not `Send`); validators talk to it over channels. This is
//! exactly the paper's async-background-validation shape, and it lets one
//! compiled model serve every node in a TCP deployment.

use crate::modeling::datagen::parse_contribution;
use crate::modeling::features::{encode_row, DIM};
use crate::runtime::PerfModel;
use crate::stores::documents::Verdict;
use crate::validation::{StatsValidator, Validator};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};

enum Req {
    Score { data: Vec<u8>, reply: Sender<(Verdict, f64)> },
    Stop,
}

/// Handle to the model-server thread; cheap to clone, `Send`, and
/// implements [`Validator`].
pub struct ModelValidator {
    tx: Sender<Req>,
}

impl Clone for ModelValidator {
    fn clone(&self) -> Self {
        ModelValidator { tx: self.tx.clone() }
    }
}

impl Validator for ModelValidator {
    fn validate(&mut self, data: &[u8]) -> (Verdict, f64) {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self
            .tx
            .send(Req::Score { data: data.to_vec(), reply: reply_tx })
            .is_err()
        {
            return (Verdict::Inconclusive, 0.5);
        }
        reply_rx.recv().unwrap_or((Verdict::Inconclusive, 0.5))
    }
}

/// The running model server; dropping (or calling [`stop`]) joins the
/// thread.
pub struct ModelServer {
    tx: Sender<Req>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ModelServer {
    /// Spawn the server. `reference_rows` are trusted feature rows the
    /// novelty score compares against (padded/truncated to the compiled
    /// refset); `threshold` is the max mean-kNN-distance considered
    /// plausible.
    pub fn spawn(
        artifacts_dir: PathBuf,
        reference_rows: Vec<[f32; DIM]>,
        threshold: f64,
    ) -> Result<ModelServer> {
        let (tx, rx): (Sender<Req>, Receiver<Req>) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let thread = std::thread::spawn(move || {
            let model = match PerfModel::load(&artifacts_dir) {
                Ok(m) => {
                    let _ = ready_tx.send(Ok(()));
                    m
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            serve(model, reference_rows, threshold, rx);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("model server died"))?
            .map_err(|e| anyhow::anyhow!("model server init: {e}"))?;
        Ok(ModelServer { tx, thread: Some(thread) })
    }

    /// A validator handle for node construction.
    pub fn validator(&self) -> ModelValidator {
        ModelValidator { tx: self.tx.clone() }
    }

    pub fn stop(mut self) {
        let _ = self.tx.send(Req::Stop);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn serve(model: PerfModel, reference_rows: Vec<[f32; DIM]>, threshold: f64, rx: Receiver<Req>) {
    let b = model.meta.batch;
    let r = model.meta.refset;
    // Pad/cycle the reference set to the compiled size.
    let mut refs = vec![0f32; r * DIM];
    if !reference_rows.is_empty() {
        for i in 0..r {
            let row = &reference_rows[i % reference_rows.len()];
            refs[i * DIM..(i + 1) * DIM].copy_from_slice(row);
        }
    }
    let mut structural = StatsValidator::default();
    while let Ok(req) = rx.recv() {
        match req {
            Req::Stop => return,
            Req::Score { data, reply } => {
                // Stage 1: structural validation.
                let (sv, sscore) = structural.validate(&data);
                if sv != Verdict::Valid {
                    let _ = reply.send((sv, sscore));
                    continue;
                }
                // Stage 2: learned novelty score over all rows.
                let rows = parse_contribution(&data).unwrap_or_default();
                if rows.is_empty() {
                    let _ = reply.send((Verdict::Inconclusive, 0.5));
                    continue;
                }
                let mut total = 0.0f64;
                let mut n = 0usize;
                for chunk in rows.chunks(b) {
                    let mut xs = vec![0f32; b * DIM];
                    for (i, row) in chunk.iter().enumerate() {
                        xs[i * DIM..(i + 1) * DIM].copy_from_slice(&encode_row(row));
                    }
                    match model.knn_score(&xs, &refs) {
                        Ok(scores) => {
                            for s in &scores[..chunk.len()] {
                                total += *s as f64;
                            }
                            n += chunk.len();
                        }
                        Err(_) => {
                            let _ = reply.send((Verdict::Inconclusive, 0.5));
                            n = 0;
                            break;
                        }
                    }
                }
                if n == 0 {
                    continue;
                }
                let mean = total / n as f64;
                // Monotone map distance → score in (0, 1].
                let score = 1.0 / (1.0 + mean / threshold.max(1e-9));
                let verdict = if mean <= threshold {
                    Verdict::Valid
                } else {
                    Verdict::Invalid
                };
                let _ = reply.send((verdict, score));
            }
        }
    }
}

/// Convenience: a shared server usable from several nodes in one process.
pub type SharedModelServer = Arc<Mutex<Option<ModelServer>>>;

//! Performance modeling of distributed dataflow jobs — the *consumer* of
//! the data distribution layer.
//!
//! The paper's motivation: resource-configuration optimization needs
//! runtime predictions, predictions need training data, and no single
//! organization has enough — hence collaborative sharing. This module
//! implements:
//!
//! * [`datagen`] — a synthetic workload-trace generator standing in for
//!   the C3O/scout public datasets (unavailable offline; see DESIGN.md
//!   §Substitutions). Runtime follows an Ernest-style scaling law per
//!   workload, so learnability mirrors real traces.
//! * [`features`] — trace row → feature-vector encoding shared with the
//!   JAX side (python/compile/model.py documents the identical layout).
//! * [`workflow`] — the §III-D performance-modeling workflow: assemble
//!   training data from the contributions store (+ local private data),
//!   train the AOT-compiled MLP via PJRT, evaluate, and compare
//!   collaborative vs local-only modeling.

pub mod datagen;
pub mod features;
#[cfg(feature = "pjrt")]
pub mod validator;
pub mod workflow;

//! Synthetic distributed-dataflow trace generation.
//!
//! Stands in for the paper's experiment corpus (C3O + scout traces:
//! 11,133 files averaging 9.06 KB gzip-compressed). Each contribution
//! file is a gzipped JSON document holding runtime observations of one
//! workload under varying resource configurations. Runtimes follow a
//! per-workload Ernest-style scaling law
//!
//! ```text
//! runtime(n, g, m) = α + β·(g/n)·s(m) + γ·ln(n) + δ·n + ε
//! ```
//!
//! (serial fraction, data-parallel work scaled by machine speed,
//! coordination overhead growing with the log of the cluster size, and a
//! linear per-node overhead; ε is lognormal-ish noise) — the same shape
//! used by Ernest/C3O-style predictors, so a learned model's accuracy
//! improves with more and more-diverse training data, which is exactly
//! the collaboration effect the paper wants to enable.

use crate::codec::json::Json;
use crate::util::Rng;
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;
use std::io::{Read, Write};

/// One runtime observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRow {
    pub workload_id: u32,
    pub nodes: u32,
    /// Machine class 0..N_MACHINE_CLASSES (larger = faster).
    pub machine_class: u32,
    pub dataset_gb: f64,
    pub runtime_s: f64,
}

/// Workload catalog — names follow the paper's framing (Spark/Flink jobs).
pub const WORKLOADS: [&str; 6] = [
    "spark-sort",
    "spark-grep",
    "spark-pagerank",
    "spark-kmeans",
    "flink-wordcount",
    "flink-sgd",
];

pub const N_MACHINE_CLASSES: u32 = 4;

/// Scaling-law coefficients for one workload.
#[derive(Clone, Copy, Debug)]
pub struct ScalingLaw {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub delta: f64,
}

/// Deterministic per-workload law (id-seeded so every peer generates
/// consistent physics).
pub fn law_for(workload_id: u32) -> ScalingLaw {
    let mut rng = Rng::new(0xC30_0000 + workload_id as u64);
    ScalingLaw {
        alpha: rng.f64_range(10.0, 60.0),
        beta: rng.f64_range(4.0, 20.0),
        gamma: rng.f64_range(5.0, 25.0),
        delta: rng.f64_range(0.2, 1.5),
    }
}

/// Relative speed of a machine class (class 0 slowest).
pub fn machine_speed(class: u32) -> f64 {
    1.0 / (1.0 + 0.45 * class as f64)
}

/// Ground-truth runtime (noise-free).
pub fn true_runtime(w: &ScalingLaw, nodes: u32, machine_class: u32, dataset_gb: f64) -> f64 {
    let n = nodes as f64;
    w.alpha + w.beta * (dataset_gb / n) * machine_speed(machine_class) / 0.1
        + w.gamma * n.ln()
        + w.delta * n
}

/// Sample one observation with multiplicative noise.
pub fn sample_row(rng: &mut Rng, workload_id: u32) -> TraceRow {
    let law = law_for(workload_id);
    let nodes = [2u32, 4, 8, 12, 16, 24, 32, 48, 64][rng.range(0, 9)];
    let machine_class = rng.gen_range(N_MACHINE_CLASSES as u64) as u32;
    let dataset_gb = rng.f64_range(5.0, 500.0);
    let noise = (rng.normal_ms(0.0, 0.08)).exp();
    let runtime_s = true_runtime(&law, nodes, machine_class, dataset_gb) * noise;
    TraceRow { workload_id, nodes, machine_class, dataset_gb, runtime_s }
}

/// Serialize rows into the contribution file format (gzipped JSON).
pub fn encode_contribution(workload_id: u32, rows: &[TraceRow]) -> Vec<u8> {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .set("nodes", r.nodes as u64)
                .set("mc", r.machine_class as u64)
                .set("gb", r.dataset_gb)
                .set("rt", r.runtime_s)
        })
        .collect();
    let doc = Json::obj()
        .set("workload", WORKLOADS[workload_id as usize % WORKLOADS.len()])
        .set("workload_id", workload_id as u64)
        .set("rows", Json::Arr(rows_json));
    let text = doc.to_string();
    let mut enc = GzEncoder::new(Vec::new(), Compression::default());
    enc.write_all(text.as_bytes()).expect("gzip write");
    enc.finish().expect("gzip finish")
}

/// Parse a contribution file; `None` if it is not valid gzip+json+schema.
pub fn parse_contribution(data: &[u8]) -> Option<Vec<TraceRow>> {
    let mut dec = GzDecoder::new(data);
    let mut text = String::new();
    dec.read_to_string(&mut text).ok()?;
    let doc = Json::parse(&text).ok()?;
    let workload_id = doc.get("workload_id")?.as_u64()? as u32;
    let rows = doc.get("rows")?.as_arr()?;
    let mut out = Vec::with_capacity(rows.len());
    for r in rows {
        out.push(TraceRow {
            workload_id,
            nodes: r.get("nodes")?.as_u64()? as u32,
            machine_class: r.get("mc")?.as_u64()? as u32,
            dataset_gb: r.get("gb")?.as_f64()?,
            runtime_s: r.get("rt")?.as_f64()?,
        });
    }
    Some(out)
}

/// Generate a realistic contribution: `n_rows` observations of one
/// workload, gzip-encoded (sizes land near the paper's ≈9 KB average for
/// n_rows ≈ 120).
pub fn generate_contribution(
    rng: &mut Rng,
    workload_id: u32,
    n_rows: usize,
) -> (Vec<u8>, Vec<TraceRow>) {
    let rows: Vec<TraceRow> = (0..n_rows).map(|_| sample_row(rng, workload_id)).collect();
    (encode_contribution(workload_id, &rows), rows)
}

/// Generate a *corrupted* contribution (for validation experiments):
/// a fraction of rows get NaN / negative / absurd values.
pub fn generate_corrupt_contribution(
    rng: &mut Rng,
    workload_id: u32,
    n_rows: usize,
    corrupt_frac: f64,
) -> (Vec<u8>, Vec<TraceRow>) {
    let mut rows: Vec<TraceRow> = (0..n_rows).map(|_| sample_row(rng, workload_id)).collect();
    for r in rows.iter_mut() {
        if rng.chance(corrupt_frac) {
            match rng.range(0, 3) {
                0 => r.runtime_s = -5.0,
                1 => r.runtime_s = 1.0e12, // absurd: beyond any plausible job
                _ => r.dataset_gb = 0.0,
            }
        }
    }
    (encode_contribution(workload_id, &rows), rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let (data, rows) = generate_contribution(&mut rng, 2, 50);
        let parsed = parse_contribution(&data).unwrap();
        assert_eq!(parsed.len(), rows.len());
        for (a, b) in parsed.iter().zip(&rows) {
            assert_eq!(a.nodes, b.nodes);
            assert!((a.runtime_s - b.runtime_s).abs() < 1e-6);
        }
    }

    #[test]
    fn sizes_near_paper_corpus() {
        let mut rng = Rng::new(2);
        let (data, _) = generate_contribution(&mut rng, 0, 120);
        // Paper: avg 9.06 KB compressed. Ours should be same order.
        assert!(data.len() > 2_000 && data.len() < 20_000, "size={}", data.len());
    }

    #[test]
    fn scaling_law_sane() {
        let law = law_for(0);
        // More nodes with fixed data: parallel term shrinks, overhead grows.
        let r2 = true_runtime(&law, 2, 0, 100.0);
        let r64 = true_runtime(&law, 64, 0, 100.0);
        assert!(r2 > 0.0 && r64 > 0.0);
        // Faster machines shorten runtimes.
        assert!(true_runtime(&law, 8, 3, 100.0) < true_runtime(&law, 8, 0, 100.0));
        // Deterministic.
        assert_eq!(law_for(3).alpha, law_for(3).alpha);
    }

    #[test]
    fn corrupt_rows_detectable() {
        let mut rng = Rng::new(3);
        let (data, _) = generate_corrupt_contribution(&mut rng, 1, 100, 0.5);
        let rows = parse_contribution(&data).unwrap();
        let bad = rows
            .iter()
            .filter(|r| {
                !r.runtime_s.is_finite()
                    || r.runtime_s <= 0.0
                    || r.runtime_s > 1e6
                    || r.dataset_gb <= 0.0
            })
            .count();
        assert!(bad > 20, "bad={bad}");
    }

    #[test]
    fn garbage_rejected() {
        assert!(parse_contribution(b"not gzip").is_none());
        // Valid gzip of invalid json:
        let mut enc = GzEncoder::new(Vec::new(), Compression::default());
        enc.write_all(b"{oops").unwrap();
        let data = enc.finish().unwrap();
        assert!(parse_contribution(&data).is_none());
    }
}

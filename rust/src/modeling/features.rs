//! Feature encoding shared between the Rust coordinator and the JAX
//! model. `python/compile/model.py` documents the identical layout; the
//! AOT artifacts are compiled against `DIM` features.

use crate::modeling::datagen::TraceRow;

/// Feature-vector dimensionality (must match the compiled artifacts).
pub const DIM: usize = 8;

/// Normalization constants (dataset ranges from `datagen`).
const MAX_NODES: f64 = 64.0;
const MAX_GB: f64 = 500.0;
const MAX_MC: f64 = 3.0;

/// Encode one trace row. Targets are `ln(runtime)` (see [`encode_target`])
/// which keeps the regression well-conditioned across the 1–2 orders of
/// magnitude that the scaling laws span.
pub fn encode_row(r: &TraceRow) -> [f32; DIM] {
    let n = r.nodes as f64;
    let wl = r.workload_id as f64;
    [
        (n / MAX_NODES) as f32,
        (n.ln() / MAX_NODES.ln()) as f32,
        (1.0 / n) as f32,
        (r.dataset_gb / MAX_GB) as f32,
        // Per-node data volume, normalized by its maximum (500 GB on the
        // smallest 2-node cluster).
        (r.dataset_gb / n / (MAX_GB / 2.0)) as f32,
        (r.machine_class as f64 / MAX_MC) as f32,
        // Two cheap workload-identity channels (sin/cos of id) — enough
        // for the 6-workload catalog without a full one-hot.
        (wl * 0.9).sin() as f32,
        (wl * 0.9).cos() as f32,
    ]
}

pub fn encode_target(r: &TraceRow) -> f32 {
    (r.runtime_s.max(1e-3)).ln() as f32
}

/// Invert [`encode_target`].
pub fn decode_target(y: f32) -> f64 {
    (y as f64).exp()
}

/// Encode a batch into flat row-major buffers.
pub fn encode_batch(rows: &[TraceRow]) -> (Vec<f32>, Vec<f32>) {
    let mut xs = Vec::with_capacity(rows.len() * DIM);
    let mut ys = Vec::with_capacity(rows.len());
    for r in rows {
        xs.extend_from_slice(&encode_row(r));
        ys.push(encode_target(r));
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modeling::datagen::sample_row;
    use crate::util::Rng;

    #[test]
    fn features_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let wl = rng.gen_range(6) as u32;
            let row = sample_row(&mut rng, wl);
            let f = encode_row(&row);
            for (i, v) in f.iter().enumerate() {
                assert!(v.is_finite(), "feature {i} not finite");
                assert!(v.abs() <= 8.0, "feature {i} out of range: {v}");
            }
            let y = encode_target(&row);
            assert!(y.is_finite());
            assert!((decode_target(y) - row.runtime_s).abs() / row.runtime_s < 1e-3);
        }
    }

    #[test]
    fn batch_layout() {
        let mut rng = Rng::new(2);
        let rows: Vec<_> = (0..10).map(|_| sample_row(&mut rng, 1)).collect();
        let (xs, ys) = encode_batch(&rows);
        assert_eq!(xs.len(), 10 * DIM);
        assert_eq!(ys.len(), 10);
        assert_eq!(xs[..DIM], encode_row(&rows[0]));
    }
}

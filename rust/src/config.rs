//! Typed configuration loaded from JSON files + CLI overrides — the
//! reproduction's stand-in for the paper's Helm-chart parametrization.

use crate::codec::json::Json;
use crate::dht::DhtConfig;
use crate::peersdb::NodeConfig;
use crate::util::time::Duration;
use crate::validation::quorum::QuorumConfig;
use crate::validation::CostModel;

/// Load a [`NodeConfig`] from a JSON document; missing fields keep their
/// defaults. See `examples/` and README for the schema.
pub fn node_config_from_json(j: &Json) -> Result<NodeConfig, String> {
    let mut cfg = NodeConfig::default();
    if let Some(v) = j.path("passphrase").and_then(|v| v.as_str()) {
        cfg.passphrase = v.to_string();
    }
    if let Some(v) = j.path("store_name").and_then(|v| v.as_str()) {
        cfg.store_name = v.to_string();
    }
    if let Some(v) = j.path("auto_pin").and_then(|v| v.as_bool()) {
        cfg.auto_pin = v;
    }
    if let Some(v) = j.path("auto_validate").and_then(|v| v.as_bool()) {
        cfg.auto_validate = v;
    }
    if let Some(v) = j.path("announce_providers").and_then(|v| v.as_bool()) {
        cfg.announce_providers = v;
    }
    if let Some(v) = j.path("neighbor_degree").and_then(|v| v.as_u64()) {
        cfg.neighbor_degree = v as usize;
    }
    if let Some(v) = j.path("tick_interval_ms").and_then(|v| v.as_u64()) {
        cfg.tick_interval = Duration::from_millis(v);
    }
    if let Some(v) = j.path("batch_size").and_then(|v| v.as_u64()) {
        cfg.batch_size = v.max(1) as usize;
    }
    if let Some(q) = j.path("quorum") {
        cfg.quorum = quorum_from_json(q)?;
    }
    if let Some(c) = j.path("cost_model") {
        cfg.cost_model = cost_model_from_json(c)?;
    }
    if let Some(d) = j.path("dht") {
        cfg.dht = dht_from_json(d, cfg.dht)?;
    }
    Ok(cfg)
}

fn quorum_from_json(j: &Json) -> Result<QuorumConfig, String> {
    let mut q = QuorumConfig::default();
    if let Some(v) = j.path("fanout").and_then(|v| v.as_u64()) {
        q.fanout = v as usize;
    }
    if let Some(v) = j.path("responses_needed").and_then(|v| v.as_u64()) {
        q.responses_needed = v as usize;
    }
    if let Some(v) = j.path("agreement").and_then(|v| v.as_f64()) {
        if !(0.0..=1.0).contains(&v) {
            return Err("quorum.agreement must be in [0,1]".into());
        }
        q.agreement = v;
    }
    if let Some(v) = j.path("timeout_ms").and_then(|v| v.as_u64()) {
        q.timeout = Duration::from_millis(v);
    }
    if let Some(v) = j.path("min_force_verdicts").and_then(|v| v.as_u64()) {
        q.min_force_verdicts = v as usize;
    }
    // Delayed-honest-verdict defense (defaults off; see
    // `QuorumConfig::timeout_grace`).
    if let Some(v) = j.path("timeout_grace_ms").and_then(|v| v.as_u64()) {
        q.timeout_grace = Duration::from_millis(v);
    }
    Ok(q)
}

fn dht_from_json(j: &Json, mut d: DhtConfig) -> Result<DhtConfig, String> {
    if let Some(v) = j.path("alpha").and_then(|v| v.as_u64()) {
        d.alpha = v.max(1) as usize;
    }
    if let Some(v) = j.path("k").and_then(|v| v.as_u64()) {
        d.k = v.max(1) as usize;
    }
    if let Some(v) = j.path("rpc_timeout_ms").and_then(|v| v.as_u64()) {
        d.rpc_timeout = Duration::from_millis(v);
    }
    // Eclipse-hardening knobs (defenses default off; see `dht::lookup`).
    if let Some(v) = j.path("lookup_paths").and_then(|v| v.as_u64()) {
        d.lookup_paths = v.max(1) as usize;
    }
    if let Some(v) = j.path("verify_peers").and_then(|v| v.as_bool()) {
        d.verify_peers = v;
    }
    if let Some(v) = j.path("verify_retry_ms").and_then(|v| v.as_u64()) {
        d.verify_retry = Duration::from_millis(v.max(1));
    }
    Ok(d)
}

/// Cost-model schema: `{"kind": "linear", "base_ns": ..., ...}`.
pub fn cost_model_from_json(j: &Json) -> Result<CostModel, String> {
    let kind = j
        .path("kind")
        .and_then(|v| v.as_str())
        .ok_or("cost_model.kind missing")?;
    let num = |name: &str, default: f64| -> f64 {
        j.path(name).and_then(|v| v.as_f64()).unwrap_or(default)
    };
    Ok(match kind {
        "constant" => CostModel::Constant { ns: num("ns", 1e6) as u64 },
        "linear" => CostModel::Linear {
            base_ns: num("base_ns", 1e6) as u64,
            ns_per_kb: num("ns_per_kb", 1e4),
        },
        "polynomial" => CostModel::Polynomial {
            base_ns: num("base_ns", 1e6) as u64,
            ns_per_kb: num("ns_per_kb", 1e4),
            power: num("power", 2.0),
        },
        "exponential" => CostModel::Exponential {
            base_ns: num("base_ns", 1e6) as u64,
            ns_per_kb: num("ns_per_kb", 1.0),
            growth_per_kb: num("growth_per_kb", 0.01),
            cap_ns: num("cap_ns", 60e9) as u64,
        },
        "logarithmic" => CostModel::Logarithmic {
            base_ns: num("base_ns", 1e6) as u64,
            ns_per_log_kb: num("ns_per_log_kb", 1e5),
        },
        other => return Err(format!("unknown cost model kind: {other}")),
    })
}

/// Load a node config from a file path.
pub fn load_node_config(path: &str) -> Result<NodeConfig, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
    node_config_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let cfg = node_config_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.store_name, "contributions");
        assert!(cfg.auto_pin);
        // The delay-defense knob defaults off.
        assert_eq!(cfg.quorum.timeout_grace, Duration::ZERO);
    }

    #[test]
    fn full_document() {
        let text = r#"{
            "passphrase": "secret",
            "auto_validate": true,
            "batch_size": 8,
            "quorum": {"fanout": 7, "responses_needed": 4, "agreement": 0.75, "timeout_ms": 2000,
                       "min_force_verdicts": 3, "timeout_grace_ms": 10000},
            "cost_model": {"kind": "polynomial", "base_ns": 1000, "ns_per_kb": 50, "power": 1.5},
            "dht": {"alpha": 4, "k": 16, "rpc_timeout_ms": 1500}
        }"#;
        let cfg = node_config_from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.passphrase, "secret");
        assert!(cfg.auto_validate);
        assert_eq!(cfg.batch_size, 8);
        assert_eq!(cfg.quorum.fanout, 7);
        assert_eq!(cfg.quorum.agreement, 0.75);
        assert_eq!(cfg.dht.alpha, 4);
        // Every quorum knob round-trips, including the timeout pair.
        assert_eq!(cfg.quorum.responses_needed, 4);
        assert_eq!(cfg.quorum.timeout, Duration::from_millis(2000));
        assert_eq!(cfg.quorum.min_force_verdicts, 3);
        assert_eq!(cfg.quorum.timeout_grace, Duration::from_millis(10_000));
        assert!(matches!(cfg.cost_model, CostModel::Polynomial { power, .. } if power == 1.5));
    }

    #[test]
    fn rejects_bad_values() {
        let j = Json::parse(r#"{"quorum": {"agreement": 1.5}}"#).unwrap();
        assert!(node_config_from_json(&j).is_err());
        let j = Json::parse(r#"{"cost_model": {"kind": "quantum"}}"#).unwrap();
        assert!(node_config_from_json(&j).is_err());
    }
}

//! Hierarchical timer-wheel event queue for the DES hot path.
//!
//! [`TimerWheel`] replaces the `BinaryHeap` that backed
//! [`crate::sim::des::Cluster`]'s event queue. A heap pays `O(log n)`
//! per push *and* per pop with poor locality; at city scale (1,000+
//! peers, millions of queued events) that log factor dominates the
//! event loop. The wheel exploits what DES traffic actually looks like:
//! almost every event is scheduled a few RTTs ahead, and events are
//! consumed in nondecreasing time order.
//!
//! ## Structure
//!
//! * **Near-future wheel** — `SLOTS` buckets of `SLOT_NS` nanoseconds
//!   each (≈1.05 ms slots, ≈1.07 s horizon). A push inside the horizon
//!   is an unordered `Vec::push` into its bucket: O(1), no comparisons.
//! * **Current buffer** — the cursor slot's entries, sorted once per
//!   slot *descending* by `(at, seq)` so the minimum pops from the
//!   `Vec` tail: amortized O(1) per pop, one `sort_unstable` per slot.
//!   Pushes that land in the cursor slot (or in the past — a handler
//!   scheduling "now") binary-search-insert to keep it ordered.
//! * **Overflow heap** — everything past the horizon, a plain min-heap.
//!   Each time the cursor advances one slot, entries that slid inside
//!   the horizon migrate to their bucket; when the wheel goes idle the
//!   cursor jumps straight to the overflow minimum's slot instead of
//!   scanning empty buckets.
//!
//! ## Order contract
//!
//! Pop order is **exactly** the `BinaryHeap` order: ascending `(at,
//! seq)`, where `seq` is the wheel-assigned push sequence number.
//! Sequence numbers are unique, so the order is total and any correct
//! min-queue yields the same sequence — the property `tests/prop.rs`
//! drives lockstep against a retained heap reference, and the reason
//! every pre-wheel scenario digest survives the swap byte-for-byte.
//!
//! ## Tombstones
//!
//! The DES guards events by node epoch, so a crashed node's queued
//! timers and deliveries become garbage ("tombstones") that the heap
//! could only discard at pop time. [`TimerWheel::compact`] removes them
//! in place — bucket by bucket, order preserved — which is what keeps
//! the queue bounded under sustained churn (`bank::city_scale`).

use crate::util::time::Nanos;
use std::collections::BinaryHeap;

/// Width of one wheel slot in nanoseconds (`1 << 20` ≈ 1.05 ms — a
/// power of two so slot indexing is a shift, not a division).
pub const SLOT_NS: u64 = 1 << 20;

/// Number of near-future slots. With `SLOT_NS` this spans ≈1.07 s,
/// comfortably past the DES's RTTs, egress serialization, and protocol
/// tick intervals, so steady-state traffic never touches the overflow.
pub const SLOTS: usize = 1024;

/// The wheel horizon: pushes at `wheel_start + SPAN` or later overflow.
const SPAN: u64 = SLOT_NS * SLOTS as u64;

/// One queued entry: an item plus its schedule time and the push
/// sequence number that makes the pop order total.
#[derive(Clone, Debug)]
pub struct Scheduled<T> {
    pub at: Nanos,
    pub seq: u64,
    pub item: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed so the overflow `BinaryHeap` behaves as a min-heap,
        // mirroring the `Queued` ordering the wheel replaced.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Timer-wheel min-queue ordered by `(at, seq)`. See the module docs
/// for the structure; `seq` is assigned on [`TimerWheel::push`].
pub struct TimerWheel<T> {
    /// Near-future buckets, indexed by `(at / SLOT_NS) % SLOTS`.
    slots: Vec<Vec<Scheduled<T>>>,
    /// The cursor slot's entries, sorted descending by `(at, seq)` —
    /// the minimum is at the tail.
    current: Vec<Scheduled<T>>,
    /// Entries at or past the horizon.
    overflow: BinaryHeap<Scheduled<T>>,
    /// Start of the cursor slot (multiple of `SLOT_NS`). The wheel
    /// window is `[wheel_start, wheel_start + SPAN)`.
    wheel_start: u64,
    /// Entries currently in `slots` (excluding `current` / `overflow`).
    wheel_len: usize,
    /// Total entries, the peek stash included.
    len: usize,
    /// Peeked-but-not-popped minimum ([`TimerWheel::peek`] stashes it
    /// here so peek can hand out a reference without re-deriving it).
    head: Option<Scheduled<T>>,
    /// Next push sequence number.
    seq: u64,
}

impl<T> Default for TimerWheel<T> {
    fn default() -> Self {
        TimerWheel::new()
    }
}

impl<T> TimerWheel<T> {
    pub fn new() -> Self {
        TimerWheel {
            slots: std::iter::repeat_with(Vec::new).take(SLOTS).collect(),
            current: Vec::new(),
            overflow: BinaryHeap::new(),
            wheel_start: 0,
            wheel_len: 0,
            len: 0,
            head: None,
            seq: 0,
        }
    }

    /// Total queued entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queue `item` at `at`, assigning it the next sequence number.
    pub fn push(&mut self, at: Nanos, item: T) {
        let seq = self.seq;
        self.seq += 1;
        let e = Scheduled { at, seq, item };
        self.len += 1;
        // A freshly pushed entry carries the largest `seq` ever issued,
        // so it precedes the peek stash iff its `at` is strictly
        // earlier — in which case the stash goes back into the wheel
        // and the new entry takes its place as the known minimum.
        if let Some(h) = &self.head {
            if at < h.at {
                let old = self.head.take().expect("stash checked above");
                self.head = Some(e);
                self.insert(old);
                return;
            }
        }
        self.insert(e);
    }

    /// Reference to the minimum entry, if any.
    pub fn peek(&mut self) -> Option<&Scheduled<T>> {
        if self.head.is_none() {
            self.head = self.next_internal();
        }
        self.head.as_ref()
    }

    /// Remove and return the minimum entry.
    pub fn pop(&mut self) -> Option<Scheduled<T>> {
        let e = match self.head.take() {
            Some(h) => h,
            None => self.next_internal()?,
        };
        self.len -= 1;
        Some(e)
    }

    /// Pop the minimum entry and every further entry sharing its exact
    /// timestamp into `out`, in pop order; returns the batch size. The
    /// DES drains whole same-instant batches per loop iteration —
    /// events pushed *while the batch is processed* get larger sequence
    /// numbers than every batch member, so deferring them to the next
    /// batch (even at the same timestamp) preserves the heap order.
    pub fn pop_batch(&mut self, out: &mut Vec<Scheduled<T>>) -> usize {
        let Some(first) = self.pop() else {
            return 0;
        };
        let at = first.at;
        out.push(first);
        let mut n = 1;
        while self.peek().is_some_and(|h| h.at == at) {
            out.push(self.pop().expect("peeked non-empty"));
            n += 1;
        }
        n
    }

    /// Remove every entry whose item satisfies `is_dead`, preserving
    /// the relative order of survivors; returns how many were removed.
    /// This is the tombstone compaction path: O(n) touch of every
    /// queued entry, amortized by the caller's dead-fraction trigger.
    pub fn compact(&mut self, mut is_dead: impl FnMut(&T) -> bool) -> usize {
        let before = self.len;
        if self.head.as_ref().is_some_and(|h| is_dead(&h.item)) {
            self.head = None;
        }
        self.current.retain(|e| !is_dead(&e.item));
        for slot in &mut self.slots {
            slot.retain(|e| !is_dead(&e.item));
        }
        self.overflow.retain(|e| !is_dead(&e.item));
        self.wheel_len = self.slots.iter().map(Vec::len).sum();
        self.len = self.current.len()
            + self.wheel_len
            + self.overflow.len()
            + usize::from(self.head.is_some());
        before - self.len
    }

    /// Route an entry to the current buffer, its wheel bucket, or the
    /// overflow. Entries at or before the cursor slot's end join the
    /// sorted current buffer (this also absorbs past-due pushes — a
    /// handler scheduling at "now" — which must pop before everything
    /// later).
    fn insert(&mut self, e: Scheduled<T>) {
        let at = e.at.0;
        if at < self.wheel_start + SLOT_NS {
            let key = (e.at, e.seq);
            let pos = self.current.partition_point(|x| (x.at, x.seq) > key);
            self.current.insert(pos, e);
        } else if at < self.wheel_start + SPAN {
            self.insert_slot(e);
        } else {
            self.overflow.push(e);
        }
    }

    /// Bucket an entry known to lie inside the wheel window.
    fn insert_slot(&mut self, e: Scheduled<T>) {
        let idx = (e.at.0 / SLOT_NS) as usize % SLOTS;
        self.slots[idx].push(e);
        self.wheel_len += 1;
    }

    /// Extract the global minimum (current buffer first; otherwise
    /// advance the cursor — or jump it across an idle gap — migrating
    /// overflow entries and draining the next non-empty bucket).
    /// `len` bookkeeping is the caller's job.
    fn next_internal(&mut self) -> Option<Scheduled<T>> {
        loop {
            if let Some(e) = self.current.pop() {
                return Some(e);
            }
            if self.wheel_len == 0 {
                // Idle wheel: jump the cursor straight to the overflow
                // minimum's slot instead of stepping empty buckets.
                let min_at = self.overflow.peek()?.at.0;
                self.wheel_start = min_at - (min_at % SLOT_NS);
            } else {
                self.wheel_start += SLOT_NS;
            }
            // Entries that slid inside the horizon move to buckets.
            while self.overflow.peek().is_some_and(|o| o.at.0 < self.wheel_start + SPAN) {
                let e = self.overflow.pop().expect("peeked non-empty");
                self.insert_slot(e);
            }
            let idx = (self.wheel_start / SLOT_NS) as usize % SLOTS;
            let mut v = std::mem::take(&mut self.slots[idx]);
            self.wheel_len -= v.len();
            v.sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
            self.current = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn drain(w: &mut TimerWheel<u32>) -> Vec<(u64, u64, u32)> {
        let mut out = Vec::new();
        while let Some(e) = w.pop() {
            out.push((e.at.0, e.seq, e.item));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut w = TimerWheel::new();
        w.push(Nanos(500), 0);
        w.push(Nanos(100), 1);
        w.push(Nanos(100), 2);
        w.push(Nanos(300), 3);
        let got: Vec<u32> = drain(&mut w).into_iter().map(|e| e.2).collect();
        assert_eq!(got, vec![1, 2, 3, 0], "ties broken by push order");
        assert!(w.is_empty());
    }

    #[test]
    fn crosses_slot_and_horizon_boundaries() {
        let mut w = TimerWheel::new();
        // One entry per region of the structure: cursor slot, a later
        // slot, the last slot of the window, and two overflow entries.
        let times = [
            SLOT_NS / 2,
            SLOT_NS * 3 + 7,
            SPAN - 1,
            SPAN + 5,
            SPAN * 3 + 11,
        ];
        for (i, t) in times.iter().enumerate() {
            w.push(Nanos(*t), i as u32);
        }
        let got: Vec<u64> = drain(&mut w).into_iter().map(|e| e.0).collect();
        let mut want = times.to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn idle_gap_jumps_to_overflow() {
        let mut w = TimerWheel::new();
        // Nothing inside the window; the cursor must jump, not scan.
        let far = SPAN * 1000 + SLOT_NS * 5 + 123;
        w.push(Nanos(far), 9);
        w.push(Nanos(far + 1), 10);
        assert_eq!(w.pop().unwrap().at, Nanos(far));
        assert_eq!(w.pop().unwrap().at, Nanos(far + 1));
        assert!(w.pop().is_none());
    }

    #[test]
    fn past_due_push_pops_first() {
        let mut w = TimerWheel::new();
        w.push(Nanos(SLOT_NS * 10), 0);
        // Advance the cursor to slot 10…
        assert_eq!(w.peek().unwrap().item, 0);
        // …then push into the past (a handler scheduling "now") and at
        // the peeked time: the past-due entry must still pop first.
        w.push(Nanos(3), 1);
        w.push(Nanos(SLOT_NS * 10), 2);
        let got: Vec<u32> = drain(&mut w).into_iter().map(|e| e.2).collect();
        assert_eq!(got, vec![1, 0, 2]);
    }

    #[test]
    fn pop_batch_groups_exact_timestamps() {
        let mut w = TimerWheel::new();
        w.push(Nanos(50), 0);
        w.push(Nanos(50), 1);
        w.push(Nanos(60), 2);
        let mut batch = Vec::new();
        assert_eq!(w.pop_batch(&mut batch), 2);
        assert_eq!(batch.iter().map(|e| e.item).collect::<Vec<_>>(), vec![0, 1]);
        batch.clear();
        assert_eq!(w.pop_batch(&mut batch), 1);
        assert_eq!(batch[0].item, 2);
        batch.clear();
        assert_eq!(w.pop_batch(&mut batch), 0);
        assert!(batch.is_empty());
    }

    #[test]
    fn compact_removes_dead_and_preserves_order() {
        let mut w = TimerWheel::new();
        for i in 0..100u32 {
            // Spread across slots and the overflow.
            w.push(Nanos(u64::from(i) * SLOT_NS * 17), i);
        }
        // Stash a head so compact must check it too.
        assert_eq!(w.peek().unwrap().item, 0);
        let removed = w.compact(|item| item % 3 == 0);
        assert_eq!(removed, 34, "0,3,…,99 are dead");
        assert_eq!(w.len(), 66);
        let got: Vec<u32> = drain(&mut w).into_iter().map(|e| e.2).collect();
        let want: Vec<u32> = (0..100).filter(|i| i % 3 != 0).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn matches_binary_heap_on_random_interleavings() {
        // Small in-module randomized guard; the full lockstep property
        // test (crash predicates included) lives in `tests/prop.rs`.
        let mut rng = Rng::new(0x7ee1_5eed);
        for round in 0..50 {
            let mut wheel = TimerWheel::new();
            let mut heap: BinaryHeap<Scheduled<u32>> = BinaryHeap::new();
            let mut seq = 0u64;
            let mut got = Vec::new();
            let mut want = Vec::new();
            for op in 0..400 {
                if rng.chance(0.6) {
                    let at = Nanos(rng.gen_range(SPAN * 2));
                    wheel.push(at, op);
                    heap.push(Scheduled { at, seq, item: op });
                    seq += 1;
                } else {
                    got.extend(wheel.pop().map(|e| (e.at, e.seq)));
                    want.extend(heap.pop().map(|e| (e.at, e.seq)));
                }
            }
            got.extend(std::iter::from_fn(|| wheel.pop()).map(|e| (e.at, e.seq)));
            want.extend(std::iter::from_fn(|| heap.pop()).map(|e| (e.at, e.seq)));
            assert_eq!(got, want, "diverged in round {round}");
        }
    }
}

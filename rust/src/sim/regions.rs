//! The six GCP regions of the paper's prototype deployment and an
//! approximate one-way latency matrix between them.
//!
//! Values are derived from publicly reported GCP inter-region round-trip
//! times (halved to one-way, rounded). Absolute accuracy is not required —
//! the experiments compare *relative* behaviour across regions — but the
//! ordering (e.g. São Paulo ↔ Sydney worst, Frankfurt ↔ Tel Aviv best)
//! matches the real topology.

/// Deployment regions. `Local` models a single-datacenter/Testground
/// setting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Region {
    AsiaEast2,          // Hong Kong — the paper's root peer region
    EuropeWest3,        // Frankfurt
    UsWest1,            // Oregon
    SouthamericaEast1,  // São Paulo
    MeWest1,            // Tel Aviv
    AustraliaSoutheast1, // Sydney
    Local,
}

pub const ALL: [Region; 6] = [
    Region::AsiaEast2,
    Region::EuropeWest3,
    Region::UsWest1,
    Region::SouthamericaEast1,
    Region::MeWest1,
    Region::AustraliaSoutheast1,
];

impl Region {
    pub fn name(&self) -> &'static str {
        match self {
            Region::AsiaEast2 => "asia-east2",
            Region::EuropeWest3 => "europe-west3",
            Region::UsWest1 => "us-west1",
            Region::SouthamericaEast1 => "southamerica-east1",
            Region::MeWest1 => "me-west1",
            Region::AustraliaSoutheast1 => "australia-southeast1",
            Region::Local => "local",
        }
    }

    fn index(&self) -> Option<usize> {
        ALL.iter().position(|r| r == self)
    }
}

/// One-way latency in milliseconds between region pairs (upper-triangle
/// symmetric). Intra-region latency is 0.25 ms.
const ONE_WAY_MS: [[f64; 6]; 6] = [
    // to:      HK     FRA    ORE    SAO    TLV    SYD
    /* HK  */ [0.25, 90.0, 65.0, 150.0, 110.0, 65.0],
    /* FRA */ [90.0, 0.25, 75.0, 100.0, 30.0, 140.0],
    /* ORE */ [65.0, 75.0, 0.25, 85.0, 90.0, 70.0],
    /* SAO */ [150.0, 100.0, 85.0, 0.25, 125.0, 150.0],
    /* TLV */ [110.0, 30.0, 90.0, 125.0, 0.25, 145.0],
    /* SYD */ [65.0, 140.0, 70.0, 150.0, 145.0, 0.25],
];

/// One-way base latency between two regions, in milliseconds.
pub fn one_way_ms(a: Region, b: Region) -> f64 {
    match (a.index(), b.index()) {
        (Some(i), Some(j)) => ONE_WAY_MS[i][j],
        // Local ↔ anything: treat as intra-DC.
        _ => 0.25,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric() {
        for &a in &ALL {
            for &b in &ALL {
                assert_eq!(one_way_ms(a, b), one_way_ms(b, a));
            }
        }
    }

    #[test]
    fn intra_region_small() {
        for &r in &ALL {
            assert!(one_way_ms(r, r) < 1.0);
        }
    }

    #[test]
    fn topology_ordering() {
        use Region::*;
        // Frankfurt–Tel Aviv is the closest inter-region pair;
        // São Paulo–Sydney / São Paulo–Hong Kong the farthest.
        assert!(one_way_ms(EuropeWest3, MeWest1) < one_way_ms(EuropeWest3, UsWest1));
        assert!(one_way_ms(SouthamericaEast1, AustraliaSoutheast1) >= 145.0);
    }
}

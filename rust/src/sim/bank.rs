//! The named scenario bank, as library data.
//!
//! `tests/scenarios.rs` (assertions + replay checks) and
//! `benches/sim_scale.rs` (the self-timing perf baseline that emits
//! `BENCH_sim.json`) both consume the same definitions, so a scenario's
//! shape can never drift between its correctness test and its perf
//! measurement. Seeds and schedules are stable identifiers: changing one
//! invalidates recorded `SimStats` checksums, which is exactly the
//! signal the perf-trajectory artifact is meant to carry.

use crate::peersdb::{ChunkScheduler, NodeConfig};
use crate::pubsub::MeshConfig;
use crate::sim::regions::{Region, ALL};
use crate::sim::scenario::{
    AvailabilityInvariant, EclipseInvariant, Fault, PubsubDeliveryInvariant, Scenario,
    VerdictIntegrityInvariant,
};
use crate::util::time::Duration;
use crate::validation::CostModel;

/// 1. Network partition during active contribution traffic.
pub fn partition_heal() -> Scenario {
    let mut sc = Scenario::named("partition-heal", 101, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 40 })
        // Split the cluster down the middle, root on side A.
        .at(5, Fault::Partition { a: vec![0, 1, 2, 3], b: vec![4, 5, 6, 7] })
        // Both sides keep contributing while partitioned.
        .at(7, Fault::Contribute { node: 2, workload: 1, rows: 40 })
        .at(9, Fault::Contribute { node: 5, workload: 2, rows: 40 })
        .at(11, Fault::Contribute { node: 6, workload: 3, rows: 40 })
        // Mid-partition, safety invariants must still hold.
        .at(20, Fault::Checkpoint)
        .at(30, Fault::Heal)
        .at(35, Fault::Contribute { node: 7, workload: 4, rows: 40 })
}

/// 2. Regional outage and recovery (EuropeWest3 hosts peers 1 and 7).
pub fn regional_outage() -> Scenario {
    let mut sc = Scenario::named("regional-outage", 202, 10);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(5, Fault::Outage { region: Region::EuropeWest3 })
        // The rest of the world keeps publishing during the outage.
        .at(8, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(12, Fault::Contribute { node: 4, workload: 2, rows: 30 })
        .at(20, Fault::Checkpoint)
        .at(40, Fault::Recover { region: Region::EuropeWest3 })
        .at(45, Fault::Contribute { node: 7, workload: 3, rows: 30 })
}

/// 3. Crash/restart churn while data flows.
pub fn crash_churn() -> Scenario {
    let mut sc = Scenario::named("crash-churn", 303, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(2, Fault::Crash { node: 3 })
        .at(4, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(8, Fault::Crash { node: 5 })
        .at(10, Fault::Contribute { node: 6, workload: 2, rows: 30 })
        .at(14, Fault::Restart { node: 3 })
        .at(16, Fault::Contribute { node: 3, workload: 3, rows: 30 })
        .at(20, Fault::Crash { node: 1 })
        .at(25, Fault::Restart { node: 5 })
        .at(30, Fault::Checkpoint)
        .at(35, Fault::Restart { node: 1 })
        .at(40, Fault::Contribute { node: 7, workload: 4, rows: 30 })
}

/// 4. Flash-crowd join: the cluster doubles mid-run.
pub fn flash_crowd() -> Scenario {
    let mut sc = Scenario::named("flash-crowd", 404, 5);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        // Five newcomers join through the root at the same instant.
        .at(10, Fault::FlashCrowd { n: 5, region: Region::UsWest1 })
        // Traffic continues while they bootstrap.
        .at(12, Fault::Contribute { node: 3, workload: 2, rows: 30 })
        .at(30, Fault::Checkpoint)
}

/// 5a. The CPU-strain comparison baseline (same schedule, nominal CPU).
pub fn cpu_nominal() -> Scenario {
    cpu_schedule("cpu-nominal")
}

/// 5b. Root-peer CPU strain (the paper's §IV-A artifact, injected):
/// the same schedule under a 5000× slowdown of the root's machine.
pub fn cpu_strain() -> Scenario {
    cpu_schedule("cpu-strain").at_ms(0, Fault::CpuStrain { node: 0, factor: 5000 })
}

fn cpu_schedule(name: &'static str) -> Scenario {
    let mut sc = Scenario::named(name, 505, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
        .at(4, Fault::Contribute { node: 4, workload: 1, rows: 60 })
        .at(8, Fault::Contribute { node: 6, workload: 2, rows: 60 })
        .at(60, Fault::CpuRelief { node: 0 })
}

/// 6. Byzantine validator: a lying minority must not poison verdicts.
pub fn byzantine_minority() -> Scenario {
    let mut sc = Scenario::named("byzantine-minority", 606, 8);
    sc.quiesce = Duration::from_secs(400);
    sc.stats_validators = true;
    sc.byzantine = vec![3];
    sc.cfg = NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 2_000_000, ns_per_kb: 50_000.0 },
        ..NodeConfig::default()
    };
    // With a verdict floor of 2 on timeout tallies and >50% agreement, a
    // single liar can never push a wrong verdict through a vote.
    sc.cfg.quorum.min_force_verdicts = 2;
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
        .at(5, Fault::Contribute { node: 2, workload: 1, rows: 60 })
        .at(10, Fault::ContributeCorrupt { node: 3, workload: 2, rows: 60, frac: 0.9 })
        .at(15, Fault::Contribute { node: 5, workload: 3, rows: 60 })
        .at(20, Fault::ContributeCorrupt { node: 6, workload: 4, rows: 60, frac: 0.9 })
}

/// 7. Kitchen sink: loss spike + flapping links + churn, one schedule.
pub fn kitchen_sink() -> Scenario {
    let mut sc = Scenario::named("kitchen-sink", 707, 9);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::SetLoss { loss: 0.05 })
        .at(1, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::BlockPair { a: 2, b: 5 })
        .at(5, Fault::Contribute { node: 5, workload: 1, rows: 30 })
        .at(7, Fault::Crash { node: 4 })
        .at(9, Fault::Contribute { node: 6, workload: 2, rows: 30 })
        .at(11, Fault::UnblockPair { a: 2, b: 5 })
        .at(13, Fault::BlockPair { a: 1, b: 8 })
        .at(15, Fault::Restart { node: 4 })
        .at(18, Fault::Contribute { node: 8, workload: 3, rows: 30 })
        .at(25, Fault::Checkpoint)
}

/// 8. Multi-region scale-out — the ROADMAP's "paper experiment 2 at
/// 10×": 25 initial peers rotated across all six GCP regions, then three
/// staggered flash crowds of 25 (Oregon, Frankfurt, Hong Kong) land
/// while contribution traffic continues, for 100 peers total. Bootstrap
/// time per wave is the measurement; the standard invariant set (log
/// convergence, quorum safety, routing health, availability ≥ 3) is the
/// pass condition. This cluster size is what the zero-copy block plane
/// and the allocation-free DES hot path exist for.
pub fn multi_region_scale_out() -> Scenario {
    let mut sc = Scenario::named("multi-region-scale-out", 909, 25);
    sc.quiesce = Duration::from_secs(900);
    sc.quiesce_poll = Duration::from_secs(10);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 20 })
        .at(2, Fault::Contribute { node: 4, workload: 1, rows: 20 })
        // Wave 1: nodes 25..50.
        .at(5, Fault::FlashCrowd { n: 25, region: Region::UsWest1 })
        .at(20, Fault::Contribute { node: 7, workload: 2, rows: 20 })
        // Wave 2: nodes 50..75, with more history to sync.
        .at(40, Fault::FlashCrowd { n: 25, region: Region::EuropeWest3 })
        .at(55, Fault::Contribute { node: 10, workload: 3, rows: 20 })
        // Wave 3: nodes 75..100.
        .at(80, Fault::FlashCrowd { n: 25, region: Region::AsiaEast2 })
        .at(95, Fault::Contribute { node: 13, workload: 4, rows: 20 })
        .at(100, Fault::Contribute { node: 30, workload: 5, rows: 20 })
        .at(110, Fault::Checkpoint)
}

/// Number of initial peers / flash-crowd wave size in
/// [`multi_region_scale_out`] (the bootstrap-scaling assertions slice
/// node indices by this).
pub const SCALE_OUT_WAVE: usize = 25;

/// Core cluster size in [`asymmetric_region_halfopen`] (indices
/// `0..HALFOPEN_CORE`; the root is index 0).
pub const HALFOPEN_CORE: usize = 10;
/// Size of the half-open region's flash crowd in
/// [`asymmetric_region_halfopen`] (indices `HALFOPEN_CORE..`).
pub const HALFOPEN_REGION: usize = 25;

/// 9. Asymmetric region half-open — the directional-fault headline. A
/// 25-peer region lands as one flash crowd and is *immediately* put
/// behind a half-open NAT-style link: every joiner can reach the core
/// (its `Join`s, RPCs, and announcements arrive), but nothing comes back
/// — `JoinAck`s, DHT replies, and blocks from the core are all dropped
/// on the directed core→region links. The symmetric `Partition` fault
/// cannot express this: the root *sees* the whole region knocking the
/// entire time. Bootstrap for the region stalls on join-retry until the
/// link heals at t+60 s, after which every joiner must still converge —
/// the bounded-staleness claim the test quantifies via `bootstrap_ms`.
pub fn asymmetric_region_halfopen() -> Scenario {
    let mut sc = Scenario::named("asymmetric-region-halfopen", 1111, HALFOPEN_CORE);
    sc.quiesce = Duration::from_secs(900);
    sc.quiesce_poll = Duration::from_secs(10);
    let core: Vec<usize> = (0..HALFOPEN_CORE).collect();
    let region: Vec<usize> = (HALFOPEN_CORE..HALFOPEN_CORE + HALFOPEN_REGION).collect();
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 20 })
        // The region lands as one wave…
        .at(5, Fault::FlashCrowd { n: HALFOPEN_REGION, region: Region::UsWest1 })
        // …and the same instant goes half-open (declaration order breaks
        // the tie, so the joiners exist when the fault applies): the
        // region sees the core, the core cannot answer.
        .at(5, Fault::AsymmetricPartition { a: region, b: core })
        // The core keeps publishing while the region is stalled.
        .at(10, Fault::Contribute { node: 2, workload: 1, rows: 20 })
        .at(20, Fault::Checkpoint)
        .at(30, Fault::Contribute { node: 4, workload: 2, rows: 20 })
        .at(60, Fault::Heal)
        // A freshly-admitted region peer contributes after the heal.
        .at(70, Fault::Contribute { node: HALFOPEN_CORE + 2, workload: 3, rows: 20 })
}

/// Victim node index in [`adversarial_eclipse`].
pub const ECLIPSE_VICTIM: usize = 1;
/// Colluding attacker indices in [`adversarial_eclipse`].
pub const ECLIPSE_ATTACKERS: [usize; 3] = [3, 6, 9];
/// Virtual second (after warmup) at which [`adversarial_eclipse`] heals;
/// everything scheduled earlier is the attack window (the detection test
/// truncates the schedule here to show the invariant firing).
pub const ECLIPSE_HEAL_SECS: u64 = 45;

/// 10. Adversarial eclipse — the byzantine-wire headline. Three
/// colluders forge every DHT reply they serve (`FindNodeReply` /
/// `GetProvidersReply` list only each other) while an asymmetric
/// partition makes the victim's honest RPCs time out (requests arrive,
/// replies die). The timeouts evict every honest peer from the victim's
/// routing table; only the always-answering colluders survive, so each
/// lookup the victim starts is attacker-seeded — a full eclipse. After
/// the heal the forging stops and honest lookups and announcements must
/// repopulate the victim's view: the [`EclipseInvariant`] (victim's
/// neighborhood view intersects the honest closest set) is asserted at
/// quiesce, alongside the standard convergence/availability set. Probes
/// the assumption, inherited from C3O-style collaborative optimization,
/// that every participant can trust what the discovery layer tells it.
pub fn adversarial_eclipse() -> Scenario {
    let mut sc = Scenario::named("adversarial-eclipse", 1212, 12);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.invariants.eclipse = Some(EclipseInvariant {
        victim: ECLIPSE_VICTIM,
        attackers: ECLIPSE_ATTACKERS.to_vec(),
    });
    let colluders: Vec<usize> = ECLIPSE_ATTACKERS.to_vec();
    let honest_world: Vec<usize> = (0..12)
        .filter(|i| *i != ECLIPSE_VICTIM && !ECLIPSE_ATTACKERS.contains(i))
        .collect();
    let mut sc = sc.at(0, Fault::Contribute { node: 2, workload: 0, rows: 20 });
    for &a in &ECLIPSE_ATTACKERS {
        sc = sc.at(5, Fault::ForgeDhtReplies { node: a, colluders: colluders.clone() });
    }
    sc = sc
        // The victim reaches the honest world, but no reply returns —
        // every honest RPC it sends from here on times out.
        .at(5, Fault::AsymmetricPartition { a: vec![ECLIPSE_VICTIM], b: honest_world })
        // Victim activity drives the eviction: each provide-lookup
        // queries its whole table, and the honest entries time out.
        .at(8, Fault::Contribute { node: ECLIPSE_VICTIM, workload: 1, rows: 20 })
        .at(25, Fault::Contribute { node: ECLIPSE_VICTIM, workload: 2, rows: 20 })
        // Mid-attack, the *safety* invariants must still hold.
        .at(40, Fault::Checkpoint)
        .at(ECLIPSE_HEAL_SECS, Fault::Heal);
    for &a in &ECLIPSE_ATTACKERS {
        sc = sc.at(ECLIPSE_HEAL_SECS, Fault::StopForging { node: a });
    }
    // Honest traffic after the heal gives the victim's view a way back:
    // provide-lookups query it (it answers, touching the requesters) and
    // announcements let it fetch from honest authors.
    sc.at(50, Fault::Contribute { node: 4, workload: 3, rows: 20 })
        .at(55, Fault::Contribute { node: 7, workload: 4, rows: 20 })
}

/// Disjoint lookup paths configured in [`defended_eclipse`].
pub const ECLIPSE_LOOKUP_PATHS: usize = 3;

/// 13. Defended eclipse — the eclipse *defense* headline the ROADMAP
/// called for. Exactly the [`adversarial_eclipse`] attack schedule (same
/// colluders, same forged replies, same asymmetric isolation) but
/// truncated before the heal: there is **no healed recovery tail** — no
/// post-attack honest contributions hand the victim its view back. The
/// defenses carry it instead: disjoint-path lookups
/// (`DhtConfig::lookup_paths = 3`) keep a colluding minority from
/// owning every lookup frontier, and distance-verified routing updates
/// (`DhtConfig::verify_peers`) reject lateral forged candidates,
/// quarantine hearsay peers in the `pending_verify` tier, and — the
/// recovery half — demote timed-out honest peers into that tier and
/// keep re-verifying them, so they re-enter the victim's table the
/// moment the isolation lapses. Success is the [`EclipseInvariant`]
/// holding at quiesce with `replies_forged > 0` (the attack genuinely
/// ran; the victim never stayed eclipsed), while the availability
/// repair loop (enabled here as the ROADMAP's second probe angle) keeps
/// observing non-zero provider counts via `find_providers_full`
/// throughout the attack.
///
/// Two schedule details keep the conclusion honest. First, the repair
/// loop is switched **off** cluster-wide just before the attack window
/// closes: during the quiesce the victim starts *no lookups at all*, so
/// an undefended victim would have no hearsay channel to rebuild its
/// table through — the `pending_verify` re-verification pings (which
/// run from the engine tick, independent of any lookup) are the only
/// way back, which is exactly the defense under test. Second, the
/// defenses-stripped negative control in `tests/scenarios.rs` proves
/// the same schedule fully eclipses an undefended victim by the end of
/// the attack window.
pub fn defended_eclipse() -> Scenario {
    let mut sc = adversarial_eclipse();
    sc.name = "defended-eclipse";
    sc.seed = 1515;
    // Strip the healed recovery tail: keep only the attack window
    // (everything before the heal), exactly like the PR-3 detection
    // test does — the quiesce teardown is the only heal this run gets.
    sc.events.retain(|e| e.at < Duration::from_secs(ECLIPSE_HEAL_SECS));
    sc.cfg.dht.lookup_paths = ECLIPSE_LOOKUP_PATHS;
    sc.cfg.dht.verify_peers = true;
    // The repair loop's exhaustive provider-count probes: with a 15 s
    // cadence the first cycle lands at the attack's opening instant
    // (warmup 10 s + fault offset 5 s) and every ~15 s after, so the
    // probe trace spans the whole attack window…
    sc.cfg.repair_interval = Duration::from_secs(15);
    // …and is shut down before the window closes, so recovery cannot
    // ride on repair-lookup hearsay (see the doc comment above).
    sc.at(39, Fault::SetRepair { on: false })
}

/// Nodes that deliberately unpin + GC in [`gc_pressure`] — the authors
/// of the scenario's three contributions, in contribution order (so
/// `report.cids[k]` was authored, and later dropped, by
/// `GC_PRESSURE_DROPPERS[k]`).
pub const GC_PRESSURE_DROPPERS: [usize; 3] = [1, 2, 3];

/// Nodes that deliberately unpin + GC in [`halfopen_holders`], in
/// contribution order (same indexing contract as
/// [`GC_PRESSURE_DROPPERS`]).
pub const HALFOPEN_DROPPERS: [usize; 2] = [1, 2];

/// Node configuration for the GC-pressure scenarios: automatic pinning
/// *off*, so the availability-repair loop is the only mechanism that
/// creates replicas — nothing can pass the availability invariants by
/// accident. The node-level target (5) overshoots the invariant target
/// (3) so that when the droppers strike, enough replicas exist outside
/// the dropper set for the data to be mathematically guaranteed to
/// survive.
fn repair_cfg() -> NodeConfig {
    NodeConfig {
        auto_pin: false,
        repair_interval: Duration::from_secs(8),
        replication_target: 5,
        ..NodeConfig::default()
    }
}

/// 11. GC pressure — the ROADMAP's availability-repair headline. Nine
/// peers with auto-pinning *disabled*: every data file initially lives
/// only on its author, and the repair loop (probe provider counts,
/// re-announce held data, volunteer to re-fetch under-replicated data)
/// must spread each file to the node-level replication target. Then all
/// three authors — a third of the cluster and, for their own files, the
/// original holders — deliberately unpin, withdraw their provider
/// records, and garbage-collect. Repair on the surviving nodes must
/// notice the shrunken provider counts and re-replicate from the
/// remaining holders; the droppers must never resurrect their own data.
/// At quiesce the standard replication-target invariant (≥ 3 holders)
/// and the [`AvailabilityInvariant`] (≥ 1 live honest holder) both
/// hold; the repair-disabled negative control in `tests/scenarios.rs`
/// proves the invariant genuinely fires when the loop is off.
pub fn gc_pressure() -> Scenario {
    let mut sc = Scenario::named("gc-pressure", 1313, 9);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.cfg = repair_cfg();
    sc.invariants.availability = Some(AvailabilityInvariant::default());
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(6, Fault::Contribute { node: 3, workload: 2, rows: 30 })
        // Repair has had several cycles to replicate; safety mid-run.
        .at(45, Fault::Checkpoint)
        // A third of the cluster frees its disk, authors included.
        .at(60, Fault::UnpinAndGc { node: 1 })
        .at(62, Fault::UnpinAndGc { node: 2 })
        .at(64, Fault::UnpinAndGc { node: 3 })
}

/// 12. Half-open holders — GC pressure through the directed link plane.
/// Ten peers, same repair-only replication as [`gc_pressure`]. After
/// both authors unpin + GC, the surviving replicas sit on the
/// volunteers — the bulk of them in the node group `3..10`, which
/// immediately goes half-open toward the rest of the cluster: the
/// holders' sends arrive (their re-announces keep the provider records
/// alive, making them look perfectly healthy), but nothing sent *to*
/// them gets through — `Want`s, DHT queries, and anti-entropy requests
/// from `{0, 1, 2}` all vanish. Re-replication across the boundary must
/// route around the phantom holders: fetches time out candidate by
/// candidate, succeeding only against a same-side replica (if one
/// exists) or after the link heals, after which repair finishes the job
/// and the availability invariants hold at quiesce. This is the
/// nastiest variant the ROADMAP called for: holders that *think* they
/// are reachable (their announces land) but can never hear a Want.
pub fn halfopen_holders() -> Scenario {
    let mut sc = Scenario::named("halfopen-holders", 1414, 10);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.cfg = repair_cfg();
    sc.invariants.availability = Some(AvailabilityInvariant::default());
    let holders: Vec<usize> = (3..10).collect();
    let rest: Vec<usize> = vec![0, 1, 2];
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(45, Fault::Checkpoint)
        // Both authors drop their data…
        .at(60, Fault::UnpinAndGc { node: 1 })
        .at(62, Fault::UnpinAndGc { node: 2 })
        // …and the survivors' side goes half-open the same instant:
        // announces flow out of `holders`, Wants into it die.
        .at(64, Fault::AsymmetricPartition { a: holders, b: rest })
        // Mid-fault, safety must still hold.
        .at(100, Fault::Checkpoint)
        .at(150, Fault::Heal)
}

/// Rows in the striped-transfer scenarios' one large contribution —
/// sized so the gzip'd file spans dozens of chunker blocks (≈ 10 MB at
/// ≈ 75 B/row compressed), forcing several chunk-window refills per
/// fetch. The single-block files of the other scenarios never exercise
/// striping at all.
pub const STRIPE_ROWS: usize = 140_000;

/// Initial cluster size in the striped-transfer scenarios; flash-crowd
/// joiners land at indices `STRIPE_PEERS..`.
pub const STRIPE_PEERS: usize = 6;

/// Latency multiplier on the slow author's links in [`slow_peer_drag`]
/// / [`slow_peer_drag_rr`].
pub const DRAG_FACTOR: f64 = 10.0;

/// The shared drag schedule: one multi-chunk contribution replicates to
/// the whole cluster (`announce_replicas` on, so every replica plants a
/// provider record), then two joiners land behind [`DRAG_FACTOR`]×-slow
/// links to the author. The author still answers every Want — just very
/// late — so it is exactly the provider a striped fetch should learn to
/// de-weight, and never a correctness problem a timeout would surface.
fn drag_schedule(name: &'static str, seed: u64) -> Scenario {
    let mut sc = Scenario::named(name, seed, STRIPE_PEERS);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.cfg.announce_replicas = true;
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: STRIPE_ROWS })
        // Two newcomers join once every original peer holds (and has
        // announced) the file…
        .at(60, Fault::FlashCrowd { n: 2, region: Region::UsWest1 })
        // …and the same instant (declaration order breaks the tie, so
        // the joiners exist when the fault applies) the author's links
        // to both go 10× slow.
        .at(60, Fault::SlowLink { a: 1, b: STRIPE_PEERS, factor: DRAG_FACTOR })
        .at(60, Fault::SlowLink { a: 1, b: STRIPE_PEERS + 1, factor: DRAG_FACTOR })
        .at(90, Fault::Checkpoint)
}

/// 14. Slow-peer drag — the peer-quality scheduler headline. The drag
/// schedule under [`ChunkScheduler::Quality`]: the joiners' striped
/// fetches sample one slow block from the degraded author, the EWMA
/// inflates its cost, and the remaining stripes land on the five fast
/// replicas — the joiners' time-to-replicate barely notices the drag.
/// The negative control [`slow_peer_drag_rr`] shows what ignoring the
/// observation costs; `tests/scenarios.rs` asserts the gap.
pub fn slow_peer_drag() -> Scenario {
    let mut sc = drag_schedule("slow-peer-drag", 1616);
    sc.cfg.chunk_scheduler = ChunkScheduler::Quality;
    sc
}

/// 15. Slow-peer drag, round-robin control: the identical schedule under
/// [`ChunkScheduler::RoundRobin`], which keeps dealing every Nth chunk
/// to the 10×-slow author no matter what it observes. Exists so the
/// quality scheduler's win in [`slow_peer_drag`] is measured against a
/// striping baseline, not against the single-source fetcher.
pub fn slow_peer_drag_rr() -> Scenario {
    let mut sc = drag_schedule("slow-peer-drag-rr", 1717);
    sc.cfg.chunk_scheduler = ChunkScheduler::RoundRobin;
    sc
}

/// 16. Provider death mid-transfer — the reassignment headline. Same
/// replicate-then-join shape as the drag pair, but moments after the
/// joiner lands, a replica holding an announced provider record
/// crashes. The record outlives the corpse in the DHT, so the joiner's
/// quality scheduler assigns stripes to a dead peer: those Wants must
/// time out and the chunks be reassigned to live providers
/// (`transfer_reassignments > 0`), completing the fetch — the
/// fetch-stall invariant at quiesce is the pass condition. The crashed
/// replica returns well before quiesce so convergence is unaffected.
pub fn provider_death_midtransfer() -> Scenario {
    let mut sc = Scenario::named("provider-death-midtransfer", 1818, STRIPE_PEERS);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.cfg.announce_replicas = true;
    sc.cfg.chunk_scheduler = ChunkScheduler::Quality;
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: STRIPE_ROWS })
        .at(60, Fault::FlashCrowd { n: 1, region: Region::UsWest1 })
        // 600 ms in — the joiner has synced the log and is a few chunk
        // waves into the file (a ~40-chunk fetch started around t+60.4
        // cannot finish before t+61) — a replica dies. Its provider
        // record stays behind in the DHT either way, so stripes land on
        // the corpse whether they were in flight at the crash or
        // assigned after it.
        .at_ms(60_600, Fault::Crash { node: 2 })
        .at(90, Fault::Checkpoint)
        .at(120, Fault::Restart { node: 2 })
}

/// Initial cluster size in [`delayed_honest_majority`]; the flash-crowd
/// victim joins at index [`DELAY_VOTER`].
pub const DELAY_PEERS: usize = 6;

/// The colluding liars in [`delayed_honest_majority`] — a *majority* of
/// the victim's 6-peer vote sample (the other 2 sampled peers are the
/// honest-but-slow [`DELAY_HONEST`]).
pub const DELAY_BYZANTINE: [usize; 4] = [1, 2, 3, 4];

/// The honest early validators in [`delayed_honest_majority`], placed
/// behind [`DELAY_FACTOR`]×-slow links to the late voter.
pub const DELAY_HONEST: [usize; 2] = [0, 5];

/// The late joiner whose vote the colluders dominate (first flash-crowd
/// index after the initial [`DELAY_PEERS`]).
pub const DELAY_VOTER: usize = DELAY_PEERS;

/// Latency multiplier on the voter↔honest links in
/// [`delayed_honest_majority`]. At 60× the honest ValQuery→ValReply
/// round trip over the UsWest1↔AsiaEast2 / UsWest1↔AustraliaSoutheast1
/// legs (≈ 65–70 ms one-way nominal) lands around 8 s — past the 5 s
/// vote timeout but comfortably inside the grace window: *late, not
/// lost*, which is the whole attack.
pub const DELAY_FACTOR: f64 = 60.0;

/// The grace granted by the defended scenario's knob (30 s: well past
/// the ~8 s late honest replies, well short of the quiesce tail).
pub const DELAY_GRACE: Duration = Duration(30_000_000_000);

/// 17. Delayed honest majority — the quorum-safety-envelope headline,
/// pinned at the cliff edge named by `benches/quorum_envelope.rs`
/// (`BENCH_quorum.json`). Six peers; four are byzantine, including the
/// author of the schedule's one **clean** contribution. The first-wave
/// votes are deterministic non-events: nobody holds a verdict inside
/// anyone's 5 s vote window, so every early vote collects only empty
/// answers, force-tallies `Inconclusive`, and falls back to local
/// validation (honest → `Valid`, liars → `Invalid`). Then the victim
/// joins: a flash-crowd peer whose links to *both* honest validators go
/// [`DELAY_FACTOR`]×-slow the same instant. Its auto-validation vote
/// samples the whole cluster — four prompt unanimous lies arrive in
/// ~300 ms; the two honest `Valid`s are ~8 s out. At the timeout the
/// force tally would see 4/4 `Invalid`: over the `min_force_verdicts`
/// floor of 2, unanimity over the 0.85 agreement bar — a clean file
/// poisoned as a `ValidationSource::Network` verdict. With
/// [`QuorumConfig::timeout_grace`] on, the vote is instead extended
/// once; the first late honest `Valid` completes the 5-verdict quorum,
/// where 4/5 = 0.8 misses the 0.85 agreement bar → `Inconclusive` →
/// local validation says `Valid`. The [`VerdictIntegrityInvariant`]
/// holds and `votes_rescued_by_grace > 0`; the knob-stripped negative
/// control in `tests/scenarios.rs` proves the same schedule swallows
/// the lie without the grace (`false_verdicts_adopted > 0`).
///
/// [`QuorumConfig::timeout_grace`]: crate::validation::quorum::QuorumConfig::timeout_grace
pub fn delayed_honest_majority() -> Scenario {
    let mut sc = Scenario::named("delayed-honest-majority", 1919, DELAY_PEERS);
    sc.quiesce = Duration::from_secs(400);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.stats_validators = true;
    sc.byzantine = DELAY_BYZANTINE.to_vec();
    sc.cfg = NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 2_000_000, ns_per_kb: 50_000.0 },
        ..NodeConfig::default()
    };
    // The cliff-edge cell: sample the whole cluster, demand all-but-one
    // verdicts, with an agreement bar the 4 colluders can only clear
    // while the honest verdicts are still in flight.
    sc.cfg.quorum.fanout = DELAY_PEERS;
    sc.cfg.quorum.responses_needed = DELAY_PEERS - 1;
    sc.cfg.quorum.agreement = 0.85;
    sc.cfg.quorum.min_force_verdicts = 2;
    sc.cfg.quorum.timeout_grace = DELAY_GRACE;
    sc.invariants.verdict_integrity = Some(VerdictIntegrityInvariant);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 40 })
        // The victim joins once every original peer holds a local
        // verdict, and the same instant (declaration order breaks the
        // tie) its links to both honest validators go slow — the data
        // fetch and the four lies still travel fast byzantine links.
        .at(30, Fault::FlashCrowd { n: 1, region: Region::UsWest1 })
        .at(30, Fault::SlowLink { a: DELAY_VOTER, b: DELAY_HONEST[0], factor: DELAY_FACTOR })
        .at(30, Fault::SlowLink { a: DELAY_VOTER, b: DELAY_HONEST[1], factor: DELAY_FACTOR })
        // Restore the links only after the vote (extended or not) must
        // have resolved — the slow window outliving the schedule is what
        // keeps teardown's global heal from rescuing the attack early.
        .at(240, Fault::SlowLink { a: DELAY_VOTER, b: DELAY_HONEST[0], factor: 1.0 })
        .at(240, Fault::SlowLink { a: DELAY_VOTER, b: DELAY_HONEST[1], factor: 1.0 })
}

/// 18. Parity: partition/heal under churn — the sim-to-real flagship.
/// Everything here lowers onto the TCP driver: the partition becomes
/// per-direction frame-drop rules, the slow link becomes per-frame
/// pacing, the crash/restart cycle becomes real thread stop/spawn, and
/// the flash-crowd joiner is a freshly spawned node bootstrapping
/// through the root. Contributions land on both sides of the split (one
/// on a crashed-then-restarted node's side), so convergence genuinely
/// depends on the post-heal anti-entropy path in both worlds. Sized for
/// a real-clock run: 6 peers + 1 joiner, last fault at t+13 s.
/// `sim::parity::differential` runs this schedule in the DES *and* over
/// loopback TCP and asserts the two `ConvergenceReport`s are equal.
pub fn parity_partition() -> Scenario {
    let mut sc = Scenario::named("parity-partition-heal", 2020, 6);
    sc.parity = true;
    sc.warmup = Duration::from_secs(5);
    sc.quiesce = Duration::from_secs(300);
    sc.quiesce_poll = Duration::from_secs(2);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(1, Fault::Crash { node: 3 })
        .at(2, Fault::Partition { a: vec![0, 1, 2, 3], b: vec![4, 5] })
        // Both sides keep publishing while split (node 3 is down).
        .at(3, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(4, Fault::Contribute { node: 4, workload: 2, rows: 30 })
        .at(5, Fault::SlowLink { a: 0, b: 5, factor: 4.0 })
        .at(7, Fault::Restart { node: 3 })
        .at(8, Fault::Heal)
        .at(10, Fault::FlashCrowd { n: 1, region: Region::UsWest1 })
        .at(11, Fault::Contribute { node: 5, workload: 3, rows: 30 })
        .at(13, Fault::Checkpoint)
}

/// 19. Parity: GC-pressure repair. The [`gc_pressure`] story shrunk to
/// a timing-free fixed point the parity harness can differentially
/// check: auto-pin off, one author contributes twice, repair (node
/// target = the whole cluster, so *which* peers replicate is not a
/// race) spreads both files everywhere, then the author drops and GCs
/// them. Repair on the survivors must leave every non-dropper holding
/// both files; the dropper — who authored everything it ever held, so
/// its `dropped` set is deterministic — holds nothing.
pub fn parity_gc_repair() -> Scenario {
    let mut sc = Scenario::named("parity-gc-repair", 2121, 7);
    sc.parity = true;
    sc.warmup = Duration::from_secs(5);
    sc.quiesce = Duration::from_secs(300);
    sc.quiesce_poll = Duration::from_secs(2);
    sc.cfg.auto_pin = false;
    sc.cfg.repair_interval = Duration::from_secs(2);
    sc.cfg.replication_target = 7;
    sc.invariants.availability = Some(AvailabilityInvariant::default());
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(2, Fault::Contribute { node: 1, workload: 1, rows: 30 })
        // Seven repair cycles later every peer holds both files; the
        // author frees its disk and must never resurrect the data.
        .at(16, Fault::UnpinAndGc { node: 1 })
}

/// 20. Parity: quorum validation with a byzantine minority. Stats
/// validators everywhere, one liar (node 3), clean and corrupt
/// contributions from three different authors. With the verdict floor
/// of 2 on timeout tallies, the single liar can never push a wrong
/// verdict through a vote in either world, so every honest non-author
/// converges to the ground-truth verdict — a per-peer, per-file outcome
/// the differential check compares directly (authors never
/// self-validate and are expected to hold *no* verdict; the liar's
/// store is masked). [`VerdictIntegrityInvariant`] guards both runs.
pub fn parity_quorum() -> Scenario {
    let mut sc = Scenario::named("parity-quorum", 2222, 7);
    sc.parity = true;
    sc.warmup = Duration::from_secs(5);
    sc.quiesce = Duration::from_secs(300);
    sc.quiesce_poll = Duration::from_secs(2);
    sc.stats_validators = true;
    sc.byzantine = vec![3];
    sc.cfg.auto_validate = true;
    sc.cfg.quorum.min_force_verdicts = 2;
    sc.invariants.verdict_integrity = Some(VerdictIntegrityInvariant);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
        .at(3, Fault::ContributeCorrupt { node: 2, workload: 1, rows: 60, frac: 0.9 })
        .at(6, Fault::Contribute { node: 5, workload: 2, rows: 60 })
}

/// Initial peer count in [`city_scale`].
pub const CITY_INITIAL: usize = 256;
/// Flash-crowd wave size in [`city_scale`] — one wave lands per region,
/// so the final population is `CITY_INITIAL + 6 * CITY_WAVE` = 1,006.
pub const CITY_WAVE: usize = 125;
/// Number of crash/restart churn cycles in [`city_scale`]. Targets walk
/// `1 + (7k) % 200` over the initial population — 7 is coprime to 200,
/// so all forty targets are distinct and node 0 (the bootstrap root) is
/// never touched.
pub const CITY_CHURN_CYCLES: u64 = 40;

/// 21. City-scale churn — the ROADMAP's order-of-magnitude proof point
/// for the timer-wheel DES core: 256 initial peers rotated across all
/// six regions, then six flash crowds of 125 (one per region) land in
/// the first minute for 1,006 peers total. While the crowds are still
/// bootstrapping, forty crash/restart cycles sweep the initial
/// population (each victim down for 25 s, up to ~15 concurrently
/// offline), then an entire region blacks out for 30 s and heals.
/// Contribution traffic runs before, during, and after the outage.
/// Repair runs on a 60 s cadence with 50% deterministic per-node phase
/// jitter — this is the bank's replay-checked jittered scenario, and
/// the churn is what exercises tombstone compaction and the
/// [`PeerQuality`](crate::peersdb::PeerQuality) bounds under sustained
/// join/leave. Standard invariant set at quiesce.
pub fn city_scale() -> Scenario {
    let mut sc = Scenario::named("city-scale", 2323, CITY_INITIAL);
    sc.stagger = Duration::from_millis(50);
    sc.warmup = Duration::from_secs(30);
    sc.quiesce = Duration::from_secs(900);
    sc.quiesce_poll = Duration::from_secs(15);
    sc.cfg.repair_interval = Duration::from_secs(60);
    sc.cfg.repair_jitter = 0.5;
    // Contributions from initial nodes outside the churn target set;
    // node 5 (AustraliaSoutheast1) keeps publishing mid-outage.
    sc = sc
        .at(0, Fault::Contribute { node: 2, workload: 0, rows: 20 })
        .at(5, Fault::Contribute { node: 3, workload: 1, rows: 20 });
    // Six flash-crowd waves, one per region, 10 s apart.
    for (w, region) in ALL.iter().enumerate() {
        sc = sc.at(10 * w as u64, Fault::FlashCrowd { n: CITY_WAVE, region: *region });
    }
    sc = sc
        .at(15, Fault::Contribute { node: 5, workload: 2, rows: 20 })
        .at(45, Fault::Contribute { node: 9, workload: 3, rows: 20 });
    // Sustained churn: one crash per second for 40 s, each node
    // restarted 25 s later (all restarts land before the outage).
    for k in 0..CITY_CHURN_CYCLES {
        let node = 1 + (7 * k as usize) % 200;
        sc = sc
            .at(60 + k, Fault::Crash { node })
            .at(85 + k, Fault::Restart { node });
    }
    sc.at(70, Fault::Contribute { node: 10, workload: 4, rows: 20 })
        .at(110, Fault::Checkpoint)
        .at(130, Fault::Outage { region: Region::EuropeWest3 })
        .at(135, Fault::Contribute { node: 5, workload: 5, rows: 20 })
        .at(160, Fault::Recover { region: Region::EuropeWest3 })
        .at(165, Fault::Contribute { node: 10, workload: 6, rows: 20 })
}

/// Initial peer count in the broadcast pair
/// ([`mesh_broadcast_churn`] / [`flood_broadcast_churn`]).
pub const BROADCAST_INITIAL: usize = 251;
/// Flash-crowd wave size in the broadcast pair — two waves land, so the
/// final population is `BROADCAST_INITIAL + 2 * BROADCAST_WAVE` = 501.
pub const BROADCAST_WAVE: usize = 125;
/// Crash/restart churn cycles in the broadcast pair. Targets walk
/// `20 + (7k) % 200` over the initial population — all thirty are
/// distinct, start at 20 (clear of the root and every publisher), and
/// each victim is down for 15 s while announcements broadcast.
pub const BROADCAST_CHURN_CYCLES: u64 = 30;

/// The broadcast pair's churn targets, in schedule order — also the
/// exempt set of its [`PubsubDeliveryInvariant`]: a crash wipes the
/// victim's local pubsub delivery record, so full delivery is asserted
/// over everyone *else*.
pub fn broadcast_churn_targets() -> Vec<usize> {
    (0..BROADCAST_CHURN_CYCLES).map(|k| 20 + (7 * k as usize) % 200).collect()
}

/// The shared broadcast-pair schedule: two flash crowds to 501 peers,
/// thirty crash/restart cycles, and five contribution announcements
/// published from distinct untouched nodes while the churn runs.
fn broadcast_schedule(mut sc: Scenario) -> Scenario {
    sc.stagger = Duration::from_millis(20);
    sc.warmup = Duration::from_secs(30);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(15);
    // A dense pubsub fabric: sample (nearly) the whole routing table
    // instead of the default 8. Under the default sparse sample the
    // 1 s resampling keeps flood edges so short-lived that flooding is
    // *accidentally* cheap — and occasionally misses a node outright.
    // Widening the sample makes the fabric the comparison assumes:
    // flood fan-in approaches the table size (so its full-delivery
    // half of the pair is robust and its duplicate factor shows the
    // true cost), while the mesh rows stay pinned at the watermarks
    // whatever the fabric density — that contrast is the point.
    sc.cfg.neighbor_degree = 64;
    sc.invariants.pubsub_delivery =
        Some(PubsubDeliveryInvariant { exempt: broadcast_churn_targets() });
    sc = sc
        .at(5, Fault::FlashCrowd { n: BROADCAST_WAVE, region: Region::UsWest1 })
        .at(15, Fault::FlashCrowd { n: BROADCAST_WAVE, region: Region::EuropeWest3 });
    for k in 0..BROADCAST_CHURN_CYCLES {
        let node = 20 + (7 * k as usize) % 200;
        sc = sc
            .at(55 + 2 * k, Fault::Crash { node })
            .at(70 + 2 * k, Fault::Restart { node });
    }
    sc.at(60, Fault::Contribute { node: 2, workload: 0, rows: 20 })
        .at(70, Fault::Contribute { node: 3, workload: 1, rows: 20 })
        .at(80, Fault::Contribute { node: 5, workload: 2, rows: 20 })
        .at(95, Fault::Contribute { node: 7, workload: 3, rows: 20 })
        .at(105, Fault::Contribute { node: 11, workload: 4, rows: 20 })
        .at(125, Fault::Checkpoint)
}

/// 22. Gossip-mesh broadcast under churn — the mesh's proof point. 501
/// peers (251 initial + two 125-peer flash crowds), thirty crash/restart
/// cycles sweeping the initial population, and five contribution
/// announcements published *during* the churn. Runs with the
/// [`MeshConfig`] knob on: eager push to a bounded-degree mesh, lazy
/// IHAVE/IWANT to the rest. The [`PubsubDeliveryInvariant`] asserts
/// every live non-churned subscriber received every announcement —
/// bounded redundancy must not cost delivery; `tests/scenarios.rs`
/// additionally asserts the redundancy factor sits an integer factor
/// below [`flood_broadcast_churn`]'s on the identical schedule.
pub fn mesh_broadcast_churn() -> Scenario {
    let mut sc = Scenario::named("mesh-broadcast-churn", 2424, BROADCAST_INITIAL);
    sc.cfg.mesh = Some(MeshConfig::default());
    broadcast_schedule(sc)
}

/// 23. Flood broadcast under churn — the negative control for
/// [`mesh_broadcast_churn`]: the identical 501-peer schedule with the
/// mesh knob off. Over the pair's deliberately dense fabric flood also
/// delivers fully (that is what makes the comparison fair); what it
/// cannot do is bound the duplicate factor — every subscriber receives
/// a copy per inbound edge, so redundancy tracks the fan-in. That
/// blow-up is the collapse the paired test enforces.
pub fn flood_broadcast_churn() -> Scenario {
    let sc = Scenario::named("flood-broadcast-churn", 2525, BROADCAST_INITIAL);
    broadcast_schedule(sc)
}

/// 24. City-scale churn with the gossip mesh on — [`city_scale`]'s
/// schedule verbatim (same waves, churn, outage, and contribution
/// traffic) under mesh dissemination, so the two `BENCH_sim.json` rows
/// differ in exactly one knob and the `pubsub_redundancy` column reads
/// as a controlled before/after. The mesh is tuned to the announcement
/// workload: a single-member eager spine (degree 1, watermarks 1/2)
/// with the lazy IHAVE/IWANT tier carrying the rest — head
/// announcements are latency-tolerant (anti-entropy backstops them),
/// so the thinnest mesh that still guarantees delivery is the honest
/// duplicate-factor floor to hold flood against. The broadcast pair
/// exercises the gossipsub-classic 3/2/6 shape; this row shows the
/// knob's other end.
pub fn city_scale_mesh() -> Scenario {
    let mut sc = city_scale();
    sc.name = "city-scale-mesh";
    sc.seed = 2626;
    sc.cfg.mesh = Some(MeshConfig {
        degree: 1,
        degree_low: 1,
        degree_high: 2,
        ..MeshConfig::default()
    });
    sc
}

/// Every replayable bank scenario, in canonical order: the seven
/// original fault scenarios, the multi-region scale-out headline, the
/// two directional-plane scenarios (half-open region, eclipse), the two
/// GC-pressure repair scenarios, the defended eclipse, the three
/// striped-transfer scenarios (drag pair + provider death), the
/// quorum-grace delayed-honest-majority scenario, the three
/// parity-tagged scenarios the sim-to-real harness replays over TCP,
/// the 1,006-peer city-scale churn scenario, the 501-peer gossip-mesh
/// broadcast pair (mesh + flood control), and the mesh-enabled
/// city-scale variant.
pub fn all() -> Vec<Scenario> {
    vec![
        partition_heal(),
        regional_outage(),
        crash_churn(),
        flash_crowd(),
        cpu_strain(),
        byzantine_minority(),
        kitchen_sink(),
        multi_region_scale_out(),
        asymmetric_region_halfopen(),
        adversarial_eclipse(),
        gc_pressure(),
        halfopen_holders(),
        defended_eclipse(),
        slow_peer_drag(),
        slow_peer_drag_rr(),
        provider_death_midtransfer(),
        delayed_honest_majority(),
        parity_partition(),
        parity_gc_repair(),
        parity_quorum(),
        city_scale(),
        mesh_broadcast_churn(),
        flood_broadcast_churn(),
        city_scale_mesh(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_names_and_seeds_are_unique() {
        let bank = all();
        let mut names: Vec<&str> = bank.iter().map(|s| s.name).collect();
        let mut seeds: Vec<u64> = bank.iter().map(|s| s.seed).collect();
        names.sort();
        names.dedup();
        seeds.sort();
        seeds.dedup();
        assert_eq!(names.len(), bank.len(), "duplicate scenario name");
        assert_eq!(seeds.len(), bank.len(), "duplicate scenario seed");
    }

    #[test]
    fn eclipse_shape_is_consistent() {
        let sc = adversarial_eclipse();
        let ec = sc.invariants.eclipse.as_ref().expect("eclipse invariant configured");
        assert_eq!(ec.victim, ECLIPSE_VICTIM);
        assert!(!ec.attackers.contains(&ec.victim), "victim cannot collude");
        // Every forging fault names an attacker from the invariant's list,
        // colludes only with attackers, and is healed before quiesce.
        let mut forged = Vec::new();
        let mut stopped = Vec::new();
        for ev in &sc.events {
            match &ev.fault {
                Fault::ForgeDhtReplies { node, colluders } => {
                    assert!(ec.attackers.contains(node));
                    assert!(colluders.iter().all(|c| ec.attackers.contains(c)));
                    forged.push(*node);
                }
                Fault::StopForging { node } => stopped.push(*node),
                _ => {}
            }
        }
        forged.sort();
        stopped.sort();
        assert_eq!(forged, ec.attackers.to_vec(), "all attackers forge");
        assert_eq!(forged, stopped, "every forger is stopped before quiesce");
    }

    #[test]
    fn defended_eclipse_is_the_attack_schedule_minus_the_tail() {
        let attack = adversarial_eclipse();
        let defended = defended_eclipse();
        // Defenses on, plus the repair-probe angle.
        assert_eq!(defended.cfg.dht.lookup_paths, ECLIPSE_LOOKUP_PATHS);
        assert!(defended.cfg.dht.verify_peers);
        assert!(defended.cfg.repair_interval.0 > 0);
        // Same victim/attackers under the same invariant.
        let (a, d) = (
            attack.invariants.eclipse.as_ref().unwrap(),
            defended.invariants.eclipse.as_ref().unwrap(),
        );
        assert_eq!(a.victim, d.victim);
        assert_eq!(a.attackers, d.attackers);
        // The schedule is the attack window verbatim — every attack
        // event before the heal, nothing at or after it (no recovery
        // tail) — plus exactly one extra event: the repair shutdown that
        // guarantees no lookup traffic exists for recovery to ride on.
        let window: Vec<String> = attack
            .events
            .iter()
            .filter(|e| e.at < Duration::from_secs(ECLIPSE_HEAL_SECS))
            .map(|e| format!("{:?}@{}", e.fault, e.at.0))
            .collect();
        let mut defended_events: Vec<String> = Vec::new();
        let mut repair_shutdowns = 0;
        for e in &defended.events {
            if matches!(e.fault, Fault::SetRepair { on: false }) {
                repair_shutdowns += 1;
                continue;
            }
            defended_events.push(format!("{:?}@{}", e.fault, e.at.0));
        }
        assert_eq!(window, defended_events, "defended schedule drifted from the attack");
        assert_eq!(repair_shutdowns, 1, "repair must be shut down before quiesce");
        assert!(
            defended.events.iter().all(|e| e.at < Duration::from_secs(ECLIPSE_HEAL_SECS)),
            "a healed recovery tail sneaked in"
        );
    }

    #[test]
    fn defenses_default_off_outside_defended_eclipse() {
        // Replay-compatibility guard: every pre-hardening scenario keeps
        // lookup_paths = 1 and verify_peers off, so its SimStats (and
        // checksum) are bit-identical to the pre-refactor recordings.
        for sc in all() {
            if sc.name == "defended-eclipse" {
                continue;
            }
            assert_eq!(sc.cfg.dht.lookup_paths, 1, "{}: multipath leaked in", sc.name);
            assert!(!sc.cfg.dht.verify_peers, "{}: verification leaked in", sc.name);
        }
    }

    #[test]
    fn jitter_default_off_outside_city_scale() {
        // Replay-compatibility guard: repair-phase jitter shifts every
        // repair timestamp, so any pre-existing scenario picking it up
        // would change its recorded SimStats checksum.
        for sc in all() {
            if sc.name == "city-scale" || sc.name == "city-scale-mesh" {
                assert!(sc.cfg.repair_jitter > 0.0, "{} must jitter repair", sc.name);
                continue;
            }
            assert_eq!(sc.cfg.repair_jitter, 0.0, "{}: repair jitter leaked in", sc.name);
        }
    }

    #[test]
    fn mesh_default_off_outside_mesh_scenarios() {
        // Replay-compatibility guard: the mesh knob changes every pubsub
        // frame a node emits, so any pre-existing scenario picking it up
        // would change its recorded SimStats checksum.
        for sc in all() {
            match sc.name {
                "mesh-broadcast-churn" | "city-scale-mesh" => {
                    assert!(sc.cfg.mesh.is_some(), "{}: mesh knob must be on", sc.name)
                }
                _ => assert!(sc.cfg.mesh.is_none(), "{}: mesh knob leaked in", sc.name),
            }
        }
    }

    #[test]
    fn broadcast_pair_shapes_are_consistent() {
        // The pair differs in the mesh knob (and seed) only: the
        // redundancy comparison is schedule-for-schedule.
        let mesh = mesh_broadcast_churn();
        let flood = flood_broadcast_churn();
        assert!(mesh.cfg.mesh.is_some(), "mesh row must run the mesh");
        assert!(flood.cfg.mesh.is_none(), "control must flood");
        let fmt = |sc: &Scenario| {
            sc.events.iter().map(|e| format!("{:?}@{}", e.fault, e.at.0)).collect::<Vec<_>>()
        };
        assert_eq!(fmt(&mesh), fmt(&flood), "flood control drifted from the mesh schedule");
        for sc in [&mesh, &flood] {
            let joins: usize = sc
                .events
                .iter()
                .map(|e| match e.fault {
                    Fault::FlashCrowd { n, .. } => n,
                    _ => 0,
                })
                .sum();
            assert_eq!(sc.peers, BROADCAST_INITIAL);
            assert!(sc.peers + joins > 500, "{}: the pair must exceed 500 peers", sc.name);
            assert_eq!(
                sc.cfg.neighbor_degree, 64,
                "{}: the pair runs on the dense fabric (see broadcast_schedule)",
                sc.name
            );
            let pd =
                sc.invariants.pubsub_delivery.as_ref().expect("delivery invariant configured");
            assert_eq!(pd.exempt, broadcast_churn_targets(), "{}: exempt ≠ churn set", sc.name);
            // Publishers are untouched by churn (and are not the root):
            // their announcements are the ones full delivery is sworn on.
            let publishers: Vec<usize> = sc
                .events
                .iter()
                .filter_map(|e| match e.fault {
                    Fault::Contribute { node, .. } => Some(node),
                    _ => None,
                })
                .collect();
            assert!(publishers.len() >= 5, "{}: needs broadcast traffic", sc.name);
            for p in &publishers {
                assert!(*p != 0, "{}: the root must not publish", sc.name);
                assert!(!pd.exempt.contains(p), "{}: publisher {p} is churned", sc.name);
            }
            // Every crash restarts later; all targets distinct initial
            // peers inside the exempt set.
            let crashes: Vec<(u64, usize)> = sc
                .events
                .iter()
                .filter_map(|e| match e.fault {
                    Fault::Crash { node } => Some((e.at.0, node)),
                    _ => None,
                })
                .collect();
            assert_eq!(crashes.len(), BROADCAST_CHURN_CYCLES as usize);
            let mut targets: Vec<usize> = crashes.iter().map(|&(_, n)| n).collect();
            targets.sort_unstable();
            targets.dedup();
            assert_eq!(
                targets.len(),
                BROADCAST_CHURN_CYCLES as usize,
                "{}: churn targets must be distinct",
                sc.name
            );
            for &(at, node) in &crashes {
                assert!(node < BROADCAST_INITIAL, "{}: churn must hit initial peers", sc.name);
                assert!(
                    sc.events.iter().any(|e| matches!(
                        e.fault, Fault::Restart { node: r } if r == node && e.at.0 > at
                    )),
                    "{}: node {node} never restarts",
                    sc.name
                );
            }
        }
    }

    #[test]
    fn city_scale_mesh_matches_flood_schedule() {
        // The mesh variant is city-scale verbatim apart from the knob
        // (and seed): the BENCH_sim.json before/after is controlled.
        let flood = city_scale();
        let mesh = city_scale_mesh();
        let fmt = |sc: &Scenario| {
            sc.events.iter().map(|e| format!("{:?}@{}", e.fault, e.at.0)).collect::<Vec<_>>()
        };
        assert_eq!(fmt(&mesh), fmt(&flood), "mesh variant drifted from city-scale");
        assert_eq!(mesh.peers, flood.peers);
        assert_eq!(mesh.cfg.repair_jitter, flood.cfg.repair_jitter);
        assert_eq!(
            mesh.cfg.neighbor_degree, flood.cfg.neighbor_degree,
            "city pair shares the default sparse fabric — the knob is the mesh alone"
        );
        assert_ne!(mesh.seed, flood.seed);
        assert!(mesh.cfg.mesh.is_some());
        assert!(
            mesh.invariants.pubsub_delivery.is_none(),
            "city-scale-mesh is a BENCH row; full delivery is the broadcast pair's charter \
             (city-scale churns through a regional outage, where exemption bookkeeping \
             would swallow the assertion anyway)"
        );
    }

    #[test]
    fn city_scale_shape_is_consistent() {
        let sc = city_scale();
        // Population: 256 initial + one 125-peer wave per region ≥ 1,000.
        let joins: usize = sc
            .events
            .iter()
            .map(|e| match e.fault {
                Fault::FlashCrowd { n, .. } => n,
                _ => 0,
            })
            .sum();
        assert_eq!(sc.peers, CITY_INITIAL);
        assert_eq!(joins, 6 * CITY_WAVE);
        assert!(sc.peers + joins >= 1000, "city-scale must reach 1,000 peers");
        // One wave per region, no region hit twice.
        let mut regions: Vec<Region> = sc
            .events
            .iter()
            .filter_map(|e| match e.fault {
                Fault::FlashCrowd { region, .. } => Some(region),
                _ => None,
            })
            .collect();
        regions.sort_unstable();
        regions.dedup();
        assert_eq!(regions.len(), ALL.len(), "a region missed its flash crowd");
        // Churn: every crash has a later restart of the same node, all
        // targets are distinct initial peers, the bootstrap root is
        // untouched, and churn fully precedes the regional outage.
        let crashes: Vec<(u64, usize)> = sc
            .events
            .iter()
            .filter_map(|e| match e.fault {
                Fault::Crash { node } => Some((e.at.0, node)),
                _ => None,
            })
            .collect();
        let restarts: Vec<(u64, usize)> = sc
            .events
            .iter()
            .filter_map(|e| match e.fault {
                Fault::Restart { node } => Some((e.at.0, node)),
                _ => None,
            })
            .collect();
        assert_eq!(crashes.len(), CITY_CHURN_CYCLES as usize);
        assert_eq!(restarts.len(), crashes.len());
        let outage_at = sc
            .events
            .iter()
            .find_map(|e| match e.fault {
                Fault::Outage { .. } => Some(e.at.0),
                _ => None,
            })
            .expect("regional outage present");
        let mut targets: Vec<usize> = Vec::new();
        for ((c_at, c_node), (r_at, r_node)) in crashes.iter().zip(&restarts) {
            assert_eq!(c_node, r_node, "crash/restart pairing drifted");
            assert!(c_at < r_at, "restart precedes its crash");
            assert!(*r_at < outage_at, "churn overlaps the regional outage");
            assert_ne!(*c_node, 0, "the bootstrap root must never churn");
            assert!(*c_node < CITY_INITIAL, "churn must target initial peers");
            targets.push(*c_node);
        }
        targets.sort_unstable();
        targets.dedup();
        assert_eq!(targets.len(), crashes.len(), "churn re-crashed a node");
        // Contributions only come from initial peers outside the churn
        // set, so no publish races its author's own restart.
        for e in &sc.events {
            if let Fault::Contribute { node, .. } = e.fault {
                assert!(node < CITY_INITIAL, "contributor joined mid-run");
                assert!(!targets.contains(&node), "contributor {node} is churned");
            }
        }
    }

    #[test]
    fn halfopen_region_reaches_target_size() {
        let sc = asymmetric_region_halfopen();
        let joins: usize = sc
            .events
            .iter()
            .map(|e| match e.fault {
                Fault::FlashCrowd { n, .. } => n,
                _ => 0,
            })
            .sum();
        assert_eq!(sc.peers, HALFOPEN_CORE);
        assert_eq!(joins, HALFOPEN_REGION);
        // The asymmetric fault covers exactly region→core.
        let asym = sc
            .events
            .iter()
            .find_map(|e| match &e.fault {
                Fault::AsymmetricPartition { a, b } => Some((a.clone(), b.clone())),
                _ => None,
            })
            .expect("half-open fault present");
        assert_eq!(asym.0, (HALFOPEN_CORE..HALFOPEN_CORE + HALFOPEN_REGION).collect::<Vec<_>>());
        assert_eq!(asym.1, (0..HALFOPEN_CORE).collect::<Vec<_>>());
    }

    #[test]
    fn gc_pressure_shapes_are_consistent() {
        let droppers: [&[usize]; 2] = [&GC_PRESSURE_DROPPERS, &HALFOPEN_DROPPERS];
        for (sc, droppers) in [gc_pressure(), halfopen_holders()].iter().zip(droppers) {
            // Repair must be the only replication path, and armed.
            assert!(!sc.cfg.auto_pin, "{}: auto-pin would mask repair", sc.name);
            assert!(sc.cfg.repair_interval.0 > 0, "{}: repair disabled", sc.name);
            // The node-level target must leave survivors outside the
            // dropper set: target - 1 replicas beyond the author, more
            // than can land on the remaining droppers.
            assert!(
                sc.cfg.replication_target > droppers.len() + 1,
                "{}: droppers could hold every replica",
                sc.name
            );
            assert!(sc.invariants.availability.is_some(), "{}: invariant off", sc.name);
            // Every dropper authored the same-indexed contribution
            // before dropping, and drops happen after all contributes.
            let contributes: Vec<(u64, usize)> = sc
                .events
                .iter()
                .filter_map(|e| match e.fault {
                    Fault::Contribute { node, .. } => Some((e.at.0, node)),
                    _ => None,
                })
                .collect();
            let drops: Vec<(u64, usize)> = sc
                .events
                .iter()
                .filter_map(|e| match e.fault {
                    Fault::UnpinAndGc { node } => Some((e.at.0, node)),
                    _ => None,
                })
                .collect();
            assert_eq!(
                drops.iter().map(|(_, n)| *n).collect::<Vec<_>>(),
                droppers.to_vec(),
                "{}: dropper constant drifted from the schedule",
                sc.name
            );
            for (k, (drop_at, node)) in drops.iter().enumerate() {
                let (c_at, c_node) = contributes[k];
                assert_eq!(c_node, *node, "{}: cids[{k}] not authored by dropper", sc.name);
                assert!(c_at < *drop_at, "{}: drop precedes contribution", sc.name);
            }
        }
    }

    #[test]
    fn scheduler_default_off_outside_striped_scenarios() {
        // Replay-compatibility guard, mirroring the DHT-defense guard
        // above: every pre-striping scenario keeps the single-source
        // fetcher and kubo-faithful batched announces, so its SimStats
        // (and checksum) are bit-identical to the pre-PR recordings.
        let striped = ["slow-peer-drag", "slow-peer-drag-rr", "provider-death-midtransfer"];
        for sc in all() {
            if striped.contains(&sc.name) {
                continue;
            }
            assert_eq!(
                sc.cfg.chunk_scheduler,
                ChunkScheduler::Single,
                "{}: striping leaked in",
                sc.name
            );
            assert!(!sc.cfg.announce_replicas, "{}: replica announces leaked in", sc.name);
        }
    }

    #[test]
    fn striped_transfer_shapes_are_consistent() {
        // The drag pair differs in scheduler (and seed) only: the
        // quality-vs-round-robin comparison is schedule-for-schedule.
        let drag = slow_peer_drag();
        let rr = slow_peer_drag_rr();
        assert_eq!(drag.cfg.chunk_scheduler, ChunkScheduler::Quality);
        assert_eq!(rr.cfg.chunk_scheduler, ChunkScheduler::RoundRobin);
        let fmt = |sc: &Scenario| {
            sc.events.iter().map(|e| format!("{:?}@{}", e.fault, e.at.0)).collect::<Vec<_>>()
        };
        assert_eq!(fmt(&drag), fmt(&rr), "drag control drifted from the quality schedule");
        for sc in [&drag, &rr, &provider_death_midtransfer()] {
            assert!(sc.cfg.announce_replicas, "{}: striping needs provider records", sc.name);
            assert_ne!(sc.cfg.chunk_scheduler, ChunkScheduler::Single, "{}", sc.name);
            // One multi-chunk contribution, authored before the joiners
            // exist, big enough to out-span the chunk window.
            let rows: Vec<usize> = sc
                .events
                .iter()
                .filter_map(|e| match e.fault {
                    Fault::Contribute { rows, .. } => Some(rows),
                    _ => None,
                })
                .collect();
            assert_eq!(rows, vec![STRIPE_ROWS], "{}: exactly one big contribution", sc.name);
            assert!(
                STRIPE_ROWS * 75 > sc.cfg.chunk_window * 256 * 1024,
                "file must out-span the chunk window for striping to matter"
            );
        }
        // The dying provider is a replica, not the author (the author's
        // copy must survive so reassignment has somewhere to land), and
        // it returns before quiesce.
        let death = provider_death_midtransfer();
        let (mut crashed, mut restarted) = (None, None);
        for e in &death.events {
            match e.fault {
                Fault::Crash { node } => crashed = Some((e.at.0, node)),
                Fault::Restart { node } => restarted = Some((e.at.0, node)),
                _ => {}
            }
        }
        let (crash_at, victim) = crashed.expect("a provider dies");
        let (restart_at, revived) = restarted.expect("the provider returns");
        assert_eq!(victim, revived);
        assert_ne!(victim, 1, "the author must survive");
        assert!(victim < STRIPE_PEERS, "the victim is an original replica");
        assert!(crash_at < restart_at);
    }

    #[test]
    fn grace_default_off_outside_delayed_honest_majority() {
        // Replay-compatibility guard, mirroring the defense/scheduler
        // guards above: every pre-grace scenario keeps `timeout_grace`
        // at ZERO, so its timeout path (and therefore its SimStats
        // checksum) is bit-identical to the pre-PR recordings.
        for sc in all() {
            if sc.name == "delayed-honest-majority" {
                continue;
            }
            assert_eq!(
                sc.cfg.quorum.timeout_grace,
                Duration::ZERO,
                "{}: quorum grace leaked in",
                sc.name
            );
        }
    }

    #[test]
    fn delayed_honest_majority_shape_is_consistent() {
        let sc = delayed_honest_majority();
        assert_eq!(sc.peers, DELAY_PEERS);
        assert_eq!(sc.byzantine, DELAY_BYZANTINE.to_vec());
        assert!(sc.invariants.verdict_integrity.is_some(), "ground-truth guard configured");
        // The cliff-edge arithmetic the scenario is pinned at: the
        // colluders dominate the sample but fall short of the quorum,
        // and their unanimous bloc cannot clear the agreement bar once
        // a single honest verdict completes it.
        let q = &sc.cfg.quorum;
        assert_eq!(q.fanout, DELAY_PEERS, "the victim samples the whole cluster");
        assert!(DELAY_BYZANTINE.len() * 2 > q.fanout, "colluders are a sample majority");
        assert!(q.responses_needed > DELAY_BYZANTINE.len(), "liars alone can't fill the quorum");
        let lie_frac = DELAY_BYZANTINE.len() as f64 / q.responses_needed as f64;
        assert!(lie_frac < q.agreement, "a completed quorum out-argues the lie bloc");
        assert!(DELAY_BYZANTINE.len() >= q.min_force_verdicts, "the lie clears the legacy floor");
        assert!(q.timeout_grace > q.timeout, "the grace must outlast the slow replies");
        // The one contribution is clean and byzantine-authored (the data
        // fetch rides a fast link; only verdicts are slow), and the slow
        // window opens with the join and outlives the vote.
        let contributions: Vec<_> = sc
            .events
            .iter()
            .filter_map(|e| match e.fault {
                Fault::Contribute { node, .. } => Some(node),
                Fault::ContributeCorrupt { .. } => panic!("the attack poisons a CLEAN file"),
                _ => None,
            })
            .collect();
        assert_eq!(contributions.len(), 1, "exactly one contribution");
        assert!(DELAY_BYZANTINE.contains(&contributions[0]), "authored by a colluder");
        let mut slow: Vec<(u64, usize, usize, f64)> = Vec::new();
        let mut join_at = None;
        for e in &sc.events {
            match e.fault {
                Fault::SlowLink { a, b, factor } => slow.push((e.at.0, a, b, factor)),
                Fault::FlashCrowd { n, .. } => {
                    assert_eq!(n, 1, "exactly one victim");
                    join_at = Some(e.at);
                }
                _ => {}
            }
        }
        let join_at = join_at.expect("the victim joins");
        let (slowed, restored): (Vec<_>, Vec<_>) =
            slow.iter().partition(|(_, _, _, f)| *f > 1.0);
        for group in [&slowed, &restored] {
            let mut honest: Vec<usize> = group.iter().map(|(_, _, b, _)| *b).collect();
            honest.sort_unstable();
            assert_eq!(honest, DELAY_HONEST.to_vec(), "both honest links covered");
            assert!(group.iter().all(|(_, a, _, _)| *a == DELAY_VOTER), "victim-side links");
        }
        for (at, _, _, f) in &slowed {
            assert_eq!(Duration(*at), join_at, "slow window opens with the join");
            assert_eq!(*f, DELAY_FACTOR);
        }
        for (at, _, _, _) in &restored {
            // started_at + timeout + grace, with the join/vote slack on top.
            let vote_deadline = join_at + sc.cfg.quorum.timeout + sc.cfg.quorum.timeout_grace;
            assert!(
                Duration(*at) > vote_deadline + Duration::from_secs(60),
                "restore must wait out even an extended vote"
            );
        }
    }

    #[test]
    fn parity_rows_are_tagged_and_real_clock_sized() {
        let rows = [parity_partition(), parity_gc_repair(), parity_quorum()];
        for sc in &rows {
            assert!(sc.parity, "{}: parity tag missing", sc.name);
            // Eligibility proper (lowering + timing-free fixed point) is
            // asserted by `sim::parity`'s own tests; here we guard the
            // real-clock budget: short warmup, early quiesce probes, and
            // a schedule that ends within seconds of warmup.
            assert!(sc.warmup <= Duration::from_secs(5), "{}: warmup too long", sc.name);
            assert!(sc.quiesce_poll.0 > 0, "{}: quiesce polling required", sc.name);
            let last = sc.events.iter().map(|e| e.at).max().expect("nonempty schedule");
            assert!(last <= Duration::from_secs(20), "{}: schedule too long", sc.name);
        }
        // And no sim-only scenario is accidentally tagged.
        for sc in all() {
            if sc.parity {
                assert!(
                    rows.iter().any(|r| r.name == sc.name),
                    "{}: unexpected parity tag",
                    sc.name
                );
            }
        }
    }

    #[test]
    fn scale_out_reaches_target_size() {
        let sc = multi_region_scale_out();
        let joins: usize = sc
            .events
            .iter()
            .map(|e| match e.fault {
                Fault::FlashCrowd { n, .. } => n,
                _ => 0,
            })
            .sum();
        assert!(sc.peers + joins >= 100, "scale-out must reach 100 peers");
        assert_eq!(sc.peers, SCALE_OUT_WAVE);
    }
}

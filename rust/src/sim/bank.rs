//! The named scenario bank, as library data.
//!
//! `tests/scenarios.rs` (assertions + replay checks) and
//! `benches/sim_scale.rs` (the self-timing perf baseline that emits
//! `BENCH_sim.json`) both consume the same definitions, so a scenario's
//! shape can never drift between its correctness test and its perf
//! measurement. Seeds and schedules are stable identifiers: changing one
//! invalidates recorded `SimStats` checksums, which is exactly the
//! signal the perf-trajectory artifact is meant to carry.

use crate::peersdb::NodeConfig;
use crate::sim::regions::Region;
use crate::sim::scenario::{Fault, Scenario};
use crate::util::time::Duration;
use crate::validation::CostModel;

/// 1. Network partition during active contribution traffic.
pub fn partition_heal() -> Scenario {
    let mut sc = Scenario::named("partition-heal", 101, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 40 })
        // Split the cluster down the middle, root on side A.
        .at(5, Fault::Partition { a: vec![0, 1, 2, 3], b: vec![4, 5, 6, 7] })
        // Both sides keep contributing while partitioned.
        .at(7, Fault::Contribute { node: 2, workload: 1, rows: 40 })
        .at(9, Fault::Contribute { node: 5, workload: 2, rows: 40 })
        .at(11, Fault::Contribute { node: 6, workload: 3, rows: 40 })
        // Mid-partition, safety invariants must still hold.
        .at(20, Fault::Checkpoint)
        .at(30, Fault::Heal)
        .at(35, Fault::Contribute { node: 7, workload: 4, rows: 40 })
}

/// 2. Regional outage and recovery (EuropeWest3 hosts peers 1 and 7).
pub fn regional_outage() -> Scenario {
    let mut sc = Scenario::named("regional-outage", 202, 10);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(5, Fault::Outage { region: Region::EuropeWest3 })
        // The rest of the world keeps publishing during the outage.
        .at(8, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(12, Fault::Contribute { node: 4, workload: 2, rows: 30 })
        .at(20, Fault::Checkpoint)
        .at(40, Fault::Recover { region: Region::EuropeWest3 })
        .at(45, Fault::Contribute { node: 7, workload: 3, rows: 30 })
}

/// 3. Crash/restart churn while data flows.
pub fn crash_churn() -> Scenario {
    let mut sc = Scenario::named("crash-churn", 303, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(2, Fault::Crash { node: 3 })
        .at(4, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(8, Fault::Crash { node: 5 })
        .at(10, Fault::Contribute { node: 6, workload: 2, rows: 30 })
        .at(14, Fault::Restart { node: 3 })
        .at(16, Fault::Contribute { node: 3, workload: 3, rows: 30 })
        .at(20, Fault::Crash { node: 1 })
        .at(25, Fault::Restart { node: 5 })
        .at(30, Fault::Checkpoint)
        .at(35, Fault::Restart { node: 1 })
        .at(40, Fault::Contribute { node: 7, workload: 4, rows: 30 })
}

/// 4. Flash-crowd join: the cluster doubles mid-run.
pub fn flash_crowd() -> Scenario {
    let mut sc = Scenario::named("flash-crowd", 404, 5);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        // Five newcomers join through the root at the same instant.
        .at(10, Fault::FlashCrowd { n: 5, region: Region::UsWest1 })
        // Traffic continues while they bootstrap.
        .at(12, Fault::Contribute { node: 3, workload: 2, rows: 30 })
        .at(30, Fault::Checkpoint)
}

/// 5a. The CPU-strain comparison baseline (same schedule, nominal CPU).
pub fn cpu_nominal() -> Scenario {
    cpu_schedule("cpu-nominal")
}

/// 5b. Root-peer CPU strain (the paper's §IV-A artifact, injected):
/// the same schedule under a 5000× slowdown of the root's machine.
pub fn cpu_strain() -> Scenario {
    cpu_schedule("cpu-strain").at_ms(0, Fault::CpuStrain { node: 0, factor: 5000 })
}

fn cpu_schedule(name: &'static str) -> Scenario {
    let mut sc = Scenario::named(name, 505, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
        .at(4, Fault::Contribute { node: 4, workload: 1, rows: 60 })
        .at(8, Fault::Contribute { node: 6, workload: 2, rows: 60 })
        .at(60, Fault::CpuRelief { node: 0 })
}

/// 6. Byzantine validator: a lying minority must not poison verdicts.
pub fn byzantine_minority() -> Scenario {
    let mut sc = Scenario::named("byzantine-minority", 606, 8);
    sc.quiesce = Duration::from_secs(400);
    sc.stats_validators = true;
    sc.byzantine = vec![3];
    sc.cfg = NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 2_000_000, ns_per_kb: 50_000.0 },
        ..NodeConfig::default()
    };
    // With a verdict floor of 2 on timeout tallies and >50% agreement, a
    // single liar can never push a wrong verdict through a vote.
    sc.cfg.quorum.min_force_verdicts = 2;
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
        .at(5, Fault::Contribute { node: 2, workload: 1, rows: 60 })
        .at(10, Fault::ContributeCorrupt { node: 3, workload: 2, rows: 60, frac: 0.9 })
        .at(15, Fault::Contribute { node: 5, workload: 3, rows: 60 })
        .at(20, Fault::ContributeCorrupt { node: 6, workload: 4, rows: 60, frac: 0.9 })
}

/// 7. Kitchen sink: loss spike + flapping links + churn, one schedule.
pub fn kitchen_sink() -> Scenario {
    let mut sc = Scenario::named("kitchen-sink", 707, 9);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    sc.at(0, Fault::SetLoss { loss: 0.05 })
        .at(1, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::BlockPair { a: 2, b: 5 })
        .at(5, Fault::Contribute { node: 5, workload: 1, rows: 30 })
        .at(7, Fault::Crash { node: 4 })
        .at(9, Fault::Contribute { node: 6, workload: 2, rows: 30 })
        .at(11, Fault::UnblockPair { a: 2, b: 5 })
        .at(13, Fault::BlockPair { a: 1, b: 8 })
        .at(15, Fault::Restart { node: 4 })
        .at(18, Fault::Contribute { node: 8, workload: 3, rows: 30 })
        .at(25, Fault::Checkpoint)
}

/// 8. Multi-region scale-out — the ROADMAP's "paper experiment 2 at
/// 10×": 25 initial peers rotated across all six GCP regions, then three
/// staggered flash crowds of 25 (Oregon, Frankfurt, Hong Kong) land
/// while contribution traffic continues, for 100 peers total. Bootstrap
/// time per wave is the measurement; the standard invariant set (log
/// convergence, quorum safety, routing health, availability ≥ 3) is the
/// pass condition. This cluster size is what the zero-copy block plane
/// and the allocation-free DES hot path exist for.
pub fn multi_region_scale_out() -> Scenario {
    let mut sc = Scenario::named("multi-region-scale-out", 909, 25);
    sc.quiesce = Duration::from_secs(900);
    sc.quiesce_poll = Duration::from_secs(10);
    sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 20 })
        .at(2, Fault::Contribute { node: 4, workload: 1, rows: 20 })
        // Wave 1: nodes 25..50.
        .at(5, Fault::FlashCrowd { n: 25, region: Region::UsWest1 })
        .at(20, Fault::Contribute { node: 7, workload: 2, rows: 20 })
        // Wave 2: nodes 50..75, with more history to sync.
        .at(40, Fault::FlashCrowd { n: 25, region: Region::EuropeWest3 })
        .at(55, Fault::Contribute { node: 10, workload: 3, rows: 20 })
        // Wave 3: nodes 75..100.
        .at(80, Fault::FlashCrowd { n: 25, region: Region::AsiaEast2 })
        .at(95, Fault::Contribute { node: 13, workload: 4, rows: 20 })
        .at(100, Fault::Contribute { node: 30, workload: 5, rows: 20 })
        .at(110, Fault::Checkpoint)
}

/// Number of initial peers / flash-crowd wave size in
/// [`multi_region_scale_out`] (the bootstrap-scaling assertions slice
/// node indices by this).
pub const SCALE_OUT_WAVE: usize = 25;

/// Every replayable bank scenario, in canonical order: the seven
/// original fault scenarios plus the multi-region scale-out headline.
pub fn all() -> Vec<Scenario> {
    vec![
        partition_heal(),
        regional_outage(),
        crash_churn(),
        flash_crowd(),
        cpu_strain(),
        byzantine_minority(),
        kitchen_sink(),
        multi_region_scale_out(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_names_and_seeds_are_unique() {
        let bank = all();
        let mut names: Vec<&str> = bank.iter().map(|s| s.name).collect();
        let mut seeds: Vec<u64> = bank.iter().map(|s| s.seed).collect();
        names.sort();
        names.dedup();
        seeds.sort();
        seeds.dedup();
        assert_eq!(names.len(), bank.len(), "duplicate scenario name");
        assert_eq!(seeds.len(), bank.len(), "duplicate scenario seed");
    }

    #[test]
    fn scale_out_reaches_target_size() {
        let sc = multi_region_scale_out();
        let joins: usize = sc
            .events
            .iter()
            .map(|e| match e.fault {
                Fault::FlashCrowd { n, .. } => n,
                _ => 0,
            })
            .sum();
        assert!(sc.peers + joins >= 100, "scale-out must reach 100 peers");
        assert_eq!(sc.peers, SCALE_OUT_WAVE);
    }
}

//! Network link model: latency, jitter, bandwidth, loss.

use crate::sim::regions::{one_way_ms, Region};
use crate::util::time::Duration;
use crate::util::Rng;

/// How link latency is determined.
#[derive(Clone, Debug)]
pub enum LatencySpec {
    /// Use the GCP region matrix (prototype experiments).
    RegionMatrix,
    /// Fixed one-way latency for every pair (Testground-style plans).
    Uniform { one_way_ms: f64 },
}

/// Link + node resource model. One instance shared by the whole cluster.
#[derive(Clone, Debug)]
pub struct NetModel {
    pub latency: LatencySpec,
    /// Jitter std-dev as a fraction of base latency (normal, truncated ≥0).
    pub jitter_frac: f64,
    /// Per-node egress bandwidth, bits/second.
    pub bandwidth_bps: f64,
    /// Probability a message is lost in transit.
    pub loss: f64,
    /// Fixed per-hop overhead added to every delivery (protocol stacks,
    /// kernel, etc.).
    pub per_hop_overhead: Duration,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel {
            latency: LatencySpec::RegionMatrix,
            jitter_frac: 0.05,
            // e2-standard-2 egress ≈ 4 Gbit/s cap; sustained cross-region
            // rates are far lower. 1 Gbit/s is our default.
            bandwidth_bps: 1.0e9,
            loss: 0.0,
            per_hop_overhead: Duration::from_micros(100),
        }
    }
}

impl NetModel {
    /// Testground-style uniform network.
    pub fn uniform(one_way_ms: f64, bandwidth_mbps: f64, jitter_frac: f64) -> NetModel {
        NetModel {
            latency: LatencySpec::Uniform { one_way_ms },
            jitter_frac,
            bandwidth_bps: bandwidth_mbps * 1e6,
            loss: 0.0,
            per_hop_overhead: Duration::from_micros(100),
        }
    }

    /// Same model with a message-loss probability — the degraded-network
    /// knob scenario schedules flip at run time.
    pub fn with_loss(mut self, loss: f64) -> NetModel {
        self.loss = loss.clamp(0.0, 1.0);
        self
    }

    /// Sampled one-way delay between two regions (base + jitter).
    pub fn sample_latency(&self, from: Region, to: Region, rng: &mut Rng) -> Duration {
        let base_ms = match self.latency {
            LatencySpec::RegionMatrix => one_way_ms(from, to),
            LatencySpec::Uniform { one_way_ms } => {
                if from == to {
                    0.25
                } else {
                    one_way_ms
                }
            }
        };
        let jitter = if self.jitter_frac > 0.0 {
            rng.normal_ms(0.0, base_ms * self.jitter_frac)
        } else {
            0.0
        };
        let ms = (base_ms + jitter).max(0.05);
        self.per_hop_overhead + Duration::from_secs_f64(ms / 1e3)
    }

    /// Transmission (serialization) time for `bytes` at node egress.
    pub fn tx_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(bytes as f64 * 8.0 / self.bandwidth_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_has_floor() {
        let m = NetModel {
            jitter_frac: 10.0, // extreme jitter can go negative pre-clamp
            ..NetModel::default()
        };
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let d = m.sample_latency(Region::AsiaEast2, Region::AsiaEast2, &mut rng);
            assert!(d.0 > 0);
        }
    }

    #[test]
    fn tx_time_scales() {
        let m = NetModel::uniform(50.0, 100.0, 0.0); // 100 Mbit/s
        let t1 = m.tx_time(1_000_000);
        assert!((t1.as_secs_f64() - 0.08).abs() < 1e-9); // 8 Mbit / 100 Mbit/s
    }

    #[test]
    fn uniform_spec_intra_fast() {
        let m = NetModel::uniform(150.0, 1024.0, 0.0);
        let mut rng = Rng::new(4);
        let same = m.sample_latency(Region::Local, Region::Local, &mut rng);
        assert!(same < Duration::from_millis(2));
        let cross = m.sample_latency(Region::AsiaEast2, Region::Local, &mut rng);
        assert!(cross >= Duration::from_millis(140));
    }
}

//! Declarative fault-injection scenarios with cluster-wide invariant
//! checking — the reusable evaluation surface behind `tests/scenarios.rs`
//! and `benches/sim_fuzz.rs`.
//!
//! The paper's evaluation (deployment, replication, validation) is a set
//! of ad-hoc experiments; collaborative approaches in its lineage (C3O,
//! the collaborative cluster-configuration research overview) only pay
//! off if shared performance data survives churn, partitions, and
//! malicious contributors. This module turns those conditions into
//! first-class, replayable artifacts:
//!
//! * a [`Scenario`] is a cluster shape plus a schedule of [`TimedFault`]s
//!   — partitions (symmetric *and* asymmetric, built on the simulator's
//!   directed link-state plane), heals, slow and lossy links, regional
//!   outages, peer crash/restart, flash-crowd joins, root-peer CPU
//!   strain, byzantine validators, forged DHT replies (eclipse attacks),
//!   message-loss spikes, and timed contribution traffic;
//! * [`run`] executes the schedule against a [`Cluster<Node>`] in
//!   virtual time, heals everything, lets the cluster quiesce, and then
//!   asserts the **cluster-wide invariants** ([`check_invariants`]):
//!
//!   1. **log convergence** — every online replica's contribution log
//!      has the same digest and the expected entry count
//!      (`ipfs_log` / `stores`);
//!   2. **quorum safety** — no two honest peers hold conflicting
//!      accepted validation verdicts for the same CID
//!      (`validation::quorum`);
//!   3. **routing health** — every routing table satisfies the k-bucket
//!      structural invariants and references only real cluster members
//!      (`dht::kbucket`);
//!   4. **block availability** — every contributed file is fully
//!      replicated on at least `replication_target` online peers
//!      (`bitswap` / `blockstore`);
//!
//!   5. **eclipse resistance** (opt-in, [`EclipseInvariant`]) — a
//!      designated victim's routing-table view of its own neighborhood
//!      must intersect the *honest* closest set, i.e. the attackers do
//!      not own the victim's entire view of the network;
//!
//!   6. **data survival** (opt-in, [`AvailabilityInvariant`]) — every
//!      contribution's data file must remain fetchable from at least
//!      `min_holders` live honest peers, i.e. GC pressure and holder
//!      churn did not destroy the last copy (`peersdb`'s availability-
//!      repair loop is what keeps this true);
//!
//!   7. **fetch-stall freedom** — at quiesce no node's data fetch may
//!      sit idle (chunks owed, nothing in flight, no lookup pending)
//!      while a live provider still holds the file: a fetch either
//!      makes progress or is abandoned outright, never wedged
//!      (`peersdb`'s striped chunk scheduler and reassignment paths);
//!
//!   8. **pubsub full delivery** (opt-in, [`PubsubDeliveryInvariant`])
//!      — every non-exempt live subscriber received every pubsub
//!      message published by every non-exempt node, i.e. gossip-mesh
//!      dissemination (or flood) lost nobody (`pubsub`).
//!
//! Runs are deterministic: executing the same scenario twice yields the
//! identical [`SimStats`], digest, and report — which is what makes a
//! failing scenario a *reproduction recipe* rather than a flake.

use crate::dht::Key;
use crate::modeling::datagen::{self, WORKLOADS};
use crate::net::PeerId;
use crate::peersdb::{Node, NodeConfig};
use crate::sim::des::{Cluster, SimStats};
use crate::sim::harness::{self, ClusterView, PeerSpec};
use crate::sim::model::NetModel;
use crate::sim::regions::{Region, ALL};
use crate::stores::documents::Verdict;
use crate::util::time::{Duration, Nanos};
use crate::util::Rng;
use crate::validation::{ByzantineValidator, StatsValidator, Validator};
use std::collections::BTreeSet;

/// One injectable fault (or scripted action). Node indices refer to the
/// cluster's spec order: 0 is the root.
#[derive(Clone, Debug)]
pub enum Fault {
    /// Block every link between the two groups (a bidirectional network
    /// partition). Groups need not cover the cluster.
    Partition { a: Vec<usize>, b: Vec<usize> },
    /// Heal every link blocked by previous faults.
    Heal,
    /// Block one bidirectional link (fuzz-style flapping). Equivalent to
    /// `BlockDirected` in both directions (property-tested).
    BlockPair { a: usize, b: usize },
    /// Unblock one bidirectional link.
    UnblockPair { a: usize, b: usize },
    /// Block only the directed link `from → to`: `from`'s messages to
    /// `to` vanish while the reverse path keeps working. The primitive
    /// behind half-open NAT-style failures.
    BlockDirected { from: usize, to: usize },
    /// Unblock the directed link `from → to` (loss/latency overrides on
    /// the link survive; teardown restores everything).
    UnblockDirected { from: usize, to: usize },
    /// Asymmetric partition: **A sees B, B doesn't see A.** Every node
    /// in `a` can still *send* to every node in `b`, but all directed
    /// links `b → a` are blocked — so `a`'s requests arrive and the
    /// replies die. Models a region that can reach the root but cannot
    /// be reached (half-open links during regional scale-out).
    AsymmetricPartition { a: Vec<usize>, b: Vec<usize> },
    /// Multiply the sampled propagation latency on both directions of
    /// the `a ↔ b` link by `factor` (1.0 = nominal and is a no-op on the
    /// sampled value; > 1.0 models a degraded long-haul path).
    SlowLink { a: usize, b: usize, factor: f64 },
    /// Override the loss probability of the *directed* link `from → to`
    /// (the cluster-wide `SetLoss` still governs every other link).
    SetLinkLoss { from: usize, to: usize, loss: f64 },
    /// Turn `node` into an eclipse attacker: every DHT
    /// `FindNodeReply`/`GetProvidersReply` it serves claims `colluders`
    /// (cluster indices) are the closest peers / providers. All of its
    /// other protocol behaviour stays honest, which is what makes the
    /// attack hard to spot from traffic volume alone.
    ForgeDhtReplies { node: usize, colluders: Vec<usize> },
    /// Stop `node` forging DHT replies (it answers honestly again).
    StopForging { node: usize },
    /// Take every node in the region offline (regional outage).
    Outage { region: Region },
    /// Bring every node in the region back (they re-bootstrap).
    Recover { region: Region },
    /// Crash one node: in-flight work and timers are lost.
    Crash { node: usize },
    /// Restart a crashed node (a no-op if it is online).
    Restart { node: usize },
    /// `n` fresh peers join at once through the root (flash crowd).
    FlashCrowd { n: usize, region: Region },
    /// Slow the CPU of the machine hosting `node` by `factor` — the
    /// paper's root-peer CPU-strain artifact, on demand.
    CpuStrain { node: usize, factor: u32 },
    /// Restore nominal CPU speed for `node`'s machine.
    CpuRelief { node: usize },
    /// Change the network-wide message-loss probability.
    SetLoss { loss: f64 },
    /// Swap `node`'s validator for a lying [`ByzantineValidator`].
    TurnByzantine { node: usize },
    /// Inject a contribution of `rows` observations at `node`.
    Contribute { node: usize, workload: u32, rows: usize },
    /// Inject a *corrupted* contribution (a `frac` fraction of rows get
    /// implausible values) — the malicious-contributor workload for
    /// validation scenarios.
    ContributeCorrupt { node: usize, workload: u32, rows: usize, frac: f64 },
    /// `node` deliberately unpins every contribution data file it holds
    /// (own contributions included), withdraws its provider records, and
    /// garbage-collects — the GC-pressure fault. The node keeps serving
    /// log entries; only data files are destroyed, and the node's own
    /// repair loop will refuse to resurrect them (re-replication is the
    /// surviving holders' job).
    UnpinAndGc { node: usize },
    /// Toggle the availability-repair loop on every *current* cluster
    /// member (peers joining later still get their configured default).
    /// `SetRepair { on: false }` at schedule start is the negative
    /// control proving a GC-pressure scenario detects real data loss.
    SetRepair { on: bool },
    /// Assert the safety invariants *mid-run* (routing health + quorum
    /// safety; convergence and availability are quiesce-only).
    Checkpoint,
}

/// A fault scheduled at an offset from the end of the warmup phase.
#[derive(Clone, Debug)]
pub struct TimedFault {
    pub at: Duration,
    pub fault: Fault,
}

/// The eclipse-resistance invariant: checked at quiesce when configured
/// on [`InvariantConfig::eclipse`].
///
/// The victim's routing-table view of the `k` peers closest to its own
/// id must intersect the **honest closest set** — the true `k` closest
/// online cluster members once the listed attackers are excluded. If the
/// intersection is empty, the attackers own the victim's entire view of
/// its neighborhood: every lookup the victim starts from that state is
/// seeded exclusively with colluders, which is precisely an eclipse.
/// (With `k` at least the cluster size this reduces to "the victim still
/// knows at least one honest peer", the strongest form at small n.)
#[derive(Clone, Debug)]
pub struct EclipseInvariant {
    /// The targeted node (cluster index).
    pub victim: usize,
    /// Nodes forging DHT replies — excluded from the honest set.
    pub attackers: Vec<usize>,
}

/// The data-survival invariant: checked at quiesce when configured on
/// [`InvariantConfig::availability`].
///
/// Every contribution's data file must be *fully* present — root block
/// and all chunks, not marked private — on at least `min_holders` online
/// honest peers. This is the floor beneath the standard
/// replication-target check: the target says "replication is healthy",
/// this says "the data still exists at all". A GC-pressure scenario run
/// with repair disabled demonstrably trips it, which is what proves the
/// scenario detects real data loss rather than vacuously passing.
#[derive(Clone, Debug)]
pub struct AvailabilityInvariant {
    /// Minimum number of live honest holders per contribution (≥ 1).
    pub min_holders: usize,
}

impl Default for AvailabilityInvariant {
    fn default() -> Self {
        AvailabilityInvariant { min_holders: 1 }
    }
}

/// The verdict-integrity invariant: checked at quiesce when configured on
/// [`InvariantConfig::verdict_integrity`].
///
/// No honest peer may hold a **network-adopted** verdict that contradicts
/// the schedule's ground truth — a clean [`Fault::Contribute`] marked
/// `Invalid`, or a [`Fault::ContributeCorrupt`] marked `Valid`. This is
/// strictly sharper than the quorum-safety conflict check: a colluding
/// byzantine *majority* of one vote's sample lies unanimously, so the
/// victim's adopted verdict conflicts with no other honest peer until
/// their own (local) verdicts land — and the poisoned record is already
/// in `ValidationSource::Network` by then. Ground truth is the only
/// oracle that catches the adoption itself. Locally computed verdicts
/// are exempt: the invariant polices the quorum plane, not validators.
#[derive(Clone, Copy, Debug, Default)]
pub struct VerdictIntegrityInvariant;

/// The pubsub full-delivery invariant: checked at quiesce when
/// configured on [`InvariantConfig::pubsub_delivery`].
///
/// Every online non-exempt node must have locally delivered every
/// message `(origin, seq)` that any non-exempt node published — the
/// liveness half of the gossip-mesh bargain (bounded redundancy is the
/// efficiency half; losing subscribers to win it would be cheating).
#[derive(Clone, Debug, Default)]
pub struct PubsubDeliveryInvariant {
    /// Node indices exempted both as publishers and as subscribers —
    /// typically the churn set: a crash wipes the node's local delivery
    /// record and a frame broadcast while it was down is gone for good
    /// (pubsub is fire-and-forget; the *contribution log* still
    /// converges via anti-entropy, which invariant 1 asserts).
    pub exempt: Vec<usize>,
}

/// Invariant-checker knobs.
#[derive(Clone, Debug)]
pub struct InvariantConfig {
    /// Minimum online replicas holding each contributed file at quiesce
    /// (clamped to the online-node count).
    pub replication_target: usize,
    /// Nodes whose validation stores are *expected* to lie — excluded
    /// from the quorum-safety conflict check.
    pub byzantine: Vec<usize>,
    /// Eclipse-resistance guard (quiesce-only: it is a recovery
    /// property, deliberately violated *during* an attack window).
    pub eclipse: Option<EclipseInvariant>,
    /// Data-survival guard (quiesce-only: holder loss mid-run is the
    /// scenario's whole point; what matters is that repair recovered).
    pub availability: Option<AvailabilityInvariant>,
    /// Ground-truth verdict guard (quiesce-only: an in-flight vote may
    /// still be waiting out its grace mid-run; what matters is that no
    /// lie survived to the end).
    pub verdict_integrity: Option<VerdictIntegrityInvariant>,
    /// Pubsub full-delivery guard (quiesce-only: frames are still in
    /// flight — or waiting on a heartbeat's IHAVE batch — mid-run).
    pub pubsub_delivery: Option<PubsubDeliveryInvariant>,
}

impl Default for InvariantConfig {
    fn default() -> Self {
        InvariantConfig {
            replication_target: 3,
            byzantine: Vec::new(),
            eclipse: None,
            availability: None,
            verdict_integrity: None,
            pubsub_delivery: None,
        }
    }
}

/// When the checker runs: mid-run checkpoints only assert safety;
/// quiesce additionally asserts liveness-dependent properties.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Checkpoint,
    Quiesce,
}

/// A declarative scenario: cluster shape + fault schedule + invariants.
#[derive(Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub seed: u64,
    /// Initial peer count (root included; flash crowds add more).
    pub peers: usize,
    pub model: NetModel,
    /// Start-time stagger between consecutive initial peers.
    pub stagger: Duration,
    /// Settling time before the first fault fires.
    pub warmup: Duration,
    /// Healing tail after the last fault, before the final invariants.
    pub quiesce: Duration,
    /// If nonzero, probe the quiesce invariants at this interval and
    /// stop early once they pass (records `converged_at`).
    pub quiesce_poll: Duration,
    /// Fault schedule; `at` offsets are relative to the end of warmup.
    pub events: Vec<TimedFault>,
    /// Initial peers that start with a [`ByzantineValidator`].
    pub byzantine: Vec<usize>,
    /// Give honest peers a [`StatsValidator`] (otherwise the default
    /// identity validator is used).
    pub stats_validators: bool,
    /// Node configuration template applied to every peer.
    pub cfg: NodeConfig,
    pub invariants: InvariantConfig,
    /// Parity-eligible: the schedule lowers to real-TCP actions
    /// (`sim::parity::lower_schedule` succeeds) *and* the outcome
    /// converges to a timing-free fixed point, so the parity harness
    /// replays this scenario over real sockets and differentially
    /// compares `ConvergenceReport`s. Tagged scenarios are shape-guarded
    /// by `sim::bank`'s tests; see `sim::parity::parity_eligible`.
    pub parity: bool,
}

impl Scenario {
    /// A scenario with sensible defaults: six-region layout, default
    /// network model, 10 s warmup, 600 s quiesce.
    pub fn named(name: &'static str, seed: u64, peers: usize) -> Scenario {
        Scenario {
            name,
            seed,
            peers,
            model: NetModel::default(),
            stagger: Duration::from_millis(200),
            warmup: Duration::from_secs(10),
            quiesce: Duration::from_secs(600),
            quiesce_poll: Duration::ZERO,
            events: Vec::new(),
            byzantine: Vec::new(),
            stats_validators: false,
            cfg: NodeConfig::default(),
            invariants: InvariantConfig::default(),
            parity: false,
        }
    }

    /// Schedule `fault` at `secs` seconds after warmup.
    pub fn at(mut self, secs: u64, fault: Fault) -> Scenario {
        self.events.push(TimedFault { at: Duration::from_secs(secs), fault });
        self
    }

    /// Schedule `fault` at a millisecond offset after warmup.
    pub fn at_ms(mut self, ms: u64, fault: Fault) -> Scenario {
        self.events.push(TimedFault { at: Duration::from_millis(ms), fault });
        self
    }
}

/// What a completed scenario run produced. Two runs of the same scenario
/// must compare equal — that equality *is* the determinism guarantee.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioReport {
    pub name: &'static str,
    /// Final peer count (initial + flash-crowd joiners).
    pub peers: usize,
    /// Contributions injected by the schedule.
    pub contributions: usize,
    /// Mid-run checkpoints that passed.
    pub checkpoints: usize,
    /// Virtual time at which the quiesce invariants first passed (only
    /// recorded when `quiesce_poll` is nonzero).
    pub converged_at: Option<Nanos>,
    /// Virtual end time of the run.
    pub end: Nanos,
    /// Converged contribution-log digest.
    pub digest: [u8; 32],
    /// Every injected contribution's data CID, with whether it was
    /// deliberately corrupted — so tests can assert verdicts per file.
    pub cids: Vec<(crate::cid::Cid, bool)>,
    pub stats: SimStats,
}

/// Execute a scenario start to finish. `Err` carries the first violated
/// invariant (with the scenario name and virtual time for replay).
pub fn run(sc: &Scenario) -> Result<ScenarioReport, String> {
    run_cluster(sc).map(|(report, _)| report)
}

/// Like [`run`], but hands back the quiesced cluster too, for
/// scenario-specific assertions beyond the cluster-wide invariants.
pub fn run_cluster(sc: &Scenario) -> Result<(ScenarioReport, Cluster<Node>), String> {
    assert!(sc.peers >= 2, "scenario needs a root and at least one peer");
    let mut rng = Rng::new(sc.seed ^ 0x5CE2A210_FA17_1A7E);
    let specs: Vec<PeerSpec> = (0..sc.peers)
        .map(|i| PeerSpec {
            region: if i == 0 { Region::AsiaEast2 } else { ALL[i % ALL.len()] },
            start_at: Nanos(sc.stagger.0 * i as u64),
            cfg: sc.cfg.clone(),
            validator: validator_for(sc, i),
            machine: None,
        })
        .collect();
    let mut cluster = harness::build_cluster(sc.seed, sc.model.clone(), specs);
    cluster.run_for(sc.warmup);
    let t0 = cluster.now();

    // Stable-order schedule: ties resolve in declaration order.
    let mut order: Vec<usize> = (0..sc.events.len()).collect();
    order.sort_by_key(|&i| (sc.events[i].at, i));

    let base_loss = cluster.model.loss;
    let mut inv = sc.invariants.clone();
    for b in &sc.byzantine {
        if !inv.byzantine.contains(b) {
            inv.byzantine.push(*b);
        }
    }
    let mut cids: Vec<(crate::cid::Cid, bool)> = Vec::new();
    let mut contributed = 0usize;
    let mut checkpoints = 0usize;
    // Nodes currently forging DHT replies, so teardown can restore them.
    let mut forgers: BTreeSet<usize> = BTreeSet::new();

    for i in order {
        let ev = &sc.events[i];
        cluster.run_until(t0 + ev.at);
        match &ev.fault {
            Fault::Partition { a, b } => {
                for &x in a {
                    for &y in b {
                        if x != y {
                            cluster.block_pair(x, y);
                        }
                    }
                }
            }
            Fault::Heal => cluster.unblock_all(),
            Fault::BlockPair { a, b } => cluster.block_pair(*a, *b),
            Fault::UnblockPair { a, b } => cluster.unblock_pair(*a, *b),
            Fault::BlockDirected { from, to } => cluster.block_link(*from, *to),
            Fault::UnblockDirected { from, to } => cluster.unblock_link(*from, *to),
            Fault::AsymmetricPartition { a, b } => {
                // A sees B: only the b→a directions are blocked.
                for &x in a {
                    for &y in b {
                        if x != y {
                            cluster.block_link(y, x);
                        }
                    }
                }
            }
            Fault::SlowLink { a, b, factor } => {
                cluster.set_link_latency_factor(*a, *b, *factor);
                cluster.set_link_latency_factor(*b, *a, *factor);
            }
            Fault::SetLinkLoss { from, to, loss } => {
                cluster.set_link_loss(*from, *to, Some(*loss));
            }
            Fault::ForgeDhtReplies { node, colluders } => {
                forgers.insert(*node);
                harness::forge_dht_replies(&mut cluster, *node, colluders);
            }
            Fault::StopForging { node } => {
                forgers.remove(node);
                harness::stop_forging(&mut cluster, *node);
            }
            Fault::Outage { region } => {
                for i in 0..cluster.len() {
                    if cluster.region_of(i) == *region {
                        cluster.set_offline(i);
                    }
                }
            }
            Fault::Recover { region } => {
                for i in 0..cluster.len() {
                    if cluster.region_of(i) == *region {
                        cluster.set_online(i);
                    }
                }
            }
            Fault::Crash { node } => cluster.set_offline(*node),
            Fault::Restart { node } => cluster.set_online(*node),
            Fault::FlashCrowd { n, region } => {
                for _ in 0..*n {
                    let validator: Option<Box<dyn Validator>> = if sc.stats_validators {
                        Some(Box::new(StatsValidator::default()))
                    } else {
                        None
                    };
                    harness::join_peer(&mut cluster, *region, sc.cfg.clone(), validator, &mut rng);
                }
            }
            Fault::CpuStrain { node, factor } => {
                let m = cluster.machine_of(*node);
                cluster.set_cpu_factor(m, *factor);
            }
            Fault::CpuRelief { node } => {
                let m = cluster.machine_of(*node);
                cluster.set_cpu_factor(m, 1);
            }
            Fault::SetLoss { loss } => {
                cluster.model = cluster.model.clone().with_loss(*loss);
            }
            Fault::TurnByzantine { node } => {
                if !inv.byzantine.contains(node) {
                    inv.byzantine.push(*node);
                }
                cluster.with_node(*node, |n, _, _| {
                    n.set_validator(Box::new(ByzantineValidator::default()));
                });
            }
            Fault::Contribute { node, workload, rows } => {
                let wl = (*workload as usize) % WORKLOADS.len();
                let (file, _) = datagen::generate_contribution(&mut rng, wl as u32, *rows);
                let cid = harness::contribute(&mut cluster, *node, &file, WORKLOADS[wl]);
                cids.push((cid, false));
                contributed += 1;
            }
            Fault::ContributeCorrupt { node, workload, rows, frac } => {
                let wl = (*workload as usize) % WORKLOADS.len();
                let (file, _) =
                    datagen::generate_corrupt_contribution(&mut rng, wl as u32, *rows, *frac);
                let cid = harness::contribute(&mut cluster, *node, &file, WORKLOADS[wl]);
                cids.push((cid, true));
                contributed += 1;
            }
            Fault::UnpinAndGc { node } => {
                harness::unpin_and_gc(&mut cluster, *node);
            }
            Fault::SetRepair { on } => {
                harness::set_repair(&mut cluster, *on);
            }
            Fault::Checkpoint => {
                check_invariants(&cluster, &inv, contributed, &cids, Phase::Checkpoint).map_err(
                    |e| format!("scenario '{}' checkpoint at {}: {e}", sc.name, cluster.now()),
                )?;
                checkpoints += 1;
            }
        }
    }

    // Global heal: whatever the schedule left broken comes back, then the
    // cluster gets a quiet tail to converge in. The *entire* link-state
    // plane is restored — blocked links, per-link loss overrides, and
    // latency multipliers — along with loss, CPU factors, and any DHT
    // reply forging, so back-to-back scenarios on one cluster can never
    // inherit leaked fault state.
    cluster.reset_links();
    for i in 0..cluster.len() {
        cluster.set_online(i);
    }
    cluster.reset_cpu_factors();
    cluster.model.loss = base_loss;
    for node in forgers {
        harness::stop_forging(&mut cluster, node);
    }

    let deadline = cluster.now() + sc.quiesce;
    let mut converged_at = None;
    if sc.quiesce_poll.0 > 0 {
        while cluster.now() < deadline {
            let step = sc.quiesce_poll.min(deadline - cluster.now());
            cluster.run_for(step);
            if check_invariants(&cluster, &inv, contributed, &cids, Phase::Quiesce).is_ok() {
                converged_at = Some(cluster.now());
                break;
            }
        }
    } else {
        cluster.run_until(deadline);
    }
    check_invariants(&cluster, &inv, contributed, &cids, Phase::Quiesce)
        .map_err(|e| format!("scenario '{}' at quiesce ({}): {e}", sc.name, cluster.now()))?;

    // Fold the per-node DHT lookup-hardening counters into the report's
    // stats (the transport layer cannot see node internals). All-zero —
    // and checksum-invisible — unless a defense knob was on.
    let mut stats = cluster.stats.clone();
    let (paths, rejected, quarantined) = harness::dht_defense_totals(&cluster);
    stats.lookup_paths_started = paths;
    stats.closer_peers_rejected = rejected;
    stats.unverified_peers_quarantined = quarantined;
    // Same for the striped-transfer counters: all-zero (and
    // checksum-invisible) unless a scenario ran a non-`Single`
    // chunk scheduler.
    let (striped, reassigned) = harness::transfer_totals(&cluster);
    stats.chunks_striped = striped;
    stats.transfer_reassignments = reassigned;
    // And the quorum timeout-path counters plus the ground-truth audit.
    // Of these, only the grace pair and the false-adoption count reach
    // the checksum (when nonzero); `votes_forced` is digest-excluded but
    // still replay-guarded through `ScenarioReport` equality.
    let (forced, extended, rescued) = harness::quorum_totals(&cluster);
    stats.votes_forced = forced;
    stats.votes_extended = extended;
    stats.votes_rescued_by_grace = rescued;
    stats.false_verdicts_adopted = harness::false_verdicts(&cluster, &cids, &inv.byzantine);
    // And the gossip-mesh pubsub telemetry: all-zero (and
    // checksum-invisible) unless a scenario ran with the mesh knob on.
    let (ihave, iwant, grafts, prunes) = harness::pubsub_mesh_totals(&cluster);
    stats.ihave_sent = ihave;
    stats.iwant_served = iwant;
    stats.grafts = grafts;
    stats.prunes = prunes;

    let report = ScenarioReport {
        name: sc.name,
        peers: cluster.len(),
        contributions: contributed,
        checkpoints,
        converged_at,
        end: cluster.now(),
        digest: cluster.node(0).contributions.digest(),
        cids,
        stats,
    };
    Ok((report, cluster))
}

/// Run a scenario twice and insist the runs are indistinguishable; the
/// determinism half of the harness contract. Returns the first report.
pub fn run_replayed(sc: &Scenario) -> Result<ScenarioReport, String> {
    let a = run(sc)?;
    let b = run(sc)?;
    if a != b {
        return Err(format!(
            "scenario '{}' is not deterministic:\n  first : {:?}\n  replay: {:?}",
            sc.name, a, b
        ));
    }
    Ok(a)
}

pub(crate) fn validator_for(sc: &Scenario, i: usize) -> Option<Box<dyn Validator>> {
    if sc.byzantine.contains(&i) {
        Some(Box::new(ByzantineValidator::default()))
    } else if sc.stats_validators {
        Some(Box::new(StatsValidator::default()))
    } else {
        None
    }
}

/// Check the cluster-wide invariants. Checkpoint phase asserts safety
/// only (routing health, quorum safety); quiesce additionally asserts
/// convergence, bootstrap completion, and block availability.
pub fn check_invariants(
    cluster: &impl ClusterView,
    cfg: &InvariantConfig,
    expected_contributions: usize,
    ground_truth: &[(crate::cid::Cid, bool)],
    phase: Phase,
) -> Result<(), String> {
    let online: Vec<usize> = (0..cluster.len()).filter(|&i| cluster.is_online(i)).collect();

    // ---- DHT routing-table health (safety) -----------------------------
    for &i in &online {
        let node = cluster.node(i);
        node.dht
            .table
            .check_invariants()
            .map_err(|e| format!("node {i}: routing table: {e}"))?;
        for p in node.dht.table.peers() {
            if cluster.index_of(p).is_none() {
                return Err(format!("node {i}: routing table references unknown peer {p:?}"));
            }
        }
    }

    // ---- Verdict integrity vs ground truth (quiesce; before the
    // conflict check so an adopted lie is reported as the adoption it
    // is, not as the downstream honest-vs-honest conflict it causes
    // once the slow honest verdicts land) -------------------------------
    if phase == Phase::Quiesce && cfg.verdict_integrity.is_some() {
        check_verdict_integrity(cluster, ground_truth, &cfg.byzantine)?;
    }

    // ---- Quorum safety: no conflicting accepted verdicts (safety) ------
    // Honest validators are deterministic, and a quorum decision requires
    // `agreement` of the sampled verdicts, so two honest peers accepting
    // opposite verdicts for one CID means the voting machinery broke (or
    // a byzantine minority outvoted the honest peers).
    let mut cids: BTreeSet<crate::cid::Cid> = BTreeSet::new();
    for i in 0..cluster.len() {
        for c in cluster.node(i).contributions.iter() {
            cids.insert(c.data_cid);
        }
    }
    for cid in &cids {
        let mut valid_holder = None;
        let mut invalid_holder = None;
        for i in 0..cluster.len() {
            if cfg.byzantine.contains(&i) {
                continue;
            }
            match cluster.node(i).validations.verdict(cid) {
                Some(Verdict::Valid) => valid_holder = Some(i),
                Some(Verdict::Invalid) => invalid_holder = Some(i),
                _ => {}
            }
        }
        if let (Some(a), Some(b)) = (valid_holder, invalid_holder) {
            return Err(format!(
                "quorum safety violated for {cid:?}: node {a} accepted Valid, \
                 node {b} accepted Invalid"
            ));
        }
    }

    if phase == Phase::Checkpoint {
        return Ok(());
    }

    // ---- Eclipse resistance (quiesce; checked first so a still-eclipsed
    // victim is reported as such, not as a downstream convergence symptom)
    if let Some(ec) = &cfg.eclipse {
        check_eclipse(cluster, ec)?;
    }

    // ---- Data survival (quiesce; before the replication-target check so
    // total loss reads as "data loss", not as a replica shortfall)
    if let Some(av) = &cfg.availability {
        check_availability(cluster, av, &cfg.byzantine)?;
    }

    // ---- Fetch-stall freedom (quiesce) ---------------------------------
    // No data fetch may sit idle — chunks owed but nothing in flight and
    // no lookup pending — while a live node still holds the whole file.
    // Every abandon path must tear the fetch down outright; a stalled
    // entry means a scheduler or reassignment path dropped its driver.
    for &i in &online {
        for root in cluster.node(i).stalled_data_fetches() {
            let holder = online.iter().any(|&j| {
                j != i && crate::blockstore::chunker::has_file(&cluster.node(j).bs, &root)
            });
            if holder {
                return Err(format!(
                    "fetch stall: node {i}'s fetch of {root:?} has no request in \
                     flight and no lookup pending while a live provider holds the file"
                ));
            }
        }
    }

    // ---- Bootstrap + log convergence (quiesce) -------------------------
    for &i in &online {
        if !cluster.node(i).is_bootstrapped() {
            return Err(format!("node {i} never finished bootstrapping"));
        }
        if !cluster.node(i).contributions.log().missing_is_empty() {
            return Err(format!("node {i} still missing log entries"));
        }
    }
    let Some(&first) = online.first() else {
        return Err("no online nodes at quiesce".into());
    };
    let d0 = cluster.node(first).contributions.digest();
    for &i in &online {
        let n = cluster.node(i);
        if n.contributions.len() != expected_contributions {
            return Err(format!(
                "node {i} has {} contributions, expected {expected_contributions}",
                n.contributions.len()
            ));
        }
        if n.contributions.digest() != d0 {
            return Err(format!("log divergence: node {i} differs from node {first}"));
        }
    }

    // ---- Pubsub full delivery (quiesce; opt-in) ------------------------
    if let Some(pd) = &cfg.pubsub_delivery {
        check_pubsub_delivery(cluster, pd)?;
    }

    // ---- Block availability ≥ replication target (quiesce) -------------
    let target = cfg.replication_target.min(online.len());
    for c in cluster.node(first).contributions.iter() {
        let replicas = online
            .iter()
            .filter(|&&i| crate::blockstore::chunker::has_file(&cluster.node(i).bs, &c.data_cid))
            .count();
        if replicas < target {
            return Err(format!(
                "availability: {:?} ({}) on {replicas}/{} online peers, target {target}",
                c.data_cid,
                c.workload,
                online.len()
            ));
        }
    }
    Ok(())
}

/// The [`PubsubDeliveryInvariant`] predicate, exposed for
/// scenario-specific assertions: every online non-exempt node must have
/// locally delivered every message `(origin, seq)` published by every
/// other online non-exempt node. Publishers vouch for their own
/// messages (`seq` runs `1..=published_count`), so the check needs no
/// side-channel record of what the schedule injected.
pub fn check_pubsub_delivery(
    cluster: &impl ClusterView,
    pd: &PubsubDeliveryInvariant,
) -> Result<(), String> {
    let eligible: Vec<usize> = (0..cluster.len())
        .filter(|&i| cluster.is_online(i) && !pd.exempt.contains(&i))
        .collect();
    for &j in &eligible {
        let n = cluster.node(j).pubsub_published_count();
        if n == 0 {
            continue;
        }
        let origin = cluster.peer_id(j);
        for &i in &eligible {
            if i == j {
                continue;
            }
            for seq in 1..=n {
                if !cluster.node(i).pubsub_has_delivered(origin, seq) {
                    return Err(format!(
                        "pubsub delivery: node {i} never received message {seq}/{n} \
                         published by node {j}"
                    ));
                }
            }
        }
    }
    Ok(())
}

/// The [`EclipseInvariant`] predicate, exposed for scenario-specific
/// assertions: the victim's routing-table view of the `k` peers closest
/// to its own id must share at least one member with the honest closest
/// set (online non-attacker peers ranked by XOR distance to the victim).
/// An empty intersection means every lookup the victim can start is
/// seeded exclusively with colluders — the attack succeeded.
pub fn check_eclipse(cluster: &impl ClusterView, ec: &EclipseInvariant) -> Result<(), String> {
    let victim = ec.victim;
    let vkey = Key::from_peer(cluster.peer_id(victim));
    let k = cluster.node(victim).cfg.dht.k;
    let view = cluster.node(victim).dht.table.closest(&vkey, k);
    let mut honest: Vec<PeerId> = (0..cluster.len())
        .filter(|&i| i != victim && cluster.is_online(i) && !ec.attackers.contains(&i))
        .map(|i| cluster.peer_id(i))
        .collect();
    honest.sort_by_key(|p| vkey.distance(&Key::from_peer(*p)));
    honest.truncate(k);
    if honest.is_empty() {
        return Ok(()); // degenerate cluster: nobody honest to know about
    }
    if view.iter().any(|p| honest.contains(p)) {
        Ok(())
    } else {
        Err(format!(
            "eclipse: node {victim}'s view of its {k} closest peers ({} entries) contains \
             no member of the honest closest set — lookups are attacker-seeded",
            view.len()
        ))
    }
}

/// The [`AvailabilityInvariant`] predicate, exposed for scenario-specific
/// assertions: every contribution data file referenced by *any* replica's
/// log must be fully present (root + all chunks, not private) on at least
/// `min_holders` online non-byzantine peers. Falling below that means the
/// network destroyed data it was supposed to keep — re-replication either
/// never ran or could not outpace the holder loss.
pub fn check_availability(
    cluster: &impl ClusterView,
    av: &AvailabilityInvariant,
    byzantine: &[usize],
) -> Result<(), String> {
    let min = av.min_holders.max(1);
    let mut cids: BTreeSet<crate::cid::Cid> = BTreeSet::new();
    for i in 0..cluster.len() {
        for c in cluster.node(i).contributions.iter() {
            cids.insert(c.data_cid);
        }
    }
    for cid in &cids {
        let holders = (0..cluster.len())
            .filter(|&i| cluster.is_online(i) && !byzantine.contains(&i))
            .filter(|&i| {
                let bs = &cluster.node(i).bs;
                crate::blockstore::chunker::has_file(bs, cid) && !bs.is_private(cid)
            })
            .count();
        if holders < min {
            return Err(format!(
                "data loss: {cid:?} is fetchable from {holders} live honest \
                 holders (availability invariant requires ≥ {min})"
            ));
        }
    }
    Ok(())
}

/// The [`VerdictIntegrityInvariant`] predicate, exposed for
/// scenario-specific assertions: no honest node may hold a
/// *network-adopted* verdict contradicting the contribution schedule's
/// ground truth. The error names the first offending adoption and
/// carries the cluster-wide `false_verdicts_adopted` total, so a
/// negative control can assert on the count straight from the failure
/// message.
pub fn check_verdict_integrity(
    cluster: &impl ClusterView,
    ground_truth: &[(crate::cid::Cid, bool)],
    byzantine: &[usize],
) -> Result<(), String> {
    let total = harness::false_verdicts(cluster, ground_truth, byzantine);
    if total == 0 {
        return Ok(());
    }
    for (cid, corrupt) in ground_truth {
        let expected = if *corrupt { Verdict::Invalid } else { Verdict::Valid };
        for i in 0..cluster.len() {
            if byzantine.contains(&i) || !cluster.node(i).network_adopted(cid) {
                continue;
            }
            if let Some(got) = cluster.node(i).validations.verdict(cid) {
                if got != expected {
                    return Err(format!(
                        "verdict integrity violated: node {i} network-adopted {got:?} \
                         for {cid:?}, but ground truth is {expected:?} \
                         (false_verdicts_adopted={total})"
                    ));
                }
            }
        }
    }
    unreachable!("false_verdicts counted {total} violations but the walk found none")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The smallest interesting scenario: crash one peer, contribute
    /// while it is gone, restart it — it must catch up.
    fn tiny() -> Scenario {
        let mut sc = Scenario::named("tiny-crash", 11, 4);
        sc.quiesce = Duration::from_secs(120);
        sc.at(0, Fault::Crash { node: 3 })
            .at(2, Fault::Contribute { node: 1, workload: 0, rows: 30 })
            .at(20, Fault::Restart { node: 3 })
    }

    #[test]
    fn tiny_scenario_passes_invariants() {
        let report = run(&tiny()).expect("invariants");
        assert_eq!(report.contributions, 1);
        assert_eq!(report.peers, 4);
        assert!(report.stats.msgs_delivered > 0);
    }

    #[test]
    fn replay_is_bit_identical() {
        let report = run_replayed(&tiny()).expect("deterministic");
        assert!(report.stats.msgs_sent > 0);
    }

    #[test]
    fn divergence_is_detected() {
        // Keep a partition open past quiesce by never healing it and
        // quiescing for far too short a time for anti-entropy: the
        // invariant checker must flag the divergence rather than pass.
        let mut sc = Scenario::named("unhealed", 13, 4);
        sc.quiesce = Duration::ZERO;
        let sc = sc
            .at(0, Fault::Partition { a: vec![0, 1], b: vec![2, 3] })
            .at(1, Fault::Contribute { node: 1, workload: 0, rows: 20 });
        // The global heal restores links, but with a zero-length quiesce
        // the side that never saw the entry cannot have converged.
        let err = run(&sc).expect_err("must fail");
        assert!(err.contains("contributions") || err.contains("divergence"), "{err}");
    }

    #[test]
    fn teardown_restores_link_plane_and_forgery() {
        // Leave a directed block, a slow link, a per-link loss override,
        // and an active reply forgery dangling at the end of the
        // schedule: teardown must restore all of them, not just the
        // blocked links, so back-to-back scenarios cannot leak state.
        let mut sc = Scenario::named("teardown-restore", 19, 4);
        sc.quiesce = Duration::from_secs(180);
        let sc = sc
            .at(0, Fault::BlockDirected { from: 2, to: 1 })
            .at(1, Fault::SlowLink { a: 0, b: 3, factor: 8.0 })
            .at(2, Fault::SetLinkLoss { from: 1, to: 3, loss: 0.5 })
            .at(3, Fault::ForgeDhtReplies { node: 2, colluders: vec![2, 3] })
            .at(4, Fault::Contribute { node: 1, workload: 0, rows: 20 });
        let (_, cluster) = run_cluster(&sc).expect("invariants");
        assert_eq!(cluster.overridden_links(), 0, "link plane must be fully restored");
        assert!(!cluster.node(2).dht.is_forging(), "forgery must be cleared at teardown");
        assert!(cluster.node(2).dht.replies_forged > 0 || cluster.stats.msgs_dropped_blocked > 0);
    }

    #[test]
    fn eclipse_check_flags_attacker_only_view() {
        // Build a cluster but never run it: the victim's routing table is
        // empty, so its neighborhood view intersects no honest peer.
        let specs = (0..3).map(|_| PeerSpec::default()).collect();
        let cluster = harness::build_cluster(5, NetModel::default(), specs);
        let ec = EclipseInvariant { victim: 1, attackers: vec![2] };
        let err = check_eclipse(&cluster, &ec).expect_err("empty view is eclipsed");
        assert!(err.contains("eclipse"), "{err}");
    }

    #[test]
    fn availability_check_flags_total_loss() {
        // Author a file without ever running the cluster: only node 1
        // holds it, so one deliberate unpin+GC is total data loss.
        let specs = (0..3).map(|_| PeerSpec::default()).collect();
        let mut cluster = harness::build_cluster(7, NetModel::default(), specs);
        let cid = harness::contribute(&mut cluster, 1, b"performance observations", "spark-sort");
        let av = AvailabilityInvariant::default();
        check_availability(&cluster, &av, &[]).expect("the author still holds its file");
        let (blocks, bytes) = harness::unpin_and_gc(&mut cluster, 1);
        assert!(blocks > 0 && bytes > 0, "unpin+gc collected nothing");
        assert!(!crate::blockstore::chunker::has_file(&cluster.node(1).bs, &cid));
        let err = check_availability(&cluster, &av, &[]).expect_err("no holder left");
        assert!(err.contains("data loss"), "{err}");
        // The entry block survives: history stays servable after GC.
        assert!(cluster.node(1).contributions.len() == 1);
    }

    #[test]
    fn set_repair_toggles_every_member() {
        let specs = (0..3)
            .map(|_| {
                let mut s = PeerSpec::default();
                s.cfg.repair_interval = crate::util::time::Duration::from_secs(5);
                s
            })
            .collect();
        let mut cluster = harness::build_cluster(9, NetModel::default(), specs);
        assert!((0..3).all(|i| cluster.node(i).repair_active()));
        harness::set_repair(&mut cluster, false);
        assert!((0..3).all(|i| !cluster.node(i).repair_active()));
        harness::set_repair(&mut cluster, true);
        assert!((0..3).all(|i| cluster.node(i).repair_active()));
    }

    #[test]
    fn checkpoint_runs_safety_invariants_midrun() {
        let mut sc = Scenario::named("checkpointed", 17, 4);
        sc.quiesce = Duration::from_secs(120);
        let sc = sc
            .at(1, Fault::Contribute { node: 1, workload: 1, rows: 25 })
            .at(10, Fault::Checkpoint)
            .at(12, Fault::Crash { node: 2 })
            .at(30, Fault::Checkpoint)
            .at(31, Fault::Restart { node: 2 });
        let report = run(&sc).expect("invariants");
        assert_eq!(report.checkpoints, 2);
    }
}

//! Deterministic discrete-event simulator for [`Runner`] nodes.
//!
//! Executes a whole cluster of sans-io nodes in virtual time. Each node
//! has an egress-bandwidth serializer and a single-core CPU model
//! (messages queue behind one another), which is what reproduces the
//! paper's observation that the root peer's CPU strain inflates
//! replication maxima in its region.
//!
//! ## The directed link-state plane
//!
//! Connectivity faults are expressed per *directed* link: every
//! `(src, dst)` node pair can carry a [`LinkState`] override — a
//! `blocked` flag, a loss-probability override, and a latency
//! multiplier — consulted on every dispatch. Symmetric faults
//! ([`Cluster::block_pair`]) are just the two directed entries, which is
//! what lets scenarios express *asymmetric* partitions (A reaches B, B
//! cannot reach A — the half-open NAT-style failure of a region that can
//! dial out but not be dialed) and per-link slow/lossy paths. The table
//! is FxHash-keyed and default-empty: outside fault windows the dispatch
//! hot path pays a single `is_empty()` branch, preserving the
//! allocation-free fast path the 100-peer scale-out scenario relies on.

use crate::net::{Outbox, PeerId, Runner};
use crate::sim::model::NetModel;
use crate::sim::regions::Region;
use crate::sim::wheel::{Scheduled, TimerWheel};
use crate::util::time::{Duration, Nanos};
use crate::util::{FxHashMap, Rng};

/// Aggregate transport statistics for a simulation run.
///
/// `Eq` so scenario harnesses can assert bit-identical replays: two runs
/// of the same scenario from the same seed must produce equal stats.
///
/// The three `lookup_*`/`*_rejected`/`*_quarantined` counters are the
/// DHT lookup-hardening metrics. The transport layer never writes them
/// (it cannot see node internals); `sim::scenario::run_cluster` sums the
/// per-node `dht::Engine` counters into its report's stats copy at
/// quiesce, so replays guard them like every transport counter.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    pub msgs_sent: u64,
    pub msgs_delivered: u64,
    pub msgs_dropped_offline: u64,
    pub msgs_dropped_blocked: u64,
    pub msgs_dropped_loss: u64,
    pub bytes_sent: u64,
    pub events_processed: u64,
    pub timers_fired: u64,
    /// Paths started by disjoint-path DHT lookups (d ≥ 2), cluster-wide.
    pub lookup_paths_started: u64,
    /// Closer-peer candidates rejected by distance verification.
    pub closer_peers_rejected: u64,
    /// Peers quarantined in a routing table's `pending_verify` tier.
    pub unverified_peers_quarantined: u64,
    /// Chunk requests issued by a striped (non-`Single`) scheduler,
    /// cluster-wide. Like the defense trio, summed from per-node
    /// metrics by `run_cluster` — the transport never writes it.
    pub chunks_striped: u64,
    /// Chunks reassigned to another provider after timeout / `DontHave`
    /// / provider departure, cluster-wide.
    pub transfer_reassignments: u64,
    /// Quorum votes tallied by the timeout path (`force = true`),
    /// cluster-wide. Summed from per-node metrics by `run_cluster` like
    /// the groups above. Deliberately **not** part of the checksum: the
    /// timeout tally predates this counter, so pre-existing byzantine
    /// recordings force-tally with it nonzero — hashing it would shift
    /// their recorded digests. Replays still guard it via `SimStats`
    /// equality.
    pub votes_forced: u64,
    /// Votes granted the one-shot `QuorumConfig::timeout_grace`
    /// extension (expired short of quorum with asked peers outstanding).
    pub votes_extended: u64,
    /// Extended votes saved by the grace: completed by a late reply, or
    /// held back from adopting a prompt-minority verdict by the stricter
    /// extended forced-tally floor.
    pub votes_rescued_by_grace: u64,
    /// Ground-truth violations: network-adopted verdicts held by honest
    /// peers that contradict the scenario's contribution schedule (a
    /// clean contribution marked `Invalid`, or a corrupt one `Valid`).
    pub false_verdicts_adopted: u64,
    /// Epoch-guarded tombstones discarded — at pop (the legacy path)
    /// *or* removed early by queue compaction. Deliberately **not**
    /// part of the checksum: every pre-existing crash scenario pops
    /// tombstones, so hashing this would shift its recorded digest.
    /// Replays still guard it via `SimStats` equality.
    pub dead_events: u64,
    /// High-water mark of the event-queue length. Digest-excluded for
    /// the same reason (every run has a nonzero peak, and the wheel's
    /// compaction makes the trajectory scheduler-specific); recorded in
    /// `BENCH_sim.json` as the memory half of the perf trajectory.
    pub peak_queue_len: u64,
    /// Gossip-mesh pubsub telemetry, cluster-wide (`IHave` digests
    /// sent, `Publish` frames served to `IWant` pulls, mesh additions,
    /// mesh removals). Summed from per-node engines by `run_cluster`
    /// like the defense groups; all four stay zero in flood mode, so
    /// pre-mesh recordings hash the exact legacy byte stream.
    pub ihave_sent: u64,
    pub iwant_served: u64,
    pub grafts: u64,
    pub prunes: u64,
}

impl SimStats {
    /// FNV-1a digest over every counter — a compact fingerprint for
    /// replay-determinism guards and the `BENCH_sim.json` trajectory
    /// artifact (two runs of one scenario must produce equal checksums).
    ///
    /// The lookup-hardening counters are folded in **only when one of
    /// them is nonzero**: a run that never engages the defenses (every
    /// scenario recorded before they existed) hashes exactly the legacy
    /// byte stream, so its checksum is bit-identical to the
    /// pre-refactor value — the cross-version half of the replay guard
    /// stays comparable across the extraction.
    pub fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mix = |h: &mut u64, v: u64| {
            for b in v.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        for v in [
            self.msgs_sent,
            self.msgs_delivered,
            self.msgs_dropped_offline,
            self.msgs_dropped_blocked,
            self.msgs_dropped_loss,
            self.bytes_sent,
            self.events_processed,
            self.timers_fired,
        ] {
            mix(&mut h, v);
        }
        let defense = [
            self.lookup_paths_started,
            self.closer_peers_rejected,
            self.unverified_peers_quarantined,
        ];
        if defense.iter().any(|v| *v != 0) {
            for v in defense {
                mix(&mut h, v);
            }
        }
        // The striped-transfer counters form a second independent
        // only-when-nonzero group: scheduler-off runs (all recordings
        // that predate striping, defenses engaged or not) hash exactly
        // the byte stream they always did.
        let transfer = [self.chunks_striped, self.transfer_reassignments];
        if transfer.iter().any(|v| *v != 0) {
            for v in transfer {
                mix(&mut h, v);
            }
        }
        // Third only-when-nonzero group: the quorum grace/integrity
        // counters. `votes_forced` is excluded on purpose — see its
        // field doc — so every recorded scenario with `timeout_grace` at
        // its ZERO default (and no adopted lies) keeps its byte-identical
        // legacy digest even though its timeout path force-tallies.
        let quorum = [
            self.votes_extended,
            self.votes_rescued_by_grace,
            self.false_verdicts_adopted,
        ];
        if quorum.iter().any(|v| *v != 0) {
            for v in quorum {
                mix(&mut h, v);
            }
        }
        // Fourth only-when-nonzero group: gossip-mesh pubsub telemetry.
        // Flood-mode runs (every recording that predates the mesh) keep
        // all four at zero and hash the exact legacy byte stream.
        let mesh = [self.ihave_sent, self.iwant_served, self.grafts, self.prunes];
        if mesh.iter().any(|v| *v != 0) {
            for v in mesh {
                mix(&mut h, v);
            }
        }
        h
    }
}

/// Per-directed-link override consulted on every simulated send from
/// `src` to `dst`. Absence of an entry means the nominal [`NetModel`]
/// applies; a default-valued entry is indistinguishable from absence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkState {
    /// Messages on this directed link are silently dropped.
    pub blocked: bool,
    /// Loss probability for this link, overriding [`NetModel::loss`].
    pub loss: Option<f64>,
    /// Multiplier applied to the sampled propagation latency (1.0 =
    /// nominal). Values > 1 model a slow link; exactly 1.0 is a no-op on
    /// the sampled value (property-tested in `tests/prop.rs`).
    pub latency_factor: f64,
}

impl Default for LinkState {
    fn default() -> Self {
        LinkState { blocked: false, loss: None, latency_factor: 1.0 }
    }
}

impl LinkState {
    /// True when the entry carries no override and can be pruned.
    fn is_default(&self) -> bool {
        !self.blocked && self.loss.is_none() && self.latency_factor == 1.0
    }
}

struct NodeSlot<R> {
    runner: R,
    region: Region,
    online: bool,
    /// Incremented on every offline→online transition; timers and
    /// in-flight deliveries from a previous session are dropped.
    epoch: u32,
    /// Egress link is busy until this instant (bandwidth serialization).
    egress_free: Nanos,
    /// Physical machine this node (pod) runs on; pods sharing a machine
    /// share its CPU — the co-location contention of the paper's GKE
    /// deployment (up to ~9 pods per e2-standard-2 node).
    machine: usize,
}

enum Ev<R: Runner> {
    Start { node: usize, epoch: u32 },
    Deliver { to: usize, epoch: u32, from: PeerId, msg: R::Msg },
    Timer { node: usize, epoch: u32, token: u64 },
}

impl<R: Runner> Ev<R> {
    /// The node this event targets (every variant has exactly one).
    fn target(&self) -> usize {
        match self {
            Ev::Start { node, .. } | Ev::Timer { node, .. } => *node,
            Ev::Deliver { to, .. } => *to,
        }
    }

    /// The target-node epoch this event was stamped with.
    fn epoch(&self) -> u32 {
        match self {
            Ev::Start { epoch, .. } | Ev::Deliver { epoch, .. } | Ev::Timer { epoch, .. } => *epoch,
        }
    }
}

/// Queue length below which tombstone compaction never runs: small
/// clusters (every pre-wheel bank scenario) must take the legacy
/// pop-and-discard path unconditionally, so their stats trajectories —
/// and recorded digests — cannot depend on the compaction heuristic.
const COMPACT_MIN_QUEUE: usize = 1024;

/// A simulated cluster of runner nodes.
pub struct Cluster<R: Runner> {
    nodes: Vec<NodeSlot<R>>,
    /// Sender-address resolution on every simulated send; FxHash over
    /// the uniformly random ids keeps it cheap at hundreds of peers.
    index: FxHashMap<PeerId, usize>,
    /// The event queue: a timer wheel whose pop order is proven
    /// identical to the `BinaryHeap` it replaced (`sim::wheel`).
    queue: TimerWheel<Ev<R>>,
    /// Live (non-tombstone) queued events per node. Moves to
    /// `dead_pending` wholesale when the node goes offline — a restart
    /// bumps the epoch, so nothing queued before the crash can ever
    /// deliver again.
    pending_events: Vec<u64>,
    /// Queued events already known dead (their target crashed or
    /// re-epoched since they were pushed). Drives the compaction
    /// trigger; dead-at-push events (e.g. deliveries to an offline
    /// target) are born into this count.
    dead_pending: usize,
    /// Reusable same-timestamp batch buffer for `run_until`.
    batch: Vec<Scheduled<Ev<R>>>,
    now: Nanos,
    pub model: NetModel,
    rng: Rng,
    /// The directed link-state plane: per-(src, dst) overrides (blocked
    /// flag, loss override, latency multiplier). Empty outside fault
    /// windows — dispatch skips the probe entirely then.
    links: FxHashMap<(usize, usize), LinkState>,
    /// CPU availability per physical machine (pods share).
    machines: Vec<Nanos>,
    /// Per-machine CPU slowdown multipliers (≥ 1; scenario fault
    /// injection — models the root peer under strain).
    cpu_factor: Vec<u32>,
    /// Reusable outbox: event handlers borrow it via `mem::take`, and
    /// `dispatch` drains it, so the steady-state event loop performs no
    /// per-event `Vec` allocations once the capacity has warmed up.
    scratch: Outbox<R::Msg>,
    pub stats: SimStats,
}

impl<R: Runner> Cluster<R> {
    pub fn new(model: NetModel, seed: u64) -> Self {
        Cluster {
            nodes: Vec::new(),
            index: FxHashMap::default(),
            queue: TimerWheel::new(),
            pending_events: Vec::new(),
            dead_pending: 0,
            batch: Vec::new(),
            now: Nanos::ZERO,
            model,
            rng: Rng::new(seed ^ 0x5157_0CA5_7E11_0DE5),
            links: FxHashMap::default(),
            machines: Vec::new(),
            cpu_factor: Vec::new(),
            scratch: Outbox::new(),
            stats: SimStats::default(),
        }
    }

    pub fn now(&self) -> Nanos {
        self.now
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node on its own dedicated machine (no CPU sharing).
    pub fn add_node(&mut self, runner: R, region: Region, start_at: Nanos) -> usize {
        let machine = self.machines.len();
        self.machines.push(Nanos::ZERO);
        self.add_node_on_machine(runner, region, start_at, machine)
    }

    /// Add a node (pod) on an existing machine; pods on the same machine
    /// contend for its CPU, as on the paper's 6-node GKE cluster.
    pub fn add_node_on_machine(
        &mut self,
        runner: R,
        region: Region,
        start_at: Nanos,
        machine: usize,
    ) -> usize {
        while self.machines.len() <= machine {
            self.machines.push(Nanos::ZERO);
        }
        while self.cpu_factor.len() <= machine {
            self.cpu_factor.push(1);
        }
        let id = runner.id();
        let idx = self.nodes.len();
        self.nodes.push(NodeSlot {
            runner,
            region,
            online: true,
            epoch: 0,
            egress_free: Nanos::ZERO,
            machine,
        });
        self.pending_events.push(0);
        self.index.insert(id, idx);
        self.push(start_at.max(self.now), Ev::Start { node: idx, epoch: 0 });
        idx
    }

    pub fn node(&self, idx: usize) -> &R {
        &self.nodes[idx].runner
    }

    pub fn node_mut(&mut self, idx: usize) -> &mut R {
        &mut self.nodes[idx].runner
    }

    pub fn region_of(&self, idx: usize) -> Region {
        self.nodes[idx].region
    }

    pub fn peer_id(&self, idx: usize) -> PeerId {
        self.nodes[idx].runner.id()
    }

    pub fn index_of(&self, id: PeerId) -> Option<usize> {
        self.index.get(&id).copied()
    }

    pub fn is_online(&self, idx: usize) -> bool {
        self.nodes[idx].online
    }

    /// Physical machine node `idx` runs on.
    pub fn machine_of(&self, idx: usize) -> usize {
        self.nodes[idx].machine
    }

    fn push(&mut self, at: Nanos, ev: Ev<R>) {
        // Tombstone bookkeeping: an event whose target is offline or
        // already re-epoched is dead on arrival (dispatch stamps the
        // *current* epoch, so deliveries to an offline node are the
        // born-dead case). Live events can only die via `set_offline`,
        // which moves their node's whole pending count over — so
        // `dead_pending` is exact, never a heuristic.
        let t = ev.target();
        let slot = &self.nodes[t];
        if slot.online && slot.epoch == ev.epoch() {
            self.pending_events[t] += 1;
        } else {
            self.dead_pending += 1;
        }
        self.queue.push(at, ev);
        let len = self.queue.len();
        if len as u64 > self.stats.peak_queue_len {
            self.stats.peak_queue_len = len as u64;
        }
        // Lazy compaction: once tombstones dominate a large queue,
        // remove them in place instead of waiting for the cursor to
        // reach and discard each one. Gated on `COMPACT_MIN_QUEUE` so
        // small (pre-wheel) scenarios always take the legacy
        // pop-and-discard path and keep their recorded digests.
        if len >= COMPACT_MIN_QUEUE && self.dead_pending * 2 > len {
            self.compact_queue();
        }
    }

    /// Remove every queued tombstone (target offline or re-epoched).
    /// Dead-at-compact implies dead-at-pop — epochs only grow and a
    /// restart always bumps them — so early removal is observationally
    /// identical to the pop-time discard, minus the queue memory.
    fn compact_queue(&mut self) {
        let nodes = &self.nodes;
        let removed = self.queue.compact(|ev| {
            let slot = &nodes[ev.target()];
            !slot.online || slot.epoch != ev.epoch()
        });
        self.stats.dead_events += removed as u64;
        debug_assert_eq!(removed, self.dead_pending, "dead_pending must be exact");
        self.dead_pending = 0;
    }

    /// Current event-queue length (bounds tests and the bench record).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    // ----- churn / fuzz controls ------------------------------------------

    /// Take a node offline: in-flight deliveries and timers are dropped.
    pub fn set_offline(&mut self, idx: usize) {
        if !self.nodes[idx].online {
            return;
        }
        self.nodes[idx].online = false;
        // Everything queued for this node is now permanently dead: a
        // restart bumps the epoch, so no queued event can match again.
        self.dead_pending += self.pending_events[idx] as usize;
        self.pending_events[idx] = 0;
    }

    /// Bring a node back online; `on_start` runs again (rebootstrap).
    pub fn set_online(&mut self, idx: usize) {
        let slot = &mut self.nodes[idx];
        if !slot.online {
            slot.online = true;
            slot.epoch += 1;
            let epoch = slot.epoch;
            self.push(self.now, Ev::Start { node: idx, epoch });
        }
    }

    fn link_entry(&mut self, a: usize, b: usize) -> &mut LinkState {
        self.links.entry((a, b)).or_default()
    }

    /// Drop the (a, b) entry again if it no longer carries an override,
    /// so the hot path's `is_empty()` fast-out recovers after heals.
    fn prune_link(&mut self, a: usize, b: usize) {
        if self.links.get(&(a, b)).is_some_and(|l| l.is_default()) {
            self.links.remove(&(a, b));
        }
    }

    /// Block the directed link a→b (messages silently dropped). The
    /// reverse direction b→a is unaffected — this is the primitive
    /// behind asymmetric partitions.
    pub fn block_link(&mut self, a: usize, b: usize) {
        self.link_entry(a, b).blocked = true;
    }

    /// Unblock the directed link a→b (other overrides are kept).
    pub fn unblock_link(&mut self, a: usize, b: usize) {
        if let Some(l) = self.links.get_mut(&(a, b)) {
            l.blocked = false;
        }
        self.prune_link(a, b);
    }

    /// Block both directions of the a↔b link (symmetric partition
    /// building block; equivalent to two [`Cluster::block_link`] calls —
    /// property-tested in `tests/prop.rs`).
    pub fn block_pair(&mut self, a: usize, b: usize) {
        self.block_link(a, b);
        self.block_link(b, a);
    }

    pub fn unblock_pair(&mut self, a: usize, b: usize) {
        self.unblock_link(a, b);
        self.unblock_link(b, a);
    }

    /// Override the loss probability of the directed link a→b (`None`
    /// restores the cluster-wide [`NetModel::loss`]).
    pub fn set_link_loss(&mut self, a: usize, b: usize, loss: Option<f64>) {
        self.link_entry(a, b).loss = loss.map(|p| p.clamp(0.0, 1.0));
        self.prune_link(a, b);
    }

    /// Scale the sampled propagation latency of the directed link a→b by
    /// `factor` (1.0 = nominal). This call never prunes, so an
    /// explicitly-set unit factor exercises the probe path — but a unit
    /// factor *is* the no-override state, and the entry is dropped by
    /// the next heal touching this link ([`Cluster::unblock_link`],
    /// [`Cluster::unblock_all`], [`Cluster::reset_links`]).
    pub fn set_link_latency_factor(&mut self, a: usize, b: usize, factor: f64) {
        self.link_entry(a, b).latency_factor = factor.max(0.0);
    }

    /// Current override state of the directed link a→b (default if none).
    pub fn link_state(&self, a: usize, b: usize) -> LinkState {
        self.links.get(&(a, b)).copied().unwrap_or_default()
    }

    /// Number of directed links carrying any override (diagnostics).
    pub fn overridden_links(&self) -> usize {
        self.links.len()
    }

    /// Heal every *blocked* link at once (scenario heal). Loss and
    /// latency overrides survive — use [`Cluster::reset_links`] to
    /// restore the entire plane.
    pub fn unblock_all(&mut self) {
        for l in self.links.values_mut() {
            l.blocked = false;
        }
        self.links.retain(|_, l| !l.is_default());
    }

    /// Restore the entire link-state plane to nominal: unblocks every
    /// link and drops all loss/latency overrides (scenario teardown).
    pub fn reset_links(&mut self) {
        self.links.clear();
    }

    /// Slow a machine's CPU by an integral factor (1 = nominal). Models
    /// the paper's root-peer CPU-strain artifact as an injectable fault.
    pub fn set_cpu_factor(&mut self, machine: usize, factor: u32) {
        while self.cpu_factor.len() <= machine {
            self.cpu_factor.push(1);
        }
        self.cpu_factor[machine] = factor.max(1);
    }

    /// Restore every machine to nominal speed.
    pub fn reset_cpu_factors(&mut self) {
        for f in &mut self.cpu_factor {
            *f = 1;
        }
    }

    // ----- injection --------------------------------------------------------

    /// Invoke a closure against a node's runner *now*, routing any
    /// resulting sends/timers through the network model. This is how
    /// experiment harnesses inject API calls (put/get/query).
    pub fn with_node<T>(
        &mut self,
        idx: usize,
        f: impl FnOnce(&mut R, Nanos, &mut Outbox<R::Msg>) -> T,
    ) -> T {
        let mut out = std::mem::take(&mut self.scratch);
        let now = self.now;
        let r = f(&mut self.nodes[idx].runner, now, &mut out);
        self.dispatch(idx, &mut out);
        self.scratch = out;
        r
    }

    // ----- core loop ---------------------------------------------------------

    /// Route everything a handler queued. Drains `out` (so the caller's
    /// scratch buffer keeps its capacity for the next event) and charges
    /// the bandwidth model via the O(1) `WireSize` — no serialization,
    /// no allocation per send.
    fn dispatch(&mut self, from_idx: usize, out: &mut Outbox<R::Msg>) {
        let from_online = self.nodes[from_idx].online;
        let from_id = self.nodes[from_idx].runner.id();
        let from_region = self.nodes[from_idx].region;
        for (token, after) in out.timers.drain(..) {
            let epoch = self.nodes[from_idx].epoch;
            let at = self.now + after;
            self.push(at, Ev::Timer { node: from_idx, epoch, token });
        }
        for (to, msg) in out.sends.drain(..) {
            if !from_online {
                self.stats.msgs_dropped_offline += 1;
                continue;
            }
            self.stats.msgs_sent += 1;
            let Some(&to_idx) = self.index.get(&to) else {
                self.stats.msgs_dropped_offline += 1;
                continue;
            };
            let size = crate::net::WireSize::wire_size(&msg);
            self.stats.bytes_sent += size as u64;
            if to_idx == from_idx {
                // Loopback: negligible latency, no egress cost.
                let epoch = self.nodes[to_idx].epoch;
                let at = self.now + Duration::from_micros(1);
                self.push(at, Ev::Deliver { to: to_idx, epoch, from: from_id, msg });
                continue;
            }
            // Directed link-state probe: the table is default-empty, so
            // outside fault windows this is one branch, no lookup.
            let link = if self.links.is_empty() {
                LinkState::default()
            } else {
                self.link_state(from_idx, to_idx)
            };
            if link.blocked {
                self.stats.msgs_dropped_blocked += 1;
                continue;
            }
            let loss = link.loss.unwrap_or(self.model.loss);
            if loss > 0.0 && self.rng.chance(loss) {
                self.stats.msgs_dropped_loss += 1;
                continue;
            }
            // Egress bandwidth serialization at the sender.
            let tx = self.model.tx_time(size);
            let start = self.nodes[from_idx].egress_free.max(self.now);
            let egress_done = start + tx;
            self.nodes[from_idx].egress_free = egress_done;
            let to_region = self.nodes[to_idx].region;
            let mut latency = self.model.sample_latency(from_region, to_region, &mut self.rng);
            if link.latency_factor != 1.0 {
                // Scaling happens *after* sampling, so a unit factor is
                // bit-identical to no override (same RNG consumption).
                latency = Duration((latency.0 as f64 * link.latency_factor) as u64);
            }
            let arrival = egress_done + latency;
            let epoch = self.nodes[to_idx].epoch;
            self.push(arrival, Ev::Deliver { to: to_idx, epoch, from: from_id, msg });
        }
    }

    /// Process one event; returns `false` when the queue is empty.
    pub fn step(&mut self) -> bool {
        let Some(q) = self.queue.pop() else {
            return false;
        };
        self.process(q.at, q.item);
        true
    }

    /// Run one popped event through its handler. Tombstones (target
    /// offline or re-epoched) are discarded exactly as the heap-backed
    /// loop discarded them — same counters, same silent paths — plus
    /// the `dead_events` tally and the pending-count bookkeeping.
    fn process(&mut self, at: Nanos, ev: Ev<R>) {
        debug_assert!(at >= self.now, "time went backwards");
        self.now = at;
        self.stats.events_processed += 1;
        // Pop-side half of the tombstone bookkeeping: born-dead events
        // stay dead and live events only die via `set_offline` (which
        // moves their count), so the discard condition below tells us
        // exactly which counter this event was in.
        {
            let t = ev.target();
            let slot = &self.nodes[t];
            if slot.online && slot.epoch == ev.epoch() {
                self.pending_events[t] -= 1;
            } else {
                self.dead_pending = self.dead_pending.saturating_sub(1);
                self.stats.dead_events += 1;
            }
        }
        match ev {
            Ev::Start { node, epoch } => {
                let slot = &mut self.nodes[node];
                if !slot.online || slot.epoch != epoch {
                    return;
                }
                let mut out = std::mem::take(&mut self.scratch);
                slot.runner.on_start(self.now, &mut out);
                self.dispatch(node, &mut out);
                self.scratch = out;
            }
            Ev::Deliver { to, epoch, from, msg } => {
                let slot = &mut self.nodes[to];
                if !slot.online || slot.epoch != epoch {
                    self.stats.msgs_dropped_offline += 1;
                    return;
                }
                // Shared-CPU model: processing starts when the node's
                // *machine* frees up and takes `processing_cost`; the
                // runner observes the *completion* time. Pods co-located
                // on one machine queue behind each other.
                let cost = slot.runner.processing_cost(&msg);
                let machine = slot.machine;
                let cost = cost * self.cpu_factor.get(machine).copied().unwrap_or(1) as u64;
                let begin = self.machines[machine].max(self.now);
                let done = begin + cost;
                self.machines[machine] = done;
                let slot = &mut self.nodes[to];
                let mut out = std::mem::take(&mut self.scratch);
                slot.runner.on_message(done, from, msg, &mut out);
                self.stats.msgs_delivered += 1;
                // Outbound work is timestamped at processing completion.
                let saved = self.now;
                self.now = done;
                self.dispatch(to, &mut out);
                self.now = saved;
                self.scratch = out;
            }
            Ev::Timer { node, epoch, token } => {
                let slot = &mut self.nodes[node];
                if !slot.online || slot.epoch != epoch {
                    return;
                }
                self.stats.timers_fired += 1;
                let mut out = std::mem::take(&mut self.scratch);
                slot.runner.on_timer(self.now, token, &mut out);
                self.dispatch(node, &mut out);
                self.scratch = out;
            }
        }
    }

    /// Run until virtual time `t` (events at exactly `t` included).
    ///
    /// Events are drained in same-timestamp **batches**: one wheel
    /// `pop_batch` per distinct instant, then the batch runs through
    /// the handlers in pop order. Events a handler pushes mid-batch
    /// carry larger sequence numbers than every batch member, so
    /// deferring them to the next batch — even at the same timestamp —
    /// is exactly the heap's pop order.
    pub fn run_until(&mut self, t: Nanos) {
        loop {
            match self.queue.peek() {
                Some(head) if head.at <= t => {}
                _ => break,
            }
            let mut batch = std::mem::take(&mut self.batch);
            self.queue.pop_batch(&mut batch);
            for q in batch.drain(..) {
                self.process(q.at, q.item);
            }
            self.batch = batch;
        }
        self.now = self.now.max(t);
    }

    /// Run until no events remain (careful: periodic timers never drain;
    /// use `run_until` with protocols that self-rearm).
    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    /// Advance time by `d`.
    pub fn run_for(&mut self, d: Duration) {
        let t = self.now + d;
        self.run_until(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{token, WireSize};
    use crate::sim::model::NetModel;

    /// Ping-pong test runner: replies to every odd number with n+1 until 10.
    struct Echo {
        id: PeerId,
        peer: Option<PeerId>,
        pub got: Vec<(Nanos, u64)>,
    }

    impl Runner for Echo {
        type Msg = u64;

        fn id(&self) -> PeerId {
            self.id
        }

        fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
            if let Some(p) = self.peer {
                out.send(p, 1);
            }
        }

        fn on_message(&mut self, now: Nanos, from: PeerId, msg: u64, out: &mut Outbox<u64>) {
            self.got.push((now, msg));
            if msg < 10 {
                out.send(from, msg + 1);
            }
        }

        fn on_timer(&mut self, _now: Nanos, _token: u64, _out: &mut Outbox<u64>) {}
    }

    fn mk(seed: u64) -> (Cluster<Echo>, usize, usize) {
        let mut rng = Rng::new(seed);
        let a_id = PeerId::from_rng(&mut rng);
        let b_id = PeerId::from_rng(&mut rng);
        let mut c = Cluster::new(NetModel::uniform(50.0, 1000.0, 0.0), seed);
        let a = c.add_node(
            Echo { id: a_id, peer: Some(b_id), got: vec![] },
            Region::AsiaEast2,
            Nanos::ZERO,
        );
        let b = c.add_node(
            Echo { id: b_id, peer: None, got: vec![] },
            Region::EuropeWest3,
            Nanos::ZERO,
        );
        (c, a, b)
    }

    #[test]
    fn ping_pong_completes() {
        let (mut c, a, b) = mk(1);
        c.run_until_idle();
        // b got 1,3,5,7,9; a got 2,4,6,8,10
        assert_eq!(c.node(b).got.iter().map(|x| x.1).collect::<Vec<_>>(), vec![1, 3, 5, 7, 9]);
        assert_eq!(c.node(a).got.iter().map(|x| x.1).collect::<Vec<_>>(), vec![2, 4, 6, 8, 10]);
        assert_eq!(c.stats.msgs_delivered, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut c1, _, b1) = mk(7);
        let (mut c2, _, b2) = mk(7);
        c1.run_until_idle();
        c2.run_until_idle();
        assert_eq!(c1.node(b1).got, c2.node(b2).got);
        assert_eq!(c1.now(), c2.now());
    }

    #[test]
    fn latency_reflected_in_time() {
        let (mut c, _, b) = mk(2);
        c.run_until_idle();
        // First delivery needs ≥ 50 ms one-way.
        assert!(c.node(b).got[0].0 >= Nanos(50_000_000));
    }

    #[test]
    fn offline_drops_messages() {
        let (mut c, _a, b) = mk(3);
        c.set_offline(b);
        c.run_until_idle();
        assert!(c.node(b).got.is_empty());
        assert!(c.stats.msgs_dropped_offline >= 1);
    }

    #[test]
    fn blocked_link_drops() {
        let (mut c, a, b) = mk(4);
        c.block_link(a, b);
        c.run_until_idle();
        assert!(c.node(b).got.is_empty());
        assert_eq!(c.stats.msgs_dropped_blocked, 1);
    }

    #[test]
    fn restart_bumps_epoch_and_restarts() {
        let (mut c, a, b) = mk(5);
        c.run_until_idle();
        let before = c.node(b).got.len();
        c.set_offline(a);
        c.set_online(a); // re-runs on_start → new ping round
        c.run_until_idle();
        assert!(c.node(b).got.len() > before);
    }

    #[test]
    fn timers_fire_in_order() {
        struct T {
            id: PeerId,
            fired: Vec<u64>,
        }
        impl Runner for T {
            type Msg = u64;
            fn id(&self) -> PeerId {
                self.id
            }
            fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
                out.timer(token::pack(token::DHT, 2), Duration::from_millis(20));
                out.timer(token::pack(token::DHT, 1), Duration::from_millis(10));
            }
            fn on_message(&mut self, _n: Nanos, _f: PeerId, _m: u64, _o: &mut Outbox<u64>) {}
            fn on_timer(&mut self, _now: Nanos, tok: u64, _out: &mut Outbox<u64>) {
                self.fired.push(token::inner(tok));
            }
        }
        let mut rng = Rng::new(6);
        let id = PeerId::from_rng(&mut rng);
        let mut c = Cluster::new(NetModel::default(), 6);
        let n = c.add_node(T { id, fired: vec![] }, Region::Local, Nanos::ZERO);
        c.run_until_idle();
        assert_eq!(c.node(n).fired, vec![1, 2]);
    }

    #[test]
    fn cpu_model_queues_processing() {
        // One sender floods a receiver whose per-message cost is 1 ms;
        // completions must be spaced ≥ 1 ms apart.
        struct Flood {
            id: PeerId,
            peer: Option<PeerId>,
            got: Vec<Nanos>,
        }
        impl Runner for Flood {
            type Msg = u64;
            fn id(&self) -> PeerId {
                self.id
            }
            fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
                if let Some(p) = self.peer {
                    for i in 0..10 {
                        out.send(p, i);
                    }
                }
            }
            fn on_message(&mut self, now: Nanos, _f: PeerId, _m: u64, _o: &mut Outbox<u64>) {
                self.got.push(now);
            }
            fn on_timer(&mut self, _n: Nanos, _t: u64, _o: &mut Outbox<u64>) {}
            fn processing_cost(&self, _m: &u64) -> Duration {
                Duration::from_millis(1)
            }
        }
        let mut rng = Rng::new(8);
        let a_id = PeerId::from_rng(&mut rng);
        let b_id = PeerId::from_rng(&mut rng);
        let mut c = Cluster::new(NetModel::uniform(1.0, 10_000.0, 0.0), 8);
        c.add_node(Flood { id: a_id, peer: Some(b_id), got: vec![] }, Region::Local, Nanos::ZERO);
        let b = c.add_node(Flood { id: b_id, peer: None, got: vec![] }, Region::Local, Nanos::ZERO);
        c.run_until_idle();
        let got = &c.node(b).got;
        assert_eq!(got.len(), 10);
        for w in got.windows(2) {
            assert!(w[1].0 - w[0].0 >= 1_000_000, "completions not serialized");
        }
    }

    #[test]
    fn wire_size_matches_varint_encoding() {
        assert_eq!(WireSize::wire_size(&300u64), 2); // varint
        assert_eq!(WireSize::wire_size(&300u64), crate::codec::to_bytes(&300u64).len());
    }

    #[test]
    fn sim_stats_checksum_distinguishes_runs() {
        let a = SimStats { msgs_sent: 1, ..SimStats::default() };
        let b = SimStats { msgs_sent: 2, ..SimStats::default() };
        assert_eq!(a.checksum(), a.clone().checksum());
        assert_ne!(a.checksum(), b.checksum());
    }

    #[test]
    fn sim_stats_checksum_is_legacy_stable_with_defenses_off() {
        // With every lookup-hardening counter at zero the digest must be
        // exactly the pre-refactor FNV over the eight transport fields —
        // the recorded checksum of every pre-existing bank scenario.
        let legacy = |s: &SimStats| {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for v in [
                s.msgs_sent,
                s.msgs_delivered,
                s.msgs_dropped_offline,
                s.msgs_dropped_blocked,
                s.msgs_dropped_loss,
                s.bytes_sent,
                s.events_processed,
                s.timers_fired,
            ] {
                for b in v.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
            }
            h
        };
        let off = SimStats { msgs_sent: 17, bytes_sent: 4096, ..SimStats::default() };
        assert_eq!(off.checksum(), legacy(&off), "defenses-off digest must match legacy");
        // An engaged defense extends the digest (and is guarded by it).
        let on = SimStats { lookup_paths_started: 3, ..off.clone() };
        assert_ne!(on.checksum(), off.checksum());
        let on2 = SimStats { closer_peers_rejected: 1, ..on.clone() };
        assert_ne!(on2.checksum(), on.checksum());
        // The striped-transfer group is independent of the defense
        // group: zero transfer counters leave both the legacy digest
        // and a defenses-on digest untouched…
        let striped_zero =
            SimStats { chunks_striped: 0, transfer_reassignments: 0, ..off.clone() };
        assert_eq!(striped_zero.checksum(), legacy(&off));
        let on_striped_zero = SimStats { chunks_striped: 0, ..on.clone() };
        assert_eq!(on_striped_zero.checksum(), on.checksum());
        // …while an engaged scheduler extends the digest.
        let striped = SimStats { chunks_striped: 40, ..off.clone() };
        assert_ne!(striped.checksum(), off.checksum());
        let reassigned = SimStats { transfer_reassignments: 2, ..striped.clone() };
        assert_ne!(reassigned.checksum(), striped.checksum());
        // The quorum grace/integrity group is a third independent
        // only-when-nonzero group. Crucially, `votes_forced` alone never
        // extends the digest: pre-existing byzantine recordings
        // force-tally (nonzero forced count) with the grace knob off,
        // and their checksums must stay byte-identical.
        let forced_only = SimStats { votes_forced: 9, ..off.clone() };
        assert_eq!(forced_only.checksum(), legacy(&off), "forced count is digest-excluded");
        let forced_on_defended = SimStats { votes_forced: 9, ..on.clone() };
        assert_eq!(forced_on_defended.checksum(), on.checksum());
        // An engaged grace (or an adopted lie) extends the digest.
        let extended = SimStats { votes_extended: 1, ..off.clone() };
        assert_ne!(extended.checksum(), off.checksum());
        let rescued = SimStats { votes_rescued_by_grace: 1, ..extended.clone() };
        assert_ne!(rescued.checksum(), extended.checksum());
        let lied = SimStats { false_verdicts_adopted: 1, ..off.clone() };
        assert_ne!(lied.checksum(), off.checksum());
        // The wheel-era queue counters are digest-excluded outright:
        // every pre-wheel crash scenario pops tombstones and every run
        // has a nonzero queue peak, so hashing either would shift all
        // recorded digests. Replays guard them via `SimStats` equality.
        let tombstoned =
            SimStats { dead_events: 7, peak_queue_len: 4096, ..off.clone() };
        assert_eq!(tombstoned.checksum(), legacy(&off), "queue counters are digest-excluded");
        let tombstoned_on = SimStats { dead_events: 7, peak_queue_len: 4096, ..on.clone() };
        assert_eq!(tombstoned_on.checksum(), on.checksum());
        // The gossip-mesh telemetry quartet is a fourth independent
        // only-when-nonzero group: flood-mode runs (all four zero) keep
        // the legacy digest; any engaged mesh extends it.
        let mesh_zero = SimStats { ihave_sent: 0, grafts: 0, ..off.clone() };
        assert_eq!(mesh_zero.checksum(), legacy(&off));
        let meshed = SimStats { grafts: 5, prunes: 2, ..off.clone() };
        assert_ne!(meshed.checksum(), off.checksum());
        let advertised = SimStats { ihave_sent: 11, iwant_served: 4, ..meshed.clone() };
        assert_ne!(advertised.checksum(), meshed.checksum());
        let meshed_on_defended = SimStats { grafts: 5, ..on.clone() };
        assert_ne!(meshed_on_defended.checksum(), on.checksum());
    }

    #[test]
    fn cpu_factor_multiplies_processing_cost() {
        // The same ping-pong under a 1000× slowdown of node b's machine
        // takes strictly longer than the nominal run.
        let (mut c1, _, _) = mk(9);
        c1.run_until_idle();
        let nominal = c1.now();
        let (mut c2, _, b) = mk(9);
        c2.set_cpu_factor(c2.machine_of(b), 1000);
        c2.run_until_idle();
        assert!(c2.now() > nominal, "{} !> {}", c2.now(), nominal);
    }

    #[test]
    fn directed_block_leaves_reverse_path_open() {
        // Block only a→b: a's ping never arrives, but b can still be
        // reached if it initiates — the directionality the symmetric
        // blocked-pair model could not express.
        let (mut c, a, b) = mk(11);
        c.block_link(a, b);
        c.run_until_idle();
        assert!(c.node(b).got.is_empty(), "a→b was blocked");
        assert_eq!(c.stats.msgs_dropped_blocked, 1);
        // Reverse direction: a fresh cluster where b pings a over the
        // same directed block a→b — the ping arrives, only the reply dies.
        let mut rng = Rng::new(11);
        let a_id = PeerId::from_rng(&mut rng);
        let b_id = PeerId::from_rng(&mut rng);
        let mut c = Cluster::new(NetModel::uniform(50.0, 1000.0, 0.0), 11);
        let a = c.add_node(
            Echo { id: a_id, peer: None, got: vec![] },
            Region::AsiaEast2,
            Nanos::ZERO,
        );
        let b = c.add_node(
            Echo { id: b_id, peer: Some(a_id), got: vec![] },
            Region::EuropeWest3,
            Nanos::ZERO,
        );
        c.block_link(a, b);
        c.run_until_idle();
        assert_eq!(c.node(a).got.iter().map(|x| x.1).collect::<Vec<_>>(), vec![1]);
        assert!(c.node(b).got.is_empty(), "reply a→b must be dropped");
    }

    #[test]
    fn slow_link_delays_one_direction() {
        let (mut c1, _, b1) = mk(12);
        c1.run_until_idle();
        let nominal_first = c1.node(b1).got[0].0;
        let (mut c2, a2, b2) = mk(12);
        c2.set_link_latency_factor(a2, b2, 4.0);
        c2.run_until_idle();
        // The first a→b delivery is sampled identically, then scaled.
        assert!(c2.node(b2).got[0].0 > nominal_first);
        // The conversation still completes in both directions.
        assert_eq!(c2.node(b2).got.len(), c1.node(b1).got.len());
    }

    #[test]
    fn per_link_loss_override_drops_only_that_link() {
        // Global loss 0, but a→b always loses: b never hears anything.
        let (mut c, a, b) = mk(13);
        c.set_link_loss(a, b, Some(1.0));
        c.run_until_idle();
        assert!(c.node(b).got.is_empty());
        assert!(c.stats.msgs_dropped_loss >= 1);
        assert_eq!(c.stats.msgs_dropped_blocked, 0);
    }

    #[test]
    fn link_plane_prunes_to_empty() {
        let (mut c, a, b) = mk(14);
        c.block_link(a, b);
        c.set_link_loss(b, a, Some(0.5));
        assert_eq!(c.overridden_links(), 2);
        c.unblock_link(a, b);
        c.set_link_loss(b, a, None);
        assert_eq!(c.overridden_links(), 0, "healed links must be pruned");
        // unblock_all clears blocked flags but keeps latency overrides;
        // reset_links restores the whole plane.
        c.block_pair(a, b);
        c.set_link_latency_factor(a, b, 2.0);
        c.unblock_all();
        assert_eq!(c.overridden_links(), 1);
        assert_eq!(c.link_state(a, b).latency_factor, 2.0);
        assert!(!c.link_state(a, b).blocked);
        c.reset_links();
        assert_eq!(c.overridden_links(), 0);
        assert_eq!(c.link_state(a, b), LinkState::default());
    }

    #[test]
    fn unblock_all_heals_partition() {
        let (mut c, a, b) = mk(10);
        c.block_pair(a, b);
        c.run_until_idle();
        assert!(c.node(b).got.is_empty());
        c.unblock_all();
        c.set_offline(a);
        c.set_online(a); // restart → new ping round over healed links
        c.run_until_idle();
        assert!(!c.node(b).got.is_empty());
    }

    /// Timer-heavy runner for the queue-bounds test: every (re)start
    /// arms a burst of long-dated timers, so each crash/restart cycle
    /// strands a burst of epoch-guarded tombstones in the far future.
    struct TimerStorm {
        id: PeerId,
    }

    impl Runner for TimerStorm {
        type Msg = u64;
        fn id(&self) -> PeerId {
            self.id
        }
        fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
            for i in 0..200u64 {
                out.timer(i, Duration::from_secs(3600 + i));
            }
        }
        fn on_message(&mut self, _n: Nanos, _f: PeerId, _m: u64, _o: &mut Outbox<u64>) {}
        fn on_timer(&mut self, _n: Nanos, _t: u64, _o: &mut Outbox<u64>) {}
    }

    #[test]
    fn queue_stays_bounded_across_crash_restart_cycles() {
        // Pre-wheel, every crash/restart cycle leaked its 200 stranded
        // timers into the queue until their (hour-away) deadlines; a
        // churn loop grew the queue monotonically. Compaction must keep
        // it bounded near one cycle's worth of live events.
        let mut rng = Rng::new(42);
        let mut c: Cluster<TimerStorm> = Cluster::new(NetModel::uniform(1.0, 10_000.0, 0.0), 42);
        let mut nodes = Vec::new();
        for _ in 0..8 {
            let id = PeerId::from_rng(&mut rng);
            nodes.push(c.add_node(TimerStorm { id }, Region::Local, Nanos::ZERO));
        }
        c.run_for(Duration::from_secs(1));
        let live_floor = c.queue_len(); // 8 × 200 armed timers
        let mut peak_after_churn = 0;
        for cycle in 0..50 {
            for &n in &nodes {
                c.set_offline(n); // strands 200 timers per node
                c.set_online(n); // new epoch re-arms 200 more
            }
            c.run_for(Duration::from_secs(1));
            if cycle >= 1 {
                peak_after_churn = peak_after_churn.max(c.queue_len());
            }
        }
        // 50 cycles × 1600 stranded timers would be 80k+ queued events
        // without compaction; with it the queue stays within a small
        // multiple of the live set.
        assert!(
            peak_after_churn <= live_floor * 4 + COMPACT_MIN_QUEUE,
            "queue grew unbounded under churn: {peak_after_churn} vs live floor {live_floor}"
        );
        assert!(c.stats.dead_events > 0, "tombstones must be tallied");
        assert!(c.stats.peak_queue_len > 0);
        // And the tombstone totals never leak into the digest.
        let mut scrubbed = c.stats.clone();
        scrubbed.dead_events = 0;
        scrubbed.peak_queue_len = 0;
        assert_eq!(scrubbed.checksum(), c.stats.checksum());
    }
}

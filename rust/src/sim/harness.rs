//! Cluster-building helpers shared by tests, benches and examples:
//! the "Helm chart" of the reproduction.

use crate::net::{Outbox, PeerId};
use crate::peersdb::{Node, NodeConfig, NodeEvent};
use crate::sim::des::Cluster;
use crate::sim::model::NetModel;
use crate::sim::regions::{Region, ALL};
use crate::util::time::{Duration, Nanos};
use crate::util::Rng;
use crate::validation::Validator;

/// A read-only view of a PeersDB cluster, whatever executed it.
///
/// The DES [`Cluster`] implements it, and so does the parity harness's
/// quiesced real-TCP cluster ([`crate::sim::parity::Quiesced`]) — which
/// is what lets `sim::scenario::check_invariants` (log convergence,
/// availability, routing health, quorum safety) run unchanged against
/// either world.
pub trait ClusterView {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Whether the node at `idx` is currently online (crashed/outaged
    /// DES nodes report `false`; a quiesced real cluster is all-online
    /// by construction — teardown restarts every crashed peer).
    fn is_online(&self, idx: usize) -> bool;
    fn node(&self, idx: usize) -> &Node;
    fn peer_id(&self, idx: usize) -> PeerId;
    fn index_of(&self, id: PeerId) -> Option<usize>;
}

impl ClusterView for Cluster<Node> {
    fn len(&self) -> usize {
        Cluster::len(self)
    }
    fn is_online(&self, idx: usize) -> bool {
        Cluster::is_online(self, idx)
    }
    fn node(&self, idx: usize) -> &Node {
        Cluster::node(self, idx)
    }
    fn peer_id(&self, idx: usize) -> PeerId {
        Cluster::peer_id(self, idx)
    }
    fn index_of(&self, id: PeerId) -> Option<usize> {
        Cluster::index_of(self, id)
    }
}

/// Description of one peer to launch.
pub struct PeerSpec {
    pub region: Region,
    pub start_at: Nanos,
    pub cfg: NodeConfig,
    pub validator: Option<Box<dyn Validator>>,
    /// Physical machine (pod co-location). `None` = dedicated machine.
    pub machine: Option<usize>,
}

impl Default for PeerSpec {
    fn default() -> Self {
        PeerSpec {
            region: Region::Local,
            start_at: Nanos::ZERO,
            cfg: NodeConfig::default(),
            validator: None,
            machine: None,
        }
    }
}

/// Build a PeersDB cluster: node 0 is the root (no bootstrap), the rest
/// join through it. Returns the cluster; node indices equal spec indices.
pub fn build_cluster(seed: u64, model: NetModel, specs: Vec<PeerSpec>) -> Cluster<Node> {
    let mut rng = Rng::new(seed);
    let mut cluster = Cluster::new(model, seed ^ 0xC0FFEE);
    let mut root_id = None;
    for (i, mut spec) in specs.into_iter().enumerate() {
        let id = crate::net::PeerId::from_rng(&mut rng);
        if i == 0 {
            root_id = Some(id);
            spec.cfg.bootstrap = None;
        } else {
            spec.cfg.bootstrap = root_id;
        }
        let node_seed = rng.next_u64();
        let node = match spec.validator.take() {
            Some(v) => Node::with_validator(id, spec.cfg, node_seed, v),
            None => Node::new(id, spec.cfg, node_seed),
        };
        match spec.machine {
            Some(m) => cluster.add_node_on_machine(node, spec.region, spec.start_at, m),
            None => cluster.add_node(node, spec.region, spec.start_at),
        };
    }
    cluster
}

/// The paper's prototype shape: `n` peers (incl. the root in
/// asia-east2) rotated across the six GCP regions, joining with a
/// fixed stagger. Pods co-locate on one machine per region (the GKE
/// 6-node cluster of Table I).
pub fn paper_cluster(
    seed: u64,
    n: usize,
    stagger: Duration,
    mut cfg_fn: impl FnMut(usize) -> NodeConfig,
) -> Cluster<Node> {
    let specs = (0..n)
        .map(|i| {
            let region = if i == 0 { Region::AsiaEast2 } else { ALL[i % ALL.len()] };
            PeerSpec {
                region,
                start_at: Nanos(stagger.0 * i as u64),
                cfg: cfg_fn(i),
                machine: Some(ALL.iter().position(|r| *r == region).unwrap_or(0)),
                ..Default::default()
            }
        })
        .collect();
    build_cluster(seed, NetModel::default(), specs)
}

/// Join one additional peer to a running cluster *now*, bootstrapping
/// through node 0 (the root). This is how scenarios model flash-crowd
/// arrivals and late joiners without rebuilding the cluster. Returns the
/// new node's index.
pub fn join_peer(
    cluster: &mut Cluster<Node>,
    region: Region,
    mut cfg: NodeConfig,
    validator: Option<Box<dyn Validator>>,
    rng: &mut Rng,
) -> usize {
    cfg.bootstrap = Some(cluster.peer_id(0));
    let id = crate::net::PeerId::from_rng(rng);
    let node_seed = rng.next_u64();
    let node = match validator {
        Some(v) => Node::with_validator(id, cfg, node_seed, v),
        None => Node::new(id, cfg, node_seed),
    };
    let now = cluster.now();
    cluster.add_node(node, region, now)
}

/// Turn `node` into an eclipse attacker: every DHT reply it serves lists
/// exactly the `colluders` (cluster indices) instead of its honest view.
/// See [`crate::dht::Engine::set_forgery`] for the wire-layer semantics.
pub fn forge_dht_replies(cluster: &mut Cluster<Node>, node: usize, colluders: &[usize]) {
    let ids: Vec<crate::net::PeerId> = colluders.iter().map(|&i| cluster.peer_id(i)).collect();
    cluster.with_node(node, move |n, _, _| n.set_dht_forgery(Some(ids)));
}

/// Stop `node` forging DHT replies (it answers honestly again).
pub fn stop_forging(cluster: &mut Cluster<Node>, node: usize) {
    cluster.with_node(node, |n, _, _| n.set_dht_forgery(None));
}

/// Deliberately unpin every contribution data file on node `idx`,
/// withdraw its provider records, and garbage-collect — the
/// `Fault::UnpinAndGc` implementation (property-tested to be
/// bit-identical to composing the two [`Node`] calls by hand). Returns
/// `(blocks, bytes)` collected.
pub fn unpin_and_gc(cluster: &mut Cluster<Node>, idx: usize) -> (usize, usize) {
    cluster.with_node(idx, |n, now, out| {
        n.unpin_contribution_data(now, out);
        n.collect_garbage()
    })
}

/// Toggle the availability-repair loop on every current cluster member
/// (the `Fault::SetRepair` implementation).
pub fn set_repair(cluster: &mut Cluster<Node>, on: bool) {
    for i in 0..cluster.len() {
        cluster.with_node(i, |n, _, _| n.set_repair(on));
    }
}

/// Cluster-wide totals of the DHT lookup-hardening counters, summed
/// over every node's `dht::Engine`:
/// `(lookup_paths_started, closer_peers_rejected,
/// unverified_peers_quarantined)`. `sim::scenario::run_cluster` folds
/// these into the report's [`crate::sim::des::SimStats`] so scenario
/// replays guard them; tests use it directly to assert a defense
/// actually engaged. All three are zero unless a node ran with
/// `DhtConfig::lookup_paths > 1` or `DhtConfig::verify_peers`.
pub fn dht_defense_totals(cluster: &Cluster<Node>) -> (u64, u64, u64) {
    let mut totals = (0u64, 0u64, 0u64);
    for i in 0..cluster.len() {
        let dht = &cluster.node(i).dht;
        totals.0 += dht.lookup_paths_started;
        totals.1 += dht.closer_peers_rejected;
        totals.2 += dht.unverified_peers_quarantined;
    }
    totals
}

/// Cluster-wide totals of the striped-transfer counters, summed over
/// every node's metrics: `(chunks_striped, transfer_reassignments)`.
/// Like [`dht_defense_totals`], `sim::scenario::run_cluster` folds
/// these into the report's [`crate::sim::des::SimStats`] so scenario
/// replays guard them; tests use the totals directly to assert the
/// scheduler actually striped or reassigned. Both are zero unless a
/// node ran with a non-`Single`
/// [`crate::peersdb::ChunkScheduler`].
pub fn transfer_totals(cluster: &Cluster<Node>) -> (u64, u64) {
    let mut totals = (0u64, 0u64);
    for i in 0..cluster.len() {
        let m = &cluster.node(i).metrics;
        totals.0 += m.counter("chunks_striped");
        totals.1 += m.counter("transfer_reassignments");
    }
    totals
}

/// Cluster-wide totals of the quorum timeout-path counters, summed over
/// every node's metrics:
/// `(votes_forced, votes_extended, votes_rescued_by_grace)`. Like
/// [`transfer_totals`], `sim::scenario::run_cluster` folds these into
/// the report's [`crate::sim::des::SimStats`] so scenario replays guard
/// them; tests use the totals directly to assert the grace extension
/// actually engaged. The latter two are zero unless a node ran with a
/// nonzero [`crate::validation::quorum::QuorumConfig::timeout_grace`].
pub fn quorum_totals(cluster: &Cluster<Node>) -> (u64, u64, u64) {
    let mut totals = (0u64, 0u64, 0u64);
    for i in 0..cluster.len() {
        let m = &cluster.node(i).metrics;
        totals.0 += m.counter("votes_forced");
        totals.1 += m.counter("votes_extended");
        totals.2 += m.counter("votes_rescued_by_grace");
    }
    totals
}

/// Cluster-wide totals of the gossip-mesh pubsub telemetry, summed over
/// every node's engine: `(ihave_sent, iwant_served, grafts, prunes)`.
/// Like [`quorum_totals`], `sim::scenario::run_cluster` folds these
/// into the report's [`crate::sim::des::SimStats`] so scenario replays
/// guard them; tests use the totals directly to assert the mesh
/// actually formed and advertised. All four are zero unless a node ran
/// with [`crate::peersdb::NodeConfig::mesh`] set.
pub fn pubsub_mesh_totals(cluster: &Cluster<Node>) -> (u64, u64, u64, u64) {
    let mut totals = (0u64, 0u64, 0u64, 0u64);
    for i in 0..cluster.len() {
        let (ihave, iwant, grafts, prunes) = cluster.node(i).pubsub_mesh_stats();
        totals.0 += ihave;
        totals.1 += iwant;
        totals.2 += grafts;
        totals.3 += prunes;
    }
    totals
}

/// Cluster-wide pubsub dissemination totals, summed over every node's
/// engine: `(published, forwarded, delivered, duplicates)`. `forwarded`
/// counts `Publish` frames actually pushed onto links; `delivered`
/// counts first-copy local deliveries — `duplicates / delivered` is the
/// redundancy factor `benches/sim_scale.rs` tracks per record.
pub fn pubsub_totals(cluster: &Cluster<Node>) -> (u64, u64, u64, u64) {
    let mut totals = (0u64, 0u64, 0u64, 0u64);
    for i in 0..cluster.len() {
        let (p, f, d, dup) = cluster.node(i).pubsub_stats();
        totals.0 += p;
        totals.1 += f;
        totals.2 += d;
        totals.3 += dup;
    }
    totals
}

/// Ground-truth audit of network-adopted verdicts: counts, over every
/// honest node, verdicts adopted *from the network* that contradict what
/// the contribution schedule actually injected (`corrupt = true` ⇒ the
/// honest verdict is `Invalid`, else `Valid`). Locally computed verdicts
/// are exempt — a node is entitled to its own wrong opinion; the counter
/// exists to catch lies the *quorum plane* laundered into
/// [`crate::peersdb::ValidationSource::Network`] adoptions. Byzantine
/// nodes are excluded: their stores lie by construction.
pub fn false_verdicts(
    cluster: &impl ClusterView,
    ground_truth: &[(crate::cid::Cid, bool)],
    byzantine: &[usize],
) -> u64 {
    use crate::stores::documents::Verdict;
    let mut n = 0u64;
    for (cid, corrupt) in ground_truth {
        let expected = if *corrupt { Verdict::Invalid } else { Verdict::Valid };
        for i in 0..cluster.len() {
            if byzantine.contains(&i) {
                continue;
            }
            let node = cluster.node(i);
            if !node.network_adopted(cid) {
                continue;
            }
            if let Some(r) = node.validations.get(cid) {
                if r.verdict != expected {
                    n += 1;
                }
            }
        }
    }
    n
}

/// Drain accumulated [`NodeEvent`]s from every node.
pub fn drain_events(cluster: &mut Cluster<Node>) -> Vec<(usize, NodeEvent)> {
    let mut all = Vec::new();
    for i in 0..cluster.len() {
        let evs = cluster.with_node(i, |n, _, _| std::mem::take(&mut n.events));
        for e in evs {
            all.push((i, e));
        }
    }
    all
}

/// Inject a contribution at node `idx`; returns the data root CID.
pub fn contribute(
    cluster: &mut Cluster<Node>,
    idx: usize,
    data: &[u8],
    workload: &str,
) -> crate::cid::Cid {
    let owned = data.to_vec();
    let wl = workload.to_string();
    cluster.with_node(idx, move |n: &mut Node, now, out: &mut Outbox<_>| {
        n.contribute(now, &owned, &wl, "gcp-e2-standard-2", out)
    })
}

/// Convenience: run until time `t`, then assert every node's store has
/// converged to the same digest. Returns the digest.
pub fn assert_converged(cluster: &mut Cluster<Node>) -> [u8; 32] {
    let d0 = cluster.node(0).contributions.digest();
    for i in 1..cluster.len() {
        if !cluster.is_online(i) {
            continue;
        }
        let di = cluster.node(i).contributions.digest();
        assert_eq!(
            d0,
            di,
            "store divergence between node 0 and node {i} ({} vs {} entries)",
            cluster.node(0).contributions.len(),
            cluster.node(i).contributions.len()
        );
    }
    d0
}

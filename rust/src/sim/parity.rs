//! Sim-to-real parity: run the bank's declarative fault schedules over
//! real TCP sockets and differentially compare convergence outcomes
//! against the DES.
//!
//! The DES proves invariants in virtual time; nothing there stops the
//! simulator's network model from quietly diverging from what the
//! sans-io cores do over real sockets. This module is the differential
//! check: [`run_sim`] executes a parity-tagged [`Scenario`] in the DES,
//! [`run_real`] executes the *same* schedule against a multi-threaded
//! loopback cluster of [`TcpNode<Node>`] peers, and [`differential`]
//! asserts the two timing-free [`ConvergenceReport`]s agree.
//!
//! The lowering ([`lower`]) maps each [`Fault`] onto a [`RealAction`]
//! the TCP driver can actually perform: partitions become per-direction
//! frame-drop rules on a shared [`LinkPolicy`], `SlowLink`s become
//! per-frame pacing delays, crashes/restarts become real thread
//! stop/spawn (the runner survives, mirroring the DES's
//! `set_offline`/`set_online`), flash crowds become fresh `TcpNode`
//! spawns bootstrapping through the root. Sim-only faults — forged DHT
//! replies, probabilistic loss, CPU strain — fail the lowering with an
//! explicit [`Unsupported`] error; a schedule either runs whole over
//! real sockets or not at all, never with faults silently skipped.
//!
//! **Outcomes, not timings.** Wall-clock runs are nondeterministic in
//! every timing-dependent respect, so the report only contains facts
//! both worlds must agree on once converged: which peers are
//! bootstrapped, per-peer log length, which peers fully hold which data
//! files, per-peer verdicts against the schedule's ground truth,
//! whether all logs share one digest/head-set *within the run* (log
//! digests embed `created_at` timestamps and are therefore never
//! compared *across* runs), and live-holder counts per contribution.
//! The data CIDs themselves *are* compared across runs: both drivers
//! mirror `scenario::run_cluster`'s RNG discipline (identity stream
//! from `Rng::new(seed)`, schedule stream from
//! `Rng::new(seed ^ 0x5CE2A210_FA17_1A7E)` consumed in stable schedule
//! order), so contribution bytes — and hence their content addresses —
//! must be byte-identical. Both runs converge toward the same
//! schedule-derived expected report, and at the end the real cluster's
//! reclaimed runners are wrapped in a [`Quiesced`] view and pushed
//! through the *same* [`scenario::check_invariants`] the DES asserts.

use crate::cid::Cid;
use crate::modeling::datagen::{self, WORKLOADS};
use crate::net::tcp::to_wall;
use crate::net::{Directory, LinkPolicy, PeerId, TcpNode};
use crate::peersdb::Node;
use crate::sim::harness::ClusterView;
use crate::sim::regions::{Region, ALL};
use crate::sim::scenario::{self, Fault, Phase, Scenario};
use crate::stores::documents::Verdict;
use crate::util::Rng;
use crate::validation::{ByzantineValidator, StatsValidator, Validator};
use std::collections::{BTreeSet, HashMap};
use std::time::{Duration, Instant};

/// Wall-clock pacing per unit of `SlowLink` latency factor above 1.0.
const PACE_MS_PER_FACTOR: u64 = 25;
/// Per-frame pacing ceiling: keeps a reader thread's sleep bounded so
/// shutdown joins promptly and one paced link cannot stall a run.
const MAX_PACE_MS: u64 = 250;
/// Hard wall-clock budget for the real run's quiesce poll.
const REAL_QUIESCE_CAP: Duration = Duration::from_secs(45);
/// Poll interval while the real cluster converges toward the expected
/// report.
const REAL_POLL: Duration = Duration::from_millis(250);
/// Extra virtual seconds granted to the DES run past its
/// invariant-passing quiesce to reach the outcome fixed point (verdict
/// tails, last repair fetches): `quiesce_poll` stops at the first
/// invariant pass, which can be earlier than full convergence.
const SIM_EXTEND_SECS: u64 = 120;

// ---------------------------------------------------------------------------
// Fault lowering
// ---------------------------------------------------------------------------

/// A [`Fault`] lowered to something the TCP driver can actually do.
/// Node indices refer to spec order, exactly as in the DES.
#[derive(Clone, Debug, PartialEq)]
pub enum RealAction {
    /// Block the listed directed index pairs at the frame level.
    Block(Vec<(usize, usize)>),
    /// Unblock the listed directed index pairs (pacing persists).
    Unblock(Vec<(usize, usize)>),
    /// Heal every blocked link, keeping pacing (mirrors `Fault::Heal`,
    /// which unblocks links but leaves latency multipliers in place).
    HealAll,
    /// Pace both directions of the `a ↔ b` link by a per-frame delay.
    Pace { a: usize, b: usize, delay: Duration },
    /// Stop a node's threads and park its runner; state survives.
    Crash(usize),
    /// Restart a parked runner on fresh threads (`on_start` re-runs,
    /// like the DES's epoch-bumping `set_online`).
    Restart(usize),
    /// Crash every node in the region.
    Outage(Region),
    /// Restart every parked node in the region.
    Recover(Region),
    /// Spawn `n` fresh peers bootstrapping through the root.
    Join { n: usize, region: Region },
    /// Swap the node's validator for a lying one.
    TurnByzantine(usize),
    /// Inject a contribution (corrupted when `frac` is set).
    Contribute { node: usize, workload: u32, rows: usize, frac: Option<f64> },
    /// Deliberate unpin + garbage collection on one node.
    UnpinAndGc(usize),
    /// Toggle the availability-repair loop on every current member.
    SetRepair(bool),
    /// Mid-run safety checkpoint (routing health + quorum safety).
    Checkpoint,
}

/// A sim-only fault that cannot be lowered to real TCP. Lowering
/// *fails* on these — it never skips them — so a schedule either runs
/// whole over real sockets or not at all.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Unsupported {
    /// Debug rendering of the offending fault.
    pub fault: String,
    /// Why the fault has no real-socket counterpart.
    pub why: &'static str,
}

impl std::fmt::Display for Unsupported {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fault {} has no real-TCP lowering: {}", self.fault, self.why)
    }
}

impl std::error::Error for Unsupported {}

/// Pacing delay for a `SlowLink` latency multiplier: proportional to
/// the excess over nominal, capped at [`MAX_PACE_MS`].
pub fn pace_delay(factor: f64) -> Duration {
    let excess = (factor - 1.0).max(0.0);
    Duration::from_millis(((excess * PACE_MS_PER_FACTOR as f64) as u64).min(MAX_PACE_MS))
}

/// Lower one fault to a [`RealAction`], or explain why it cannot run
/// over real sockets.
pub fn lower(fault: &Fault) -> Result<RealAction, Unsupported> {
    let unsupported = |why: &'static str| Unsupported { fault: format!("{fault:?}"), why };
    Ok(match fault {
        Fault::Partition { a, b } => {
            let mut links = Vec::new();
            for &x in a {
                for &y in b {
                    if x != y {
                        links.push((x, y));
                        links.push((y, x));
                    }
                }
            }
            RealAction::Block(links)
        }
        Fault::Heal => RealAction::HealAll,
        Fault::BlockPair { a, b } => RealAction::Block(vec![(*a, *b), (*b, *a)]),
        Fault::UnblockPair { a, b } => RealAction::Unblock(vec![(*a, *b), (*b, *a)]),
        Fault::BlockDirected { from, to } => RealAction::Block(vec![(*from, *to)]),
        Fault::UnblockDirected { from, to } => RealAction::Unblock(vec![(*from, *to)]),
        Fault::AsymmetricPartition { a, b } => {
            // A sees B: only the b→a directions are blocked.
            let mut links = Vec::new();
            for &x in a {
                for &y in b {
                    if x != y {
                        links.push((y, x));
                    }
                }
            }
            RealAction::Block(links)
        }
        Fault::SlowLink { a, b, factor } => {
            RealAction::Pace { a: *a, b: *b, delay: pace_delay(*factor) }
        }
        Fault::Outage { region } => RealAction::Outage(*region),
        Fault::Recover { region } => RealAction::Recover(*region),
        Fault::Crash { node } => RealAction::Crash(*node),
        Fault::Restart { node } => RealAction::Restart(*node),
        Fault::FlashCrowd { n, region } => RealAction::Join { n: *n, region: *region },
        Fault::TurnByzantine { node } => RealAction::TurnByzantine(*node),
        Fault::Contribute { node, workload, rows } => RealAction::Contribute {
            node: *node,
            workload: *workload,
            rows: *rows,
            frac: None,
        },
        Fault::ContributeCorrupt { node, workload, rows, frac } => RealAction::Contribute {
            node: *node,
            workload: *workload,
            rows: *rows,
            frac: Some(*frac),
        },
        Fault::UnpinAndGc { node } => RealAction::UnpinAndGc(*node),
        Fault::SetRepair { on } => RealAction::SetRepair(*on),
        Fault::Checkpoint => RealAction::Checkpoint,
        Fault::SetLoss { .. } | Fault::SetLinkLoss { .. } => {
            return Err(unsupported(
                "probabilistic loss is sampled from the DES's seeded RNG; real sockets \
                 deliver reliably and any injected sampling would make the outcome a \
                 different random variable than the simulated one",
            ))
        }
        Fault::CpuStrain { .. } | Fault::CpuRelief { .. } => {
            return Err(unsupported(
                "CPU strain is a property of the DES machine model; the loopback \
                 cluster's threads share one real CPU with no per-machine throttle",
            ))
        }
        Fault::ForgeDhtReplies { .. } | Fault::StopForging { .. } => {
            return Err(unsupported(
                "eclipse outcomes hinge on DES-deterministic eviction and lookup \
                 interleavings; over real sockets the attack window depends on the \
                 thread scheduler, so the differential report would compare noise",
            ))
        }
    })
}

/// Lower a scenario's full schedule in stable `(at, declaration)` order
/// — the order the DES executes it in.
pub fn lower_schedule(
    sc: &Scenario,
) -> Result<Vec<(crate::util::time::Duration, RealAction)>, Unsupported> {
    let mut order: Vec<usize> = (0..sc.events.len()).collect();
    order.sort_by_key(|&i| (sc.events[i].at, i));
    order
        .into_iter()
        .map(|i| Ok((sc.events[i].at, lower(&sc.events[i].fault)?)))
        .collect()
}

// ---------------------------------------------------------------------------
// Schedule analysis: the outcome fixed point a parity scenario must
// converge to, derived from the schedule alone.
// ---------------------------------------------------------------------------

/// Outcome-relevant facts read off a schedule.
struct ScheduleInfo {
    /// Peers whose validation stores lie by construction (initial
    /// byzantine set, invariant-config set, plus `TurnByzantine`
    /// targets) — their verdicts are masked out of reports.
    byzantine: BTreeSet<usize>,
    /// Peers that deliberately unpinned + GC'd; they hold nothing at
    /// quiesce (repair refuses to resurrect deliberate drops).
    droppers: BTreeSet<usize>,
    /// Author index per contribution, in schedule order. Authors never
    /// validate their own files (contributing pins locally; no data
    /// fetch ever completes), so their expected verdict is `None`.
    authors: Vec<usize>,
    /// Final peer count (initial + flash-crowd joiners).
    final_peers: usize,
}

impl ScheduleInfo {
    fn of(sc: &Scenario) -> ScheduleInfo {
        let mut byzantine: BTreeSet<usize> = sc.byzantine.iter().copied().collect();
        byzantine.extend(sc.invariants.byzantine.iter().copied());
        let mut droppers = BTreeSet::new();
        let mut authors = Vec::new();
        let mut final_peers = sc.peers;
        let mut order: Vec<usize> = (0..sc.events.len()).collect();
        order.sort_by_key(|&i| (sc.events[i].at, i));
        for i in order {
            match &sc.events[i].fault {
                Fault::TurnByzantine { node } => {
                    byzantine.insert(*node);
                }
                Fault::UnpinAndGc { node } => {
                    droppers.insert(*node);
                }
                Fault::Contribute { node, .. } | Fault::ContributeCorrupt { node, .. } => {
                    authors.push(*node);
                }
                Fault::FlashCrowd { n, .. } => final_peers += n,
                _ => {}
            }
        }
        ScheduleInfo { byzantine, droppers, authors, final_peers }
    }
}

/// Whether (and why not) a scenario is parity-eligible: its schedule
/// must lower cleanly, stay small enough for a real-clock run, and —
/// the subtle part — have a *timing-free* convergence fixed point, so
/// the sim and real runs can be expected to agree outcome-for-outcome.
/// The bank's shape-guard tests call this for every tagged scenario.
pub fn parity_eligible(sc: &Scenario) -> Result<(), String> {
    lower_schedule(sc).map_err(|e| e.to_string())?;
    let info = ScheduleInfo::of(sc);
    if info.final_peers > 10 {
        return Err(format!(
            "{} final peers; the real-clock runner is sized for ≤ 10",
            info.final_peers
        ));
    }
    if !sc.cfg.auto_pin && sc.cfg.replication_target < info.final_peers {
        return Err(
            "without auto_pin, NodeConfig::replication_target must reach the whole \
             cluster: a partial target makes *which* peers end up holding a repaired \
             file a timing race, so per-peer holds would not be comparable"
                .into(),
        );
    }
    if sc.cfg.auto_validate && !sc.stats_validators {
        return Err(
            "auto_validate without stats validators leaves verdicts to the default \
             identity validator, which cannot distinguish corrupt data — the expected \
             verdict column would be meaningless"
                .into(),
        );
    }
    // Drop determinism: repair's no-resurrect rule keys off which files
    // the dropper held at drop time, and whether a *non-author* held a
    // file right then is a race. Requiring droppers to author every
    // earlier contribution — and forbidding contributions after a drop
    // — pins the fixed point to "droppers hold nothing".
    let mut order: Vec<usize> = (0..sc.events.len()).collect();
    order.sort_by_key(|&i| (sc.events[i].at, i));
    let mut dropped = false;
    let mut authors_so_far: Vec<usize> = Vec::new();
    for i in order {
        match &sc.events[i].fault {
            Fault::Contribute { node, .. } | Fault::ContributeCorrupt { node, .. } => {
                if dropped {
                    return Err(
                        "a contribution after an UnpinAndGc would be repair-fetched by \
                         the dropper too (it is not in its dropped set), contradicting \
                         the droppers-hold-nothing fixed point"
                            .into(),
                    );
                }
                authors_so_far.push(*node);
            }
            Fault::UnpinAndGc { node } => {
                if authors_so_far.iter().any(|a| a != node) {
                    return Err(
                        "an UnpinAndGc node must have authored every earlier \
                         contribution: whether it held someone else's file at drop \
                         time is a timing race"
                            .into(),
                    );
                }
                dropped = true;
            }
            _ => {}
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The timing-free convergence report
// ---------------------------------------------------------------------------

/// One peer's timing-free outcome at quiesce.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PeerOutcome {
    pub bootstrapped: bool,
    /// Contribution-log length.
    pub log_len: usize,
    /// Per ground-truth contribution (schedule order): does this peer
    /// fully hold the data file?
    pub holds: Vec<bool>,
    /// Per ground-truth contribution: this peer's verdict. Byzantine
    /// peers are masked to `None` — their stores lie by construction,
    /// in ways the wall clock is allowed to influence.
    pub verdicts: Vec<Option<Verdict>>,
}

/// The timing-free convergence outcome of one scenario run, sim or
/// real. Two converged runs of the same schedule must compare equal —
/// that equality *is* the parity claim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvergenceReport {
    pub scenario: String,
    /// Data CIDs in schedule order. Content-addressed from RNG-mirrored
    /// bytes, so equal across sim and real — unlike log-entry CIDs and
    /// digests, which embed `created_at` timestamps and are only
    /// compared *within* a run (`logs_converged`).
    pub data_cids: Vec<Cid>,
    /// Ground truth per contribution: was it deliberately corrupted?
    pub corrupt: Vec<bool>,
    /// Every online peer shares one log digest and head set.
    pub logs_converged: bool,
    /// Live full holders per contribution — availability in outcome
    /// terms (DHT provider *records* are timing-dependent; who actually
    /// holds the bytes is not).
    pub provider_counts: Vec<usize>,
    pub peers: Vec<PeerOutcome>,
}

impl ConvergenceReport {
    /// Hand-rolled JSON rendering for the CI failure artifact.
    pub fn to_json(&self) -> String {
        let join = |parts: Vec<String>| parts.join(",");
        let peers = self
            .peers
            .iter()
            .map(|p| {
                format!(
                    "{{\"bootstrapped\":{},\"log_len\":{},\"holds\":[{}],\"verdicts\":[{}]}}",
                    p.bootstrapped,
                    p.log_len,
                    join(p.holds.iter().map(|b| b.to_string()).collect()),
                    join(
                        p.verdicts
                            .iter()
                            .map(|v| match v {
                                None => "null".to_string(),
                                Some(v) => format!("\"{v:?}\""),
                            })
                            .collect()
                    ),
                )
            })
            .collect();
        format!(
            "{{\"scenario\":\"{}\",\"data_cids\":[{}],\"corrupt\":[{}],\
             \"logs_converged\":{},\"provider_counts\":[{}],\"peers\":[{}]}}",
            self.scenario,
            join(self.data_cids.iter().map(|c| format!("\"{c}\"")).collect()),
            join(self.corrupt.iter().map(|b| b.to_string()).collect()),
            self.logs_converged,
            join(self.provider_counts.iter().map(|n| n.to_string()).collect()),
            join(peers),
        )
    }
}

/// One peer's probe: outcome plus the within-run convergence
/// fingerprints (never compared across runs).
struct PeerProbe {
    outcome: PeerOutcome,
    digest: [u8; 32],
    heads: Vec<Cid>,
    online: bool,
}

fn probe_node(n: &Node, ground_truth: &[(Cid, bool)], masked: bool) -> PeerOutcome {
    PeerOutcome {
        bootstrapped: n.is_bootstrapped(),
        log_len: n.contributions.len(),
        holds: ground_truth.iter().map(|(c, _)| n.holds_data(c)).collect(),
        verdicts: ground_truth
            .iter()
            .map(|(c, _)| if masked { None } else { n.validations.verdict(c) })
            .collect(),
    }
}

fn assemble(name: &str, probes: Vec<PeerProbe>, ground_truth: &[(Cid, bool)]) -> ConvergenceReport {
    let online: Vec<usize> =
        probes.iter().enumerate().filter(|(_, p)| p.online).map(|(i, _)| i).collect();
    let logs_converged = online.windows(2).all(|w| {
        probes[w[0]].digest == probes[w[1]].digest && probes[w[0]].heads == probes[w[1]].heads
    });
    let provider_counts = (0..ground_truth.len())
        .map(|k| online.iter().filter(|&&i| probes[i].outcome.holds[k]).count())
        .collect();
    ConvergenceReport {
        scenario: name.to_string(),
        data_cids: ground_truth.iter().map(|(c, _)| *c).collect(),
        corrupt: ground_truth.iter().map(|(_, x)| *x).collect(),
        logs_converged,
        provider_counts,
        peers: probes.into_iter().map(|p| p.outcome).collect(),
    }
}

/// Extract a report from any [`ClusterView`] (the quiesced DES cluster,
/// or the real cluster's reclaimed runners).
pub fn report_from_view(
    name: &str,
    view: &impl ClusterView,
    ground_truth: &[(Cid, bool)],
    byzantine: &BTreeSet<usize>,
) -> ConvergenceReport {
    let probes = (0..view.len())
        .map(|i| {
            let n = view.node(i);
            PeerProbe {
                outcome: probe_node(n, ground_truth, byzantine.contains(&i)),
                digest: n.log_digest(),
                heads: n.log_heads(),
                online: view.is_online(i),
            }
        })
        .collect();
    assemble(name, probes, ground_truth)
}

/// The schedule-derived fixed point both runs poll toward: everyone
/// bootstrapped and log-converged; everyone except deliberate droppers
/// holds every file; verdicts are ground truth for honest validating
/// non-authors and `None` for authors, byzantine peers, and
/// non-validating configurations.
fn expected_report(
    sc: &Scenario,
    info: &ScheduleInfo,
    ground_truth: &[(Cid, bool)],
) -> ConvergenceReport {
    let validating = sc.stats_validators && sc.cfg.auto_validate;
    let peers = (0..info.final_peers)
        .map(|i| PeerOutcome {
            bootstrapped: true,
            log_len: ground_truth.len(),
            holds: ground_truth.iter().map(|_| !info.droppers.contains(&i)).collect(),
            verdicts: ground_truth
                .iter()
                .enumerate()
                .map(|(k, (_, corrupt))| {
                    if !validating || info.byzantine.contains(&i) || info.authors[k] == i {
                        None
                    } else if *corrupt {
                        Some(Verdict::Invalid)
                    } else {
                        Some(Verdict::Valid)
                    }
                })
                .collect(),
        })
        .collect();
    let holders = info.final_peers - info.droppers.len();
    ConvergenceReport {
        scenario: sc.name.to_string(),
        data_cids: ground_truth.iter().map(|(c, _)| *c).collect(),
        corrupt: ground_truth.iter().map(|(_, x)| *x).collect(),
        logs_converged: true,
        provider_counts: vec![holders; ground_truth.len()],
        peers,
    }
}

// ---------------------------------------------------------------------------
// The DES side
// ---------------------------------------------------------------------------

/// Run the scenario in the DES and extract its convergence report,
/// extending virtual time (up to [`SIM_EXTEND_SECS`]) until the report
/// reaches the schedule-derived fixed point — `quiesce_poll` stops at
/// the first invariant pass, which can precede the last verdict.
pub fn run_sim(sc: &Scenario) -> Result<ConvergenceReport, String> {
    let info = ScheduleInfo::of(sc);
    let (report, mut cluster) = scenario::run_cluster(sc)?;
    let expected = expected_report(sc, &info, &report.cids);
    let deadline = cluster.now() + crate::util::time::Duration::from_secs(SIM_EXTEND_SECS);
    let mut got = report_from_view(sc.name, &cluster, &report.cids, &info.byzantine);
    while got != expected && cluster.now() < deadline {
        cluster.run_for(crate::util::time::Duration::from_secs(2));
        got = report_from_view(sc.name, &cluster, &report.cids, &info.byzantine);
    }
    Ok(got)
}

// ---------------------------------------------------------------------------
// The real-TCP side
// ---------------------------------------------------------------------------

/// One loopback peer: a live [`TcpNode`] or a parked (crashed) runner.
struct RealPeer {
    id: PeerId,
    region: Region,
    node: Option<TcpNode<Node>>,
    parked: Option<Node>,
}

impl RealPeer {
    fn live(&self, i: usize) -> Result<&TcpNode<Node>, String> {
        self.node
            .as_ref()
            .ok_or_else(|| format!("peer {i} is crashed but the schedule targets it"))
    }
}

/// The real cluster after every node has been stopped and its runner
/// reclaimed. Implements [`ClusterView`], so the *same*
/// [`scenario::check_invariants`] the DES asserts runs against the real
/// outcome too.
pub struct Quiesced {
    nodes: Vec<Node>,
    ids: Vec<PeerId>,
    index: HashMap<PeerId, usize>,
}

impl ClusterView for Quiesced {
    fn len(&self) -> usize {
        self.nodes.len()
    }
    fn is_online(&self, _idx: usize) -> bool {
        true // teardown restarted every crashed peer before the freeze
    }
    fn node(&self, idx: usize) -> &Node {
        &self.nodes[idx]
    }
    fn peer_id(&self, idx: usize) -> PeerId {
        self.ids[idx]
    }
    fn index_of(&self, id: PeerId) -> Option<usize> {
        self.index.get(&id).copied()
    }
}

fn crash(peers: &mut [RealPeer], i: usize) -> Result<(), String> {
    if let Some(tcp) = peers[i].node.take() {
        match tcp.shutdown() {
            Some(runner) => peers[i].parked = Some(runner),
            None => return Err(format!("peer {i}: event loop lost its runner")),
        }
    }
    Ok(()) // crashing an already-crashed node is a no-op, as in the DES
}

fn restart(
    peers: &mut [RealPeer],
    i: usize,
    dir: &Directory,
    policy: &LinkPolicy,
) -> Result<(), String> {
    if let Some(runner) = peers[i].parked.take() {
        let tcp = TcpNode::start_with_policy(runner, dir.clone(), policy.clone())
            .map_err(|e| format!("restarting peer {i}: {e}"))?;
        peers[i].node = Some(tcp);
    }
    Ok(()) // restarting an online node is a no-op, as in the DES
}

fn probe_live(
    name: &str,
    peers: &[RealPeer],
    ground_truth: &[(Cid, bool)],
    byzantine: &BTreeSet<usize>,
) -> Result<ConvergenceReport, String> {
    let mut probes = Vec::with_capacity(peers.len());
    for (i, p) in peers.iter().enumerate() {
        let tcp = p.live(i)?;
        let gt = ground_truth.to_vec();
        let masked = byzantine.contains(&i);
        let (outcome, digest, heads) = tcp
            .try_call_sync(move |n, _, _| {
                (probe_node(n, &gt, masked), n.log_digest(), n.log_heads())
            })
            .map_err(|_| format!("peer {i} died mid-quiesce"))?;
        probes.push(PeerProbe { outcome, digest, heads, online: true });
    }
    Ok(assemble(name, probes, ground_truth))
}

/// Mid-run safety checkpoint over the live cluster: per-node routing
/// health, routing tables referencing only real members, and no
/// conflicting honest verdicts — the same safety half
/// `check_invariants` asserts at a DES checkpoint.
fn check_real_checkpoint(
    peers: &[RealPeer],
    byzantine: &BTreeSet<usize>,
    ground_truth: &[(Cid, bool)],
) -> Result<(), String> {
    let members: BTreeSet<PeerId> = peers.iter().map(|p| p.id).collect();
    let mut verdicts: Vec<Vec<Option<Verdict>>> = Vec::new();
    for (i, p) in peers.iter().enumerate() {
        let Some(tcp) = &p.node else {
            verdicts.push(vec![None; ground_truth.len()]);
            continue; // crashed peers are skipped, as in the DES
        };
        let gt = ground_truth.to_vec();
        let (routing, table_peers, verd) = tcp
            .try_call_sync(move |n, _, _| {
                (
                    n.dht.table.check_invariants(),
                    n.dht.table.peers(),
                    gt.iter().map(|(c, _)| n.validations.verdict(c)).collect::<Vec<_>>(),
                )
            })
            .map_err(|_| format!("peer {i} died at checkpoint"))?;
        routing.map_err(|e| format!("node {i}: routing table: {e}"))?;
        for peer in table_peers {
            if !members.contains(&peer) {
                return Err(format!("node {i}: routing table references unknown peer {peer:?}"));
            }
        }
        verdicts.push(if byzantine.contains(&i) { vec![None; ground_truth.len()] } else { verd });
    }
    for (k, (cid, _)) in ground_truth.iter().enumerate() {
        let holds = |v: Verdict| verdicts.iter().position(|vs| vs[k] == Some(v));
        if let (Some(a), Some(b)) = (holds(Verdict::Valid), holds(Verdict::Invalid)) {
            return Err(format!(
                "quorum safety violated for {cid:?}: node {a} accepted Valid, \
                 node {b} accepted Invalid"
            ));
        }
    }
    Ok(())
}

fn sleep_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}

/// Run the scenario's lowered schedule against a real loopback cluster
/// and extract its convergence report.
///
/// The run mirrors `scenario::run_cluster` step for step: identities
/// and node seeds from `Rng::new(seed)` in spec order, schedule
/// randomness (joiner identities, contribution bytes) from
/// `Rng::new(seed ^ 0x5CE2A210_FA17_1A7E)` in stable schedule order,
/// regions rotated the same way, faults applied at the same offsets
/// (wall seconds standing in for virtual seconds), the same teardown
/// (heal + restart everything), a quiesce poll toward the expected
/// report, and finally the *same* `check_invariants` over the
/// [`Quiesced`] runners.
pub fn run_real(sc: &Scenario) -> Result<ConvergenceReport, String> {
    assert!(sc.peers >= 2, "scenario needs a root and at least one peer");
    let schedule = lower_schedule(sc).map_err(|e| e.to_string())?;
    let info = ScheduleInfo::of(sc);
    let dir = Directory::new();
    let policy = LinkPolicy::new();
    let mut id_rng = Rng::new(sc.seed);
    let mut schedule_rng = Rng::new(sc.seed ^ 0x5CE2A210_FA17_1A7E);

    // ---- Launch, with the DES's stagger --------------------------------
    let t0 = Instant::now();
    let mut peers: Vec<RealPeer> = Vec::new();
    let mut root_id: Option<PeerId> = None;
    for i in 0..sc.peers {
        let id = PeerId::from_rng(&mut id_rng);
        let node_seed = id_rng.next_u64();
        let mut cfg = sc.cfg.clone();
        cfg.bootstrap = if i == 0 {
            root_id = Some(id);
            None
        } else {
            root_id
        };
        let node = match scenario::validator_for(sc, i) {
            Some(v) => Node::with_validator(id, cfg, node_seed, v),
            None => Node::new(id, cfg, node_seed),
        };
        let region = if i == 0 { Region::AsiaEast2 } else { ALL[i % ALL.len()] };
        sleep_until(t0 + Duration::from_nanos(sc.stagger.0) * i as u32);
        let tcp = TcpNode::start_with_policy(node, dir.clone(), policy.clone())
            .map_err(|e| format!("spawning peer {i}: {e}"))?;
        peers.push(RealPeer { id, region, node: Some(tcp), parked: None });
    }
    let root_id = root_id.expect("peers >= 2");

    // ---- Schedule execution --------------------------------------------
    let events_t0 = t0 + to_wall(sc.warmup);
    let mut cids: Vec<(Cid, bool)> = Vec::new();
    for (at, action) in &schedule {
        sleep_until(events_t0 + to_wall(*at));
        match action {
            RealAction::Block(links) => {
                for &(x, y) in links {
                    policy.block(peers[x].id, peers[y].id);
                }
            }
            RealAction::Unblock(links) => {
                for &(x, y) in links {
                    policy.unblock(peers[x].id, peers[y].id);
                }
            }
            RealAction::HealAll => policy.unblock_all(),
            RealAction::Pace { a, b, delay } => {
                policy.set_delay(peers[*a].id, peers[*b].id, *delay);
                policy.set_delay(peers[*b].id, peers[*a].id, *delay);
            }
            RealAction::Crash(i) => crash(&mut peers, *i)?,
            RealAction::Restart(i) => restart(&mut peers, *i, &dir, &policy)?,
            RealAction::Outage(region) => {
                let members: Vec<usize> = (0..peers.len())
                    .filter(|&i| peers[i].region == *region)
                    .collect();
                for i in members {
                    crash(&mut peers, i)?;
                }
            }
            RealAction::Recover(region) => {
                let members: Vec<usize> = (0..peers.len())
                    .filter(|&i| peers[i].region == *region)
                    .collect();
                for i in members {
                    restart(&mut peers, i, &dir, &policy)?;
                }
            }
            RealAction::Join { n, region } => {
                for _ in 0..*n {
                    let id = PeerId::from_rng(&mut schedule_rng);
                    let node_seed = schedule_rng.next_u64();
                    let mut cfg = sc.cfg.clone();
                    cfg.bootstrap = Some(root_id);
                    let node = if sc.stats_validators {
                        let v: Box<dyn Validator> = Box::new(StatsValidator::default());
                        Node::with_validator(id, cfg, node_seed, v)
                    } else {
                        Node::new(id, cfg, node_seed)
                    };
                    let tcp = TcpNode::start_with_policy(node, dir.clone(), policy.clone())
                        .map_err(|e| format!("spawning joiner: {e}"))?;
                    peers.push(RealPeer { id, region: *region, node: Some(tcp), parked: None });
                }
            }
            RealAction::TurnByzantine(i) => {
                peers[*i]
                    .live(*i)?
                    .try_call_sync(|n, _, _| {
                        n.set_validator(Box::new(ByzantineValidator::default()))
                    })
                    .map_err(|e| format!("peer {i}: {e}"))?;
            }
            RealAction::Contribute { node, workload, rows, frac } => {
                let wl = (*workload as usize) % WORKLOADS.len();
                let (file, _) = match frac {
                    None => datagen::generate_contribution(&mut schedule_rng, wl as u32, *rows),
                    Some(f) => datagen::generate_corrupt_contribution(
                        &mut schedule_rng,
                        wl as u32,
                        *rows,
                        *f,
                    ),
                };
                let name = WORKLOADS[wl];
                let cid = peers[*node]
                    .live(*node)?
                    .try_call_sync(move |n, now, out| {
                        n.contribute(now, &file, name, "gcp-e2-standard-2", out)
                    })
                    .map_err(|e| format!("peer {node}: {e}"))?;
                cids.push((cid, frac.is_some()));
            }
            RealAction::UnpinAndGc(i) => {
                peers[*i]
                    .live(*i)?
                    .try_call_sync(|n, now, out| {
                        n.unpin_contribution_data(now, out);
                        n.collect_garbage();
                    })
                    .map_err(|e| format!("peer {i}: {e}"))?;
            }
            RealAction::SetRepair(on) => {
                let on = *on;
                for (i, p) in peers.iter().enumerate() {
                    if let Some(tcp) = &p.node {
                        tcp.try_call_sync(move |n, _, _| n.set_repair(on))
                            .map_err(|e| format!("peer {i}: {e}"))?;
                    }
                }
            }
            RealAction::Checkpoint => {
                check_real_checkpoint(&peers, &info.byzantine, &cids)
                    .map_err(|e| format!("real '{}' checkpoint: {e}", sc.name))?;
            }
        }
    }

    // ---- Teardown: the DES's global heal -------------------------------
    policy.clear();
    for i in 0..peers.len() {
        restart(&mut peers, i, &dir, &policy)?;
    }

    // ---- Quiesce: poll toward the expected fixed point -----------------
    let expected = expected_report(sc, &info, &cids);
    let deadline = Instant::now() + REAL_QUIESCE_CAP.min(to_wall(sc.quiesce));
    loop {
        let got = probe_live(sc.name, &peers, &cids, &info.byzantine)?;
        if got == expected || Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(REAL_POLL);
    }

    // ---- Freeze and run the DES's own invariant checker ----------------
    let mut nodes = Vec::with_capacity(peers.len());
    let mut ids = Vec::with_capacity(peers.len());
    for (i, p) in peers.into_iter().enumerate() {
        let runner = match (p.node, p.parked) {
            (Some(tcp), _) => tcp
                .shutdown()
                .ok_or_else(|| format!("peer {i}: event loop lost its runner"))?,
            (None, Some(parked)) => parked,
            (None, None) => return Err(format!("peer {i} has no runner to reclaim")),
        };
        ids.push(p.id);
        nodes.push(runner);
    }
    let index = ids.iter().enumerate().map(|(i, id)| (*id, i)).collect();
    let quiesced = Quiesced { nodes, ids, index };

    let mut inv = sc.invariants.clone();
    for b in &info.byzantine {
        if !inv.byzantine.contains(b) {
            inv.byzantine.push(*b);
        }
    }
    scenario::check_invariants(&quiesced, &inv, cids.len(), &cids, Phase::Quiesce)
        .map_err(|e| format!("real run of '{}' at quiesce: {e}", sc.name))?;

    Ok(report_from_view(sc.name, &quiesced, &cids, &info.byzantine))
}

// ---------------------------------------------------------------------------
// The differential check
// ---------------------------------------------------------------------------

fn first_divergence(sim: &ConvergenceReport, real: &ConvergenceReport) -> String {
    if sim.data_cids != real.data_cids {
        return "data CIDs differ — contribution bytes were not RNG-mirrored".into();
    }
    if sim.peers.len() != real.peers.len() {
        return format!("peer count: sim={} real={}", sim.peers.len(), real.peers.len());
    }
    if sim.logs_converged != real.logs_converged {
        return format!(
            "logs_converged: sim={} real={}",
            sim.logs_converged, real.logs_converged
        );
    }
    for (i, (s, r)) in sim.peers.iter().zip(&real.peers).enumerate() {
        if s != r {
            return format!("peer {i}: sim={s:?} real={r:?}");
        }
    }
    if sim.provider_counts != real.provider_counts {
        return format!(
            "provider counts: sim={:?} real={:?}",
            sim.provider_counts, real.provider_counts
        );
    }
    "reports differ".into()
}

/// Run `sc` in the DES and over real TCP; the two convergence reports
/// must agree. On mismatch both reports are written to
/// `PARITY_<scenario>_{sim,real}.json` (the CI failure artifact) and an
/// error naming the first divergence is returned.
pub fn differential(sc: &Scenario) -> Result<ConvergenceReport, String> {
    assert!(sc.parity, "scenario '{}' is not tagged parity-eligible", sc.name);
    let sim = run_sim(sc)?;
    let real = run_real(sc)?;
    if sim != real {
        let slug: String = sc
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        let _ = std::fs::write(format!("PARITY_{slug}_sim.json"), sim.to_json());
        let _ = std::fs::write(format!("PARITY_{slug}_real.json"), real.to_json());
        return Err(format!(
            "sim-vs-real divergence in '{}': {}",
            sc.name,
            first_divergence(&sim, &real)
        ));
    }
    Ok(sim)
}

// ---------------------------------------------------------------------------
// The loopback demo (shared by examples/tcp_cluster.rs and tests/tcp.rs)
// ---------------------------------------------------------------------------

/// The `tcp_cluster` end-to-end path: a root plus three joiners over
/// loopback TCP, a contribution POSTed through the HTTP API, replicated
/// to every peer through real sockets, status checked, all nodes torn
/// down. Errors instead of hanging: every wait has a deadline.
pub fn tcp_cluster_demo(verbose: bool) -> anyhow::Result<()> {
    use crate::api::http::{http_get, http_post, HttpServer};
    use crate::codec::json::Json;
    use crate::peersdb::NodeConfig;
    use std::sync::Arc;

    let say = |msg: String| {
        if verbose {
            println!("{msg}");
        }
    };
    let mut rng = Rng::new(3);
    let dir = Directory::new();

    let root_id = PeerId::from_rng(&mut rng);
    let root = Arc::new(TcpNode::start(
        Node::new(root_id, NodeConfig::default(), rng.next_u64()),
        dir.clone(),
    )?);
    say(format!("root {} on {}", root_id.short(), root.addr));

    let mut peers = Vec::new();
    for i in 0..3 {
        let id = PeerId::from_rng(&mut rng);
        let cfg = NodeConfig { bootstrap: Some(root_id), ..NodeConfig::default() };
        let node = Node::new(id, cfg, rng.next_u64());
        let tcp = Arc::new(TcpNode::start(node, dir.clone())?);
        say(format!("peer {i} {} on {}", id.short(), tcp.addr));
        peers.push(tcp);
    }

    // Wait for bootstrap over real sockets.
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let ready = peers.iter().filter(|p| p.call_sync(|n, _, _| n.is_bootstrapped())).count();
        if ready == peers.len() {
            break;
        }
        if Instant::now() > deadline {
            anyhow::bail!("bootstrap timed out ({ready}/3 ready)");
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    say("all peers bootstrapped over TCP".to_string());

    // HTTP API on peer 0 (the prototype's access path).
    let http = HttpServer::start(peers[0].clone())?;
    say(format!("http api on http://{}", http.addr));
    let (file, _) = datagen::generate_contribution(&mut rng, 2, 100);
    let (code, body) = http_post(
        http.addr,
        "/contributions?workload=spark-pagerank&platform=loopback",
        &file,
    )?;
    anyhow::ensure!(code == 200, "contribute failed: {code}");
    let cid = Json::parse(std::str::from_utf8(&body)?)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .path("cid")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("no cid in response"))?
        .to_string();
    say(format!("contributed via HTTP: cid {}", &cid[..16]));

    // The contribution replicates to every other peer through real
    // sockets (pubsub → log entry fetch → data fetch).
    let cid_parsed =
        crate::cid::Cid::parse(&cid).ok_or_else(|| anyhow::anyhow!("unparseable cid"))?;
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let have = peers
            .iter()
            .filter(|p| p.call_sync(move |n, _, _| n.get_file(&cid_parsed).is_some()))
            .count();
        let root_has = root.call_sync(move |n, _, _| n.get_file(&cid_parsed).is_some());
        if have == peers.len() && root_has {
            break;
        }
        if Instant::now() > deadline {
            anyhow::bail!("replication timed out ({have}/3 peers + root {root_has})");
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    say("replicated to root + all 3 peers over TCP".to_string());

    let (code, body) = http_get(http.addr, "/status")?;
    anyhow::ensure!(code == 200);
    say(format!("status: {}", String::from_utf8_lossy(&body)));

    http.stop();
    for p in &peers {
        p.shutdown();
    }
    root.shutdown();
    say("tcp_cluster OK".to_string());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::bank;

    #[test]
    fn sim_only_faults_are_rejected_not_skipped() {
        let rejected = [
            Fault::SetLoss { loss: 0.1 },
            Fault::SetLinkLoss { from: 0, to: 1, loss: 0.5 },
            Fault::CpuStrain { node: 0, factor: 4 },
            Fault::CpuRelief { node: 0 },
            Fault::ForgeDhtReplies { node: 1, colluders: vec![2] },
            Fault::StopForging { node: 1 },
        ];
        for fault in rejected {
            let err = lower(&fault).expect_err("sim-only fault must not lower");
            assert!(err.fault.contains(&format!("{fault:?}")[..8]), "{err}");
            assert!(!err.why.is_empty());
        }
        // And a schedule containing one fails as a whole — no silent
        // skipping of individual entries.
        let sc = Scenario::named("has-sim-only", 1, 3)
            .at(0, Fault::Contribute { node: 1, workload: 0, rows: 10 })
            .at(1, Fault::SetLoss { loss: 0.2 });
        assert!(lower_schedule(&sc).is_err());
    }

    #[test]
    fn supported_faults_lower_faithfully() {
        assert_eq!(
            lower(&Fault::Partition { a: vec![0, 1], b: vec![2] }).unwrap(),
            RealAction::Block(vec![(0, 2), (2, 0), (1, 2), (2, 1)]),
        );
        assert_eq!(
            lower(&Fault::AsymmetricPartition { a: vec![0], b: vec![1] }).unwrap(),
            RealAction::Block(vec![(1, 0)]), // A sees B: only b→a blocked
        );
        assert_eq!(lower(&Fault::Heal).unwrap(), RealAction::HealAll);
        assert_eq!(lower(&Fault::Crash { node: 3 }).unwrap(), RealAction::Crash(3));
        match lower(&Fault::SlowLink { a: 0, b: 5, factor: 4.0 }).unwrap() {
            RealAction::Pace { a: 0, b: 5, delay } => {
                assert_eq!(delay, Duration::from_millis(3 * PACE_MS_PER_FACTOR));
            }
            other => panic!("unexpected lowering: {other:?}"),
        }
        // Pacing is proportional but capped.
        assert_eq!(pace_delay(1.0), Duration::ZERO);
        assert_eq!(pace_delay(1000.0), Duration::from_millis(MAX_PACE_MS));
        assert_eq!(
            lower(&Fault::ContributeCorrupt { node: 2, workload: 1, rows: 60, frac: 0.9 })
                .unwrap(),
            RealAction::Contribute { node: 2, workload: 1, rows: 60, frac: Some(0.9) },
        );
    }

    #[test]
    fn lowered_schedules_keep_des_order() {
        let sc = Scenario::named("ordering", 1, 4)
            .at(5, Fault::Heal)
            .at(0, Fault::Crash { node: 1 })
            .at(0, Fault::Restart { node: 1 })
            .at(2, Fault::Checkpoint);
        let lowered = lower_schedule(&sc).unwrap();
        let actions: Vec<&RealAction> = lowered.iter().map(|(_, a)| a).collect();
        // Stable (at, declaration-order) sort, exactly like run_cluster.
        assert_eq!(
            actions,
            vec![
                &RealAction::Crash(1),
                &RealAction::Restart(1),
                &RealAction::Checkpoint,
                &RealAction::HealAll,
            ]
        );
    }

    #[test]
    fn eligibility_rejects_timing_dependent_fixed_points() {
        // Sim-only fault in the schedule.
        let sc = Scenario::named("x", 1, 3).at(0, Fault::SetLoss { loss: 0.1 });
        assert!(parity_eligible(&sc).unwrap_err().contains("no real-TCP lowering"));

        // Too large for a real-clock run.
        let sc = Scenario::named("x", 1, 11);
        assert!(parity_eligible(&sc).unwrap_err().contains("≤ 10"));

        // Partial replication target without auto_pin: holder set races.
        let mut sc = Scenario::named("x", 1, 5);
        sc.cfg.auto_pin = false;
        sc.cfg.replication_target = 3;
        assert!(parity_eligible(&sc).unwrap_err().contains("replication_target"));

        // auto_validate without stats validators.
        let mut sc = Scenario::named("x", 1, 4);
        sc.cfg.auto_validate = true;
        assert!(parity_eligible(&sc).unwrap_err().contains("stats validators"));

        // A dropper that did not author an earlier contribution.
        let sc = Scenario::named("x", 1, 5)
            .at(0, Fault::Contribute { node: 2, workload: 0, rows: 10 })
            .at(5, Fault::UnpinAndGc { node: 1 });
        assert!(parity_eligible(&sc).unwrap_err().contains("authored"));

        // A contribution after a drop resurrects data on the dropper.
        let sc = Scenario::named("x", 1, 5)
            .at(0, Fault::Contribute { node: 1, workload: 0, rows: 10 })
            .at(5, Fault::UnpinAndGc { node: 1 })
            .at(6, Fault::Contribute { node: 2, workload: 1, rows: 10 });
        assert!(parity_eligible(&sc).unwrap_err().contains("after an UnpinAndGc"));
    }

    #[test]
    fn expected_report_masks_authors_and_byzantine() {
        let mut sc = Scenario::named("mask", 7, 4);
        sc.stats_validators = true;
        sc.cfg.auto_validate = true;
        sc.byzantine = vec![3];
        let sc = sc
            .at(0, Fault::Contribute { node: 1, workload: 0, rows: 10 })
            .at(1, Fault::ContributeCorrupt { node: 2, workload: 1, rows: 10, frac: 0.9 });
        let info = ScheduleInfo::of(&sc);
        let gt = vec![(Cid::of_raw(b"a"), false), (Cid::of_raw(b"b"), true)];
        let exp = expected_report(&sc, &info, &gt);
        assert_eq!(exp.peers.len(), 4);
        // Node 0: honest non-author — ground truth on both files.
        assert_eq!(exp.peers[0].verdicts, vec![Some(Verdict::Valid), Some(Verdict::Invalid)]);
        // Node 1 authored file 0 (no self-validation), judges file 1.
        assert_eq!(exp.peers[1].verdicts, vec![None, Some(Verdict::Invalid)]);
        // Node 2 judges file 0, authored file 1.
        assert_eq!(exp.peers[2].verdicts, vec![Some(Verdict::Valid), None]);
        // Node 3 is byzantine: fully masked.
        assert_eq!(exp.peers[3].verdicts, vec![None, None]);
        // Everyone holds everything (auto_pin default), logs converge.
        assert!(exp.peers.iter().all(|p| p.holds == vec![true, true] && p.log_len == 2));
        assert_eq!(exp.provider_counts, vec![4, 4]);
    }

    #[test]
    fn every_tagged_bank_scenario_is_parity_eligible() {
        let mut tagged = 0;
        for sc in bank::all() {
            if sc.parity {
                parity_eligible(&sc).unwrap_or_else(|e| {
                    panic!("bank scenario '{}' is tagged parity but ineligible: {e}", sc.name)
                });
                tagged += 1;
            }
        }
        assert!(tagged >= 3, "the bank must carry ≥ 3 parity scenarios, found {tagged}");
    }

    #[test]
    fn attack_bank_rows_are_rejected_by_lowering() {
        // The eclipse-attack scenarios depend on forged DHT replies — a
        // sim-only fault. Their ineligibility must come from an explicit
        // lowering error, not from a missing tag.
        let mut saw_unsupported = false;
        for sc in bank::all() {
            if !sc.parity && lower_schedule(&sc).is_err() {
                saw_unsupported = true;
            }
        }
        assert!(saw_unsupported, "expected at least one bank row with sim-only faults");
    }

    #[test]
    fn convergence_report_json_is_wellformed_enough_for_artifacts() {
        let report = ConvergenceReport {
            scenario: "x".into(),
            data_cids: vec![Cid::of_raw(b"a")],
            corrupt: vec![true],
            logs_converged: true,
            provider_counts: vec![3],
            peers: vec![PeerOutcome {
                bootstrapped: true,
                log_len: 1,
                holds: vec![true],
                verdicts: vec![Some(Verdict::Invalid)],
            }],
        };
        let json = report.to_json();
        assert!(json.contains("\"scenario\":\"x\""));
        assert!(json.contains("\"verdicts\":[\"Invalid\"]"));
        assert!(json.contains("\"provider_counts\":[3]"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }
}

//! Discrete-event simulation driver and network models.
//!
//! This is the evaluation substrate standing in for both the paper's
//! 6-region GKE deployment (experiment 1 & 2) and its Testground
//! simulations (`transfer`, `fuzz`, validation-strategy study). The same
//! [`crate::net::Runner`] cores that run over TCP are driven here in
//! virtual time, with:
//!
//! * a region-to-region latency matrix calibrated to public GCP
//!   inter-region RTTs ([`regions`]),
//! * per-node egress bandwidth serialization and a per-node CPU model
//!   (which reproduces the paper's root-peer CPU-strain artifact),
//! * optional jitter and packet loss,
//! * a **directed link-state plane** ([`des::LinkState`]): per-(src→dst)
//!   blocked flags, loss overrides, and latency multipliers, which is
//!   what lets faults be *asymmetric* (a region that can reach the root
//!   but not be reached; a victim whose requests arrive while every
//!   reply dies), and
//! * deterministic execution from a single seed, and
//! * a **timer-wheel event queue** ([`wheel`]): O(1) amortized
//!   push/pop with batched same-timestamp dispatch and in-place
//!   tombstone compaction, proven pop-order-identical to the
//!   `BinaryHeap` it replaced — the DES-core work behind the 1,000+
//!   peer `bank::city_scale` churn scenario.
//!
//! On top of the raw driver sits the **scenario subsystem**
//! ([`scenario`]): declarative fault schedules — partition/heal
//! (symmetric and asymmetric), slow and lossy links, regional outage,
//! crash/restart churn, flash-crowd joins, root-peer CPU strain,
//! byzantine validator injection, forged DHT replies (eclipse attacks),
//! loss spikes, deliberate unpin + garbage collection (GC pressure) and
//! repair-loop toggling — executed against a [`Cluster`] of full
//! PeersDB nodes, with a cluster-wide invariant checker
//! (contribution-log convergence, quorum safety, DHT routing-table
//! health, block availability ≥ replication target, and opt-in eclipse
//! resistance and data survival) asserted at mid-run checkpoints and at
//! quiesce. The same seed always reproduces
//! the identical [`SimStats`], so every scenario doubles as a regression
//! reproduction recipe. The named bank lives in [`bank`] (shared by
//! `tests/scenarios.rs` and the self-timing `benches/sim_scale.rs`,
//! which emits `BENCH_sim.json`); `benches/sim_fuzz.rs` reuses the
//! invariants under randomized link flapping.
//!
//! The **parity subsystem** ([`parity`]) closes the sim-to-real loop:
//! bank scenarios tagged [`Scenario::parity`] are lowered onto the
//! threaded TCP driver ([`crate::net::tcp`]) — partitions and slow
//! links become [`crate::net::LinkPolicy`] frame rules, crashes become
//! real thread stop/spawn — and the DES and real runs must produce
//! equal timing-free [`parity::ConvergenceReport`]s. Sim-only faults
//! (forged DHT replies, probabilistic loss, CPU strain) fail lowering
//! with an explicit [`parity::Unsupported`] error rather than being
//! silently skipped.

pub mod bank;
pub mod des;
pub mod harness;
pub mod model;
pub mod parity;
pub mod regions;
pub mod scenario;
pub mod wheel;

pub use des::{Cluster, LinkState, SimStats};
pub use model::{LatencySpec, NetModel};
pub use parity::{ConvergenceReport, RealAction, Unsupported};
pub use regions::Region;
pub use scenario::{
    EclipseInvariant, Fault, InvariantConfig, Scenario, ScenarioReport, TimedFault,
};

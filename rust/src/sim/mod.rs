//! Discrete-event simulation driver and network models.
//!
//! This is the evaluation substrate standing in for both the paper's
//! 6-region GKE deployment (experiment 1 & 2) and its Testground
//! simulations (`transfer`, `fuzz`, validation-strategy study). The same
//! [`crate::net::Runner`] cores that run over TCP are driven here in
//! virtual time, with:
//!
//! * a region-to-region latency matrix calibrated to public GCP
//!   inter-region RTTs ([`regions`]),
//! * per-node egress bandwidth serialization and a per-node CPU model
//!   (which reproduces the paper's root-peer CPU-strain artifact),
//! * optional jitter, packet loss, link blocking (fuzz/churn), and
//! * deterministic execution from a single seed.

pub mod des;
pub mod harness;
pub mod model;
pub mod regions;

pub use des::{Cluster, SimStats};
pub use model::{LatencySpec, NetModel};
pub use regions::Region;

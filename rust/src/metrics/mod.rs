//! Per-node metrics: counters and sample summaries.
//!
//! Experiment harnesses read these after (or during) a run; nothing here
//! allocates on the hot path beyond the first observation of a name.

use crate::util::stats::Summary;
use std::collections::BTreeMap;

#[derive(Default)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    summaries: BTreeMap<&'static str, Summary>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&mut self, name: &'static str) {
        *self.counters.entry(name).or_insert(0) += 1;
    }

    #[inline]
    pub fn add(&mut self, name: &'static str, v: u64) {
        *self.counters.entry(name).or_insert(0) += v;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    #[inline]
    pub fn observe(&mut self, name: &'static str, v: f64) {
        self.summaries.entry(name).or_default().push(v);
    }

    pub fn summary(&self, name: &str) -> Option<&Summary> {
        self.summaries.get(name)
    }

    pub fn summary_mut(&mut self, name: &'static str) -> &mut Summary {
        self.summaries.entry(name).or_default()
    }

    /// Render all metrics as a sorted report (debugging / API endpoint).
    pub fn report(&mut self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k} = {v}\n"));
        }
        let keys: Vec<&'static str> = self.summaries.keys().copied().collect();
        for k in keys {
            let line = self.summaries.get_mut(k).unwrap().brief();
            s.push_str(&format!("{k}: {line}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_summaries() {
        let mut m = Metrics::new();
        m.inc("msgs");
        m.inc("msgs");
        m.add("bytes", 100);
        assert_eq!(m.counter("msgs"), 2);
        assert_eq!(m.counter("bytes"), 100);
        assert_eq!(m.counter("nope"), 0);
        m.observe("lat", 1.0);
        m.observe("lat", 3.0);
        assert_eq!(m.summary("lat").unwrap().mean(), 2.0);
        let rep = m.report();
        assert!(rep.contains("msgs = 2"));
        assert!(rep.contains("lat:"));
    }
}

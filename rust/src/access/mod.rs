//! Access control: passphrase-gated network join.
//!
//! §III-C: "A straightforward step is the implementation of access
//! control, i.e., the requirement of a passphrase for joining through the
//! IPFS bootstrapping node." Peers present `sha256(passphrase)` in their
//! `Join` message; bootstrap nodes verify it before admitting them (and
//! before revealing peers or store heads).
//!
//! The second access-control mechanism of the paper — the middleware that
//! "denies external CID requests for particular CIDs" — lives in
//! [`crate::blockstore::BlockStore::get_public`] and is exercised on every
//! remote `Want`.

use sha2::{Digest, Sha256};

/// Hash a passphrase for presentation/verification.
pub fn hash_passphrase(pass: &str) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(b"peersdb-join-v1:");
    h.update(pass.as_bytes());
    h.finalize().into()
}

/// Join gate held by every peer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Gate {
    expected: [u8; 32],
}

impl Gate {
    pub fn new(passphrase: &str) -> Gate {
        Gate { expected: hash_passphrase(passphrase) }
    }

    pub fn from_hash(expected: [u8; 32]) -> Gate {
        Gate { expected }
    }

    /// The hash this node presents when joining others.
    pub fn presentation(&self) -> [u8; 32] {
        self.expected
    }

    /// Verify a presented hash (constant-time comparison).
    pub fn check(&self, presented: &[u8; 32]) -> bool {
        let mut diff = 0u8;
        for i in 0..32 {
            diff |= self.expected[i] ^ presented[i];
        }
        diff == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correct_passphrase_admits() {
        let gate = Gate::new("fonda-c5");
        let joiner = Gate::new("fonda-c5");
        assert!(gate.check(&joiner.presentation()));
    }

    #[test]
    fn wrong_passphrase_rejected() {
        let gate = Gate::new("fonda-c5");
        let joiner = Gate::new("wrong");
        assert!(!gate.check(&joiner.presentation()));
    }

    #[test]
    fn hash_is_stable_and_domain_separated() {
        assert_eq!(hash_passphrase("x"), hash_passphrase("x"));
        assert_ne!(hash_passphrase("x"), hash_passphrase("y"));
        // Domain prefix: differs from a bare sha256.
        let bare: [u8; 32] = Sha256::digest(b"x").into();
        assert_ne!(hash_passphrase("x"), bare);
    }
}

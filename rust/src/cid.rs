//! Content identifiers: sha2-256 multihash-style CIDs.
//!
//! A [`Cid`] is the sha-256 digest of a block's bytes, tagged with a codec
//! byte distinguishing raw data blocks from encoded log entries (mirroring
//! IPFS's multicodec). Content addressing is what gives the distribution
//! layer its tamper-resistance: a peer can verify any fetched block by
//! re-hashing it (§III-C of the paper).

use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};
use crate::util::hex;
use sha2::{Digest, Sha256};

/// Payload codec tag carried inside a CID.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Codec {
    /// Opaque user bytes (contribution files, chunks).
    Raw = 0,
    /// Canonically-encoded [`crate::ipfs_log::Entry`].
    LogEntry = 1,
}

impl Codec {
    fn from_u8(v: u8) -> Result<Codec, DecodeError> {
        match v {
            0 => Ok(Codec::Raw),
            1 => Ok(Codec::LogEntry),
            _ => Err(DecodeError("invalid cid codec")),
        }
    }
}

/// A content identifier: `(codec, sha256(content))`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Cid {
    pub codec: Codec,
    pub hash: [u8; 32],
}

impl Cid {
    /// Hash `content` under the given codec.
    pub fn of(codec: Codec, content: &[u8]) -> Cid {
        let mut hasher = Sha256::new();
        hasher.update([codec as u8]);
        hasher.update(content);
        Cid {
            codec,
            hash: hasher.finalize().into(),
        }
    }

    pub fn of_raw(content: &[u8]) -> Cid {
        Cid::of(Codec::Raw, content)
    }

    /// Verify that `content` hashes to this CID.
    pub fn verifies(&self, content: &[u8]) -> bool {
        Cid::of(self.codec, content) == *self
    }

    /// The 256-bit hash as a DHT key (XOR metric operates on this).
    pub fn key(&self) -> [u8; 32] {
        self.hash
    }

    /// Short printable form (first 8 hash bytes), e.g. `raw:1a2b3c4d…`.
    pub fn short(&self) -> String {
        format!(
            "{}:{}",
            match self.codec {
                Codec::Raw => "raw",
                Codec::LogEntry => "log",
            },
            hex::encode(&self.hash[..8])
        )
    }

    /// Full printable form; parseable by [`Cid::parse`].
    pub fn to_string_full(&self) -> String {
        format!("{}{}", (self.codec as u8) + b'0' as u8 - 48, hex::encode(&self.hash))
    }

    /// Parse the full printable form: one codec digit + 64 hex chars.
    pub fn parse(s: &str) -> Option<Cid> {
        if s.len() != 65 {
            return None;
        }
        let codec = Codec::from_u8(s.as_bytes()[0].wrapping_sub(b'0')).ok()?;
        let bytes = hex::decode(&s[1..])?;
        Some(Cid {
            codec,
            hash: bytes.try_into().ok()?,
        })
    }
}

impl std::fmt::Debug for Cid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Cid({})", self.short())
    }
}

impl std::fmt::Display for Cid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_string_full())
    }
}

impl Encode for Cid {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(self.codec as u8);
        w.put_raw(&self.hash);
    }
}

impl Decode for Cid {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let codec = Codec::from_u8(r.get_u8()?)?;
        let hash = r.get_raw(32)?.try_into().unwrap();
        Ok(Cid { codec, hash })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{from_bytes, to_bytes};

    #[test]
    fn deterministic() {
        assert_eq!(Cid::of_raw(b"hello"), Cid::of_raw(b"hello"));
        assert_ne!(Cid::of_raw(b"hello"), Cid::of_raw(b"world"));
    }

    #[test]
    fn codec_separates_namespaces() {
        assert_ne!(Cid::of(Codec::Raw, b"x"), Cid::of(Codec::LogEntry, b"x"));
    }

    #[test]
    fn verification() {
        let cid = Cid::of_raw(b"data");
        assert!(cid.verifies(b"data"));
        assert!(!cid.verifies(b"Data"));
    }

    #[test]
    fn string_roundtrip() {
        let cid = Cid::of(Codec::LogEntry, b"entry");
        let s = cid.to_string_full();
        assert_eq!(Cid::parse(&s), Some(cid));
        assert!(Cid::parse("junk").is_none());
        assert!(Cid::parse(&s[..64]).is_none());
    }

    #[test]
    fn binary_roundtrip() {
        let cid = Cid::of_raw(b"abc");
        assert_eq!(from_bytes::<Cid>(&to_bytes(&cid)).unwrap(), cid);
    }
}

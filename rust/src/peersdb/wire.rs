//! The node's wire message: a tagged union over all sub-protocols plus
//! PeersDB's own control RPCs (join handshake, head exchange, validation
//! queries).

use crate::bitswap;
use crate::cid::Cid;
use crate::codec::bin::{varint_len, Decode, DecodeError, Encode, Reader, Writer};
use crate::dht;
use crate::net::{PeerId, WireSize};
use crate::pubsub;
use crate::stores::documents::ValidationRecord;

#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    Dht(dht::Rpc),
    Bitswap(bitswap::Msg),
    Pubsub(pubsub::Msg),
    /// Join handshake: presented passphrase hash (§III-C access control).
    Join { passphrase: [u8; 32] },
    /// Bootstrap response: admission, peer sample, current store heads.
    JoinAck { accepted: bool, peers: Vec<PeerId>, heads: Vec<Cid> },
    /// Ask a peer for its current contributions-store heads.
    HeadsRequest,
    HeadsReply { heads: Vec<Cid> },
    /// Ask a peer for its stored validation verdict on a data CID.
    ValQuery { req_id: u64, cid: Cid },
    ValReply { req_id: u64, cid: Cid, record: Option<ValidationRecord> },
}

impl Encode for Message {
    fn encode(&self, w: &mut Writer) {
        match self {
            Message::Dht(r) => {
                w.put_u8(0);
                r.encode(w);
            }
            Message::Bitswap(m) => {
                w.put_u8(1);
                m.encode(w);
            }
            Message::Pubsub(m) => {
                w.put_u8(2);
                m.encode(w);
            }
            Message::Join { passphrase } => {
                w.put_u8(3);
                w.put_raw(passphrase);
            }
            Message::JoinAck { accepted, peers, heads } => {
                w.put_u8(4);
                accepted.encode(w);
                peers.encode(w);
                heads.encode(w);
            }
            Message::HeadsRequest => {
                w.put_u8(5);
            }
            Message::HeadsReply { heads } => {
                w.put_u8(6);
                heads.encode(w);
            }
            Message::ValQuery { req_id, cid } => {
                w.put_u8(7);
                w.put_varint(*req_id);
                cid.encode(w);
            }
            Message::ValReply { req_id, cid, record } => {
                w.put_u8(8);
                w.put_varint(*req_id);
                cid.encode(w);
                record.encode(w);
            }
        }
    }
}

impl Decode for Message {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(match r.get_u8()? {
            0 => Message::Dht(dht::Rpc::decode(r)?),
            1 => Message::Bitswap(bitswap::Msg::decode(r)?),
            2 => Message::Pubsub(pubsub::Msg::decode(r)?),
            3 => Message::Join { passphrase: r.get_raw(32)?.try_into().unwrap() },
            4 => Message::JoinAck {
                accepted: bool::decode(r)?,
                peers: Vec::decode(r)?,
                heads: Vec::decode(r)?,
            },
            5 => Message::HeadsRequest,
            6 => Message::HeadsReply { heads: Vec::decode(r)? },
            7 => Message::ValQuery { req_id: r.get_varint()?, cid: Cid::decode(r)? },
            8 => Message::ValReply {
                req_id: r.get_varint()?,
                cid: Cid::decode(r)?,
                record: Option::decode(r)?,
            },
            _ => return Err(DecodeError("bad message tag")),
        })
    }
}

impl WireSize for Message {
    /// *Exact* encoded length, O(1) for every variant — the simulator's
    /// bandwidth model charges precisely the bytes the codec would emit,
    /// and `Cluster::dispatch` never allocates a `Writer` to find out.
    /// Exactness is property-tested in `tests/prop.rs`
    /// (`prop_wire_size_is_exact`).
    fn wire_size(&self) -> usize {
        match self {
            Message::Dht(r) => 1 + r.wire_size(),
            Message::Bitswap(m) => 1 + m.wire_size(),
            Message::Pubsub(m) => 1 + m.wire_size(),
            Message::Join { .. } => 1 + 32,
            Message::JoinAck { peers, heads, .. } => {
                1 + 1
                    + varint_len(peers.len() as u64)
                    + peers.len() * 32
                    + varint_len(heads.len() as u64)
                    + heads.len() * 33
            }
            Message::HeadsRequest => 1,
            Message::HeadsReply { heads } => {
                1 + varint_len(heads.len() as u64) + heads.len() * 33
            }
            Message::ValQuery { req_id, .. } => 1 + varint_len(*req_id) + 33,
            Message::ValReply { req_id, record, .. } => {
                1 + varint_len(*req_id) + 33 + 1 + record.as_ref().map_or(0, validation_record_len)
            }
        }
    }
}

/// Exact encoded length of a [`ValidationRecord`]: CID (33) + verdict
/// byte + f64 score + validator id (32) + two varints.
fn validation_record_len(r: &ValidationRecord) -> usize {
    33 + 1 + 8 + 32 + varint_len(r.validated_at) + varint_len(r.cost_ns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stores::documents::Verdict;
    use crate::util::Rng;

    #[test]
    fn all_variants_roundtrip() {
        let mut rng = Rng::new(1);
        let pid = PeerId::from_rng(&mut rng);
        let cid = Cid::of_raw(b"x");
        let msgs = vec![
            Message::Dht(dht::Rpc::Ping { req_id: 1 }),
            Message::Bitswap(bitswap::Msg::Want { req_id: 2, cid }),
            Message::Pubsub(pubsub::Msg::Subscriptions { topics: vec![pubsub::Topic::named("t")] }),
            Message::Join { passphrase: [7; 32] },
            Message::JoinAck { accepted: true, peers: vec![pid], heads: vec![cid] },
            Message::HeadsRequest,
            Message::HeadsReply { heads: vec![cid, cid] },
            Message::ValQuery { req_id: 3, cid },
            Message::ValReply {
                req_id: 3,
                cid,
                record: Some(ValidationRecord {
                    data_cid: cid,
                    verdict: Verdict::Valid,
                    score: 0.5,
                    validator: pid,
                    validated_at: 1,
                    cost_ns: 2,
                }),
            },
        ];
        for m in msgs {
            let b = crate::codec::to_bytes(&m);
            assert_eq!(crate::codec::from_bytes::<Message>(&b).unwrap(), m);
            assert_eq!(m.wire_size(), b.len(), "wire_size must be exact for {m:?}");
        }
    }

    #[test]
    fn wire_size_exact_for_large_block() {
        let cid = Cid::of_raw(b"block");
        let m = Message::Bitswap(bitswap::Msg::Block {
            req_id: 1,
            cid,
            data: vec![0; 9000].into(),
        });
        assert_eq!(m.wire_size(), crate::codec::to_bytes(&m).len());
    }
}

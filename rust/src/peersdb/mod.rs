//! The PeersDB node: the paper's prototype, §IV-A.
//!
//! A node composes every substrate — blockstore, Kademlia DHT, bitswap,
//! IPFS-Log stores, pubsub, validation, access control — behind one
//! sans-io [`net::Runner`](crate::net::Runner), mirroring the prototype's
//! "service Go-routine [that] manages recurring tasks like user requests,
//! data storage, event handling, P2P communication for new peers, and
//! collaborative validation coordination".
//!
//! The same [`Node`] runs under the DES ([`crate::sim`]) for experiments
//! and under TCP ([`crate::net::tcp`]) for deployments; the HTTP/shell
//! APIs ([`crate::api`]) call the same public methods the experiment
//! harnesses use.

pub mod node;
pub mod quality;
pub mod wire;

pub use node::{Node, NodeConfig, NodeEvent, ValidationSource};
pub use quality::{ChunkScheduler, PeerQuality};
pub use wire::Message;

//! Per-peer transfer-quality tracking for the striped fetch scheduler.
//!
//! The paper's bulk workload is replicating performance datasets between
//! peers (§III-B); a multi-source fetch is only faster than a
//! single-source one if chunks land on the providers that actually
//! deliver. [`PeerQuality`] is the node-local observation table that
//! makes that possible: every bitswap request outcome
//! ([`crate::bitswap::Outcome`]) updates a per-peer cost estimate, and
//! [`ChunkScheduler::Quality`] assigns each chunk to the provider with
//! the lowest estimated cost weighted by its current load.

use crate::net::PeerId;
use std::collections::{BTreeMap, BTreeSet};

/// Chunk-assignment policy for multi-chunk file fetches
/// ([`crate::peersdb::NodeConfig::chunk_scheduler`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkScheduler {
    /// Legacy single-source window: every chunk is requested from one
    /// source peer (the peer that served the root block). The default —
    /// schedules recorded before striping existed replay bit-identically.
    Single,
    /// Stripe chunks across the whole provider set in rotation,
    /// ignoring observed peer quality. Exists as the negative control
    /// for [`ChunkScheduler::Quality`]: a slow provider keeps receiving
    /// its share of chunks and drags the transfer.
    RoundRobin,
    /// Stripe chunks across the provider set weighted by the observed
    /// [`PeerQuality`] cost: cheap (fast, reliable) providers absorb
    /// proportionally more of the window, and a provider that times out
    /// or answers `DontHave` is penalized away from future assignments.
    Quality,
}

/// Newest-sample weight of the block-latency EWMA. 0.3 adapts within a
/// few blocks while smoothing over single-sample jitter.
const EWMA_ALPHA: f64 = 0.3;

/// Optimistic prior cost (milliseconds) for a peer we have never
/// observed. Low enough that unknown providers get tried — discovering
/// a fast peer requires sending it at least one chunk — but nonzero so
/// a peer with one good observation immediately outranks strangers.
const DEFAULT_COST_MS: f64 = 300.0;

/// Penalty (milliseconds) added when a request to the peer times out.
/// A timeout costs the transfer a full RPC-timeout window (4 s by
/// default) plus the reassignment round-trip, so it is scored far above
/// any plausible block latency.
const TIMEOUT_PENALTY_MS: f64 = 2_000.0;

/// Penalty (milliseconds) added when the peer answers `DontHave` (or
/// serves a block that fails content verification — equivalent from the
/// fetcher's point of view: the peer cannot provide this content).
const DONTHAVE_PENALTY_MS: f64 = 500.0;

/// Hard cap on tracked peers. Pre-cap, the table leaked one entry per
/// peer ever fetched from — under city-scale churn that is every peer
/// that ever existed. At the cap, admitting a new peer evicts the
/// worst-cost entry: the peer least likely to win a chunk assignment is
/// the one whose stale score is cheapest to re-learn.
const MAX_TRACKED: usize = 256;

/// Observed statistics for one peer.
#[derive(Clone, Copy, Debug, Default)]
struct PeerScore {
    /// EWMA of block latency in milliseconds; 0.0 until the first block.
    ewma_ms: f64,
    /// Whether `ewma_ms` has at least one sample behind it.
    observed: bool,
    /// Accumulated failure penalty in milliseconds. Grows on timeout /
    /// `DontHave`, halves on every successful block, so a peer that
    /// recovers earns its way back instead of being banned forever.
    penalty_ms: f64,
}

/// Per-node table of observed transfer quality, one entry per peer this
/// node has exchanged bitswap requests with.
///
/// ## Cost model
///
/// A peer's cost (milliseconds, lower is better) is
///
/// ```text
/// cost(p) = ewma(p) + penalty(p)
/// ```
///
/// where
///
/// * `ewma(p)` is an exponentially weighted moving average of observed
///   block latencies with newest-sample weight `EWMA_ALPHA` (0.3):
///   `ewma ← 0.3·sample + 0.7·ewma`. Before the first block arrives the
///   optimistic prior `DEFAULT_COST_MS` (300 ms) stands in, so unknown
///   providers are competitive enough to get sampled at all;
/// * `penalty(p)` accumulates failures — `+2000 ms` per timeout,
///   `+500 ms` per `DontHave` (or tampered block) — and *halves* on
///   every successful block, so transient failures decay once the peer
///   behaves again.
///
/// The table is pure bookkeeping: updates draw no randomness and send
/// no messages, so feeding it unconditionally (even with the scheduler
/// knob off) cannot perturb replay determinism. Iteration is over a
/// `BTreeMap` keyed by [`PeerId`] so any future ordered walk is
/// deterministic too.
///
/// ## Bounds
///
/// The table is bounded two ways, so churn cannot leak one entry per
/// peer that ever existed: a hard [`MAX_TRACKED`] cap with
/// deterministic worst-cost eviction on admission, and the
/// [`PeerQuality::retain_known`] sweep the owning node runs on its
/// anti-entropy cadence to drop peers it no longer tracks anywhere.
#[derive(Clone, Debug, Default)]
pub struct PeerQuality {
    scores: BTreeMap<PeerId, PeerScore>,
}

/// Cost of a recorded score: observed EWMA (or the prior) plus the
/// accumulated failure penalty.
fn score_cost(s: &PeerScore) -> f64 {
    let base = if s.observed { s.ewma_ms } else { DEFAULT_COST_MS };
    base + s.penalty_ms
}

impl PeerQuality {
    pub fn new() -> PeerQuality {
        PeerQuality::default()
    }

    /// Entry for `peer`, admitting it under the [`MAX_TRACKED`] cap: a
    /// full table evicts its worst-cost entry first (ties keep evicting
    /// the smallest [`PeerId`] — strict `>` over the ordered walk —
    /// so eviction is deterministic and replay-safe).
    fn score_mut(&mut self, peer: PeerId) -> &mut PeerScore {
        if !self.scores.contains_key(&peer) && self.scores.len() >= MAX_TRACKED {
            let mut worst: Option<(PeerId, f64)> = None;
            for (id, s) in &self.scores {
                let c = score_cost(s);
                let beats = match &worst {
                    None => true,
                    Some((_, w)) => c > *w,
                };
                if beats {
                    worst = Some((*id, c));
                }
            }
            if let Some((id, _)) = worst {
                self.scores.remove(&id);
            }
        }
        self.scores.entry(peer).or_default()
    }

    /// A verified block arrived from `peer` after `latency_ms`.
    pub fn observe_block(&mut self, peer: PeerId, latency_ms: f64) {
        let s = self.score_mut(peer);
        if s.observed {
            s.ewma_ms = EWMA_ALPHA * latency_ms + (1.0 - EWMA_ALPHA) * s.ewma_ms;
        } else {
            s.ewma_ms = latency_ms;
            s.observed = true;
        }
        s.penalty_ms *= 0.5;
    }

    /// A request to `peer` timed out.
    pub fn observe_timeout(&mut self, peer: PeerId) {
        self.score_mut(peer).penalty_ms += TIMEOUT_PENALTY_MS;
    }

    /// `peer` answered `DontHave` (or served unverifiable content).
    pub fn observe_dont_have(&mut self, peer: PeerId) {
        self.score_mut(peer).penalty_ms += DONTHAVE_PENALTY_MS;
    }

    /// Drop `peer`'s entry (the peer departed or was evicted from every
    /// view this node holds; its next appearance starts from the prior).
    pub fn forget(&mut self, peer: &PeerId) {
        self.scores.remove(peer);
    }

    /// Drop every entry whose peer is not in `known` — the churn-proof
    /// sweep [`crate::peersdb::Node`] runs on its anti-entropy cadence
    /// with the union of its routing table and active fetch providers,
    /// so departed peers cannot accumulate (pure bookkeeping: no sends,
    /// no randomness, replay-inert).
    pub fn retain_known(&mut self, known: &BTreeSet<PeerId>) {
        self.scores.retain(|id, _| known.contains(id));
    }

    /// Estimated cost of requesting a chunk from `peer`, in
    /// milliseconds; lower is better. Unobserved peers cost the
    /// optimistic prior.
    pub fn cost(&self, peer: &PeerId) -> f64 {
        match self.scores.get(peer) {
            Some(s) => score_cost(s),
            None => DEFAULT_COST_MS,
        }
    }

    /// Number of peers with at least one recorded observation.
    pub fn len(&self) -> usize {
        self.scores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scores.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn peer(n: u64) -> PeerId {
        let mut rng = Rng::new(n);
        PeerId::from_rng(&mut rng)
    }

    #[test]
    fn unknown_peer_costs_the_prior() {
        let q = PeerQuality::new();
        assert_eq!(q.cost(&peer(1)), DEFAULT_COST_MS);
        assert!(q.is_empty());
    }

    #[test]
    fn first_block_replaces_the_prior_not_blends_it() {
        let mut q = PeerQuality::new();
        let p = peer(1);
        q.observe_block(p, 40.0);
        assert_eq!(q.cost(&p), 40.0, "first sample is adopted verbatim");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn ewma_converges_toward_recent_latency() {
        let mut q = PeerQuality::new();
        let p = peer(2);
        q.observe_block(p, 100.0);
        for _ in 0..20 {
            q.observe_block(p, 500.0);
        }
        let c = q.cost(&p);
        assert!(c > 450.0 && c <= 500.0, "ewma converged: {c}");
    }

    #[test]
    fn failures_penalize_and_successes_forgive() {
        let mut q = PeerQuality::new();
        let p = peer(3);
        q.observe_block(p, 50.0);
        q.observe_timeout(p);
        assert_eq!(q.cost(&p), 50.0 + TIMEOUT_PENALTY_MS);
        q.observe_dont_have(p);
        assert_eq!(q.cost(&p), 50.0 + TIMEOUT_PENALTY_MS + DONTHAVE_PENALTY_MS);
        // Each successful block halves the accumulated penalty.
        q.observe_block(p, 50.0);
        let c = q.cost(&p);
        assert!(c < 50.0 + (TIMEOUT_PENALTY_MS + DONTHAVE_PENALTY_MS) * 0.6, "{c}");
        for _ in 0..12 {
            q.observe_block(p, 50.0);
        }
        assert!(q.cost(&p) < 55.0, "penalty decays to noise: {}", q.cost(&p));
    }

    #[test]
    fn churn_loop_leaves_the_table_bounded() {
        // Regression: pre-cap, 10,000 distinct peers left 10,000
        // entries — the per-peer leak that bites at city scale.
        let mut q = PeerQuality::new();
        for n in 0..10_000u64 {
            let p = peer(n + 100);
            q.observe_block(p, 50.0 + (n % 7) as f64);
            if n % 3 == 0 {
                q.observe_timeout(p);
            }
        }
        assert!(q.len() <= MAX_TRACKED, "table leaked: {} entries", q.len());
        assert_eq!(q.len(), MAX_TRACKED, "cap admits up to the cap");
    }

    #[test]
    fn admission_evicts_the_worst_cost_entry() {
        let mut q = PeerQuality::new();
        let cheap = peer(1);
        q.observe_block(cheap, 10.0);
        let expensive = peer(2);
        q.observe_block(expensive, 10.0);
        q.observe_timeout(expensive); // worst cost in the table
        // Fill to the cap with middling peers…
        for n in 0..MAX_TRACKED as u64 {
            q.observe_block(peer(n + 100), 200.0);
        }
        // …which must have evicted `expensive` (worst-first), never
        // `cheap`.
        assert!(q.len() <= MAX_TRACKED);
        assert_eq!(q.cost(&cheap), 10.0, "best entry survives eviction");
        assert_eq!(q.cost(&expensive), DEFAULT_COST_MS, "worst entry was evicted");
    }

    #[test]
    fn forget_and_retain_known_drop_departed_peers() {
        let mut q = PeerQuality::new();
        let (a, b, c) = (peer(1), peer(2), peer(3));
        q.observe_block(a, 20.0);
        q.observe_block(b, 30.0);
        q.observe_block(c, 40.0);
        q.forget(&b);
        assert_eq!(q.len(), 2);
        assert_eq!(q.cost(&b), DEFAULT_COST_MS);
        let known: std::collections::BTreeSet<PeerId> = [a].into_iter().collect();
        q.retain_known(&known);
        assert_eq!(q.len(), 1);
        assert_eq!(q.cost(&a), 20.0);
        assert_eq!(q.cost(&c), DEFAULT_COST_MS);
    }

    #[test]
    fn slow_peer_ranks_below_fast_peer_but_above_nothing() {
        let mut q = PeerQuality::new();
        let (fast, slow) = (peer(4), peer(5));
        q.observe_block(fast, 30.0);
        q.observe_block(slow, 900.0);
        assert!(q.cost(&fast) < q.cost(&slow));
        // A known-slow peer is still assignable (finite cost): striping
        // over a bad provider beats stalling with no provider.
        assert!(q.cost(&slow).is_finite());
    }
}

//! The PeersDB node service: composition of all protocol engines plus the
//! paper's workflows (contribution §III-E, replication §III-B, validation
//! §III-C, bootstrap §IV-A experiment 2).

use crate::access::Gate;
use crate::bitswap::{self, BitswapConfig, BitswapEvent, FetchId, Outcome};
use crate::blockstore::{chunker, BlockStore, Pin};
use crate::cid::{Cid, Codec};
use crate::dht::{self, DhtConfig, DhtEvent, Key, LookupId};
use crate::ipfs_log::{Entry, Join};
use crate::metrics::Metrics;
use crate::net::{token, Outbox, PeerId, Runner};
use crate::peersdb::quality::{ChunkScheduler, PeerQuality};
use crate::peersdb::wire::Message;
use crate::pubsub::{self, Topic};
use crate::stores::documents::{ValidationRecord, ValidationsStore, Verdict};
use crate::stores::{Contribution, ContributionsStore, KvStore, StoreAddress};
use crate::util::time::{Duration, Nanos};
use crate::util::{Blob, Rng};
use crate::validation::{BatchQueue, CostModel, IdentityValidator, Task, Validator};
use crate::validation::quorum::{QuorumConfig, VoteOutcome, VoteState};
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Node configuration (the paper's Helm-chart parametrization).
#[derive(Clone, Debug)]
pub struct NodeConfig {
    pub passphrase: String,
    pub store_name: String,
    /// Bootstrap (root) peer to join through, if any.
    pub bootstrap: Option<PeerId>,
    /// Replicate (pin) contribution data files automatically.
    pub auto_pin: bool,
    /// Validate replicated contributions automatically.
    pub auto_validate: bool,
    /// Announce DHT provider records for data we contribute.
    pub announce_providers: bool,
    /// Also announce provider records immediately after *replicating*
    /// someone else's data. kubo batches these on a multi-hour reprovide
    /// interval, so the faithful default is off; replicas still serve
    /// Wants either way, and anti-entropy covers discovery.
    pub announce_replicas: bool,
    pub quorum: QuorumConfig,
    pub cost_model: CostModel,
    /// Validation batch size (1 = validate each contribution alone).
    pub batch_size: usize,
    /// Max outstanding chunk requests per file fetch (bitswap-session
    /// window; keeps large files on slow links under the RPC timeout).
    pub chunk_window: usize,
    /// How chunks of a multi-block file are assigned to providers.
    /// Default [`ChunkScheduler::Single`] — the legacy one-source
    /// window — so pre-striping schedules replay bit-identically; the
    /// striped modes spread the window across the whole provider set
    /// and reassign failed chunks to the next-best provider.
    pub chunk_scheduler: ChunkScheduler,
    /// Start a partial batch after this long without new work.
    pub batch_flush: Duration,
    pub tick_interval: Duration,
    /// DHT engine knobs, including the eclipse-hardening pair:
    /// [`DhtConfig::lookup_paths`] (disjoint-path lookups) and
    /// [`DhtConfig::verify_peers`] (distance-verified routing updates +
    /// the `pending_verify` first-contact tier). Both default off, so
    /// pre-hardening schedules replay bit-identically.
    pub dht: DhtConfig,
    pub bitswap: BitswapConfig,
    /// Pubsub neighbor sample size taken from the routing table.
    pub neighbor_degree: usize,
    /// Gossip-mesh pubsub knobs ([`pubsub::MeshConfig`]). `Some` flips
    /// the engine from floodsub to the bounded-degree eager-push +
    /// lazy-IHAVE/IWANT mesh, with the heartbeat driven off the node
    /// tick. Default `None`: flood dissemination, zero extra frames and
    /// zero extra RNG draws, so pre-mesh schedules replay
    /// bit-identically.
    pub mesh: Option<pubsub::MeshConfig>,
    /// CPU model: base cost per message + per-KiB payload cost.
    pub proc_cost_per_msg: Duration,
    pub proc_cost_per_kb: Duration,
    /// Periodic anti-entropy: every N ticks, exchange heads with one
    /// random peer (guarantees convergence even when a pubsub
    /// announcement races ahead of subscription gossip). 0 disables.
    pub anti_entropy_every_ticks: u32,
    /// Availability-repair cadence (§III-B replication maintenance):
    /// every `repair_interval`, probe the DHT for each known
    /// contribution's provider count and, when one has fallen below
    /// [`NodeConfig::replication_target`], re-announce a held copy or
    /// re-fetch + re-pin a lost one from the surviving providers.
    /// `Duration::ZERO` (the default) disables the loop entirely — no
    /// probes, no extra RNG draws — so schedules that predate the loop
    /// replay bit-identically.
    pub repair_interval: Duration,
    /// Per-node phase jitter applied to the repair cadence, as a
    /// fraction of `repair_interval` in `[0, 1]`. With a shared
    /// interval and no jitter every node fires its provider-count
    /// probes on the same phase — a thundering herd of
    /// `find_providers_full` lookups that at city scale lands a
    /// cluster-wide synchronized burst each cycle. A nonzero jitter
    /// offsets each node's *first* fire by a deterministic hash of its
    /// peer id (no RNG draw — consuming randomness here would shift
    /// every later draw and break replay comparisons), spreading the
    /// herd across `jitter · repair_interval` while preserving the
    /// per-node cadence. Default `0.0`: pre-jitter schedules replay
    /// bit-identically.
    pub repair_jitter: f64,
    /// Provider-record floor the repair loop drives each contribution
    /// toward. Distinct from the *invariant checker's* target
    /// (`sim::scenario::InvariantConfig::replication_target`): this is
    /// what nodes aim for, that is what a test demands.
    pub replication_target: usize,
    /// ABLATION (benches/sim_validation): answer validation queries only
    /// after in-flight local validations finish — the *blocking* design
    /// the paper's simulation study argues against. Default: async
    /// (answer immediately from the validations store).
    pub blocking_validation: bool,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig {
            passphrase: "peersdb".into(),
            store_name: "contributions".into(),
            bootstrap: None,
            auto_pin: true,
            auto_validate: false,
            announce_providers: true,
            announce_replicas: false,
            quorum: QuorumConfig::default(),
            cost_model: CostModel::Constant { ns: 1_000_000 },
            batch_size: 1,
            chunk_window: 8,
            chunk_scheduler: ChunkScheduler::Single,
            batch_flush: Duration::from_millis(500),
            tick_interval: Duration::from_millis(100),
            dht: DhtConfig::default(),
            bitswap: BitswapConfig::default(),
            neighbor_degree: 8,
            mesh: None,
            proc_cost_per_msg: Duration::from_micros(30),
            proc_cost_per_kb: Duration::from_micros(8),
            anti_entropy_every_ticks: 20,
            repair_interval: Duration::ZERO,
            repair_jitter: 0.0,
            replication_target: 3,
            blocking_validation: false,
        }
    }
}

/// Where a validation verdict came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValidationSource {
    Local,
    Network,
}

/// Observable node events, drained by harnesses / the API layer.
#[derive(Clone, Debug)]
pub enum NodeEvent {
    /// Bootstrap finished: DHT populated and store synced.
    BootstrapDone { started: Nanos, completed: Nanos, entries_synced: usize },
    /// A remote contribution is fully replicated locally (entry + data).
    ContributionReplicated {
        data_cid: Cid,
        author: PeerId,
        created_at: u64,
        completed_at: Nanos,
    },
    /// A validation verdict was stored.
    ValidationDone {
        data_cid: Cid,
        verdict: Verdict,
        score: f64,
        source: ValidationSource,
    },
    /// A remote peer asked for a private CID and was denied.
    PrivateDenied { cid: Cid, peer: PeerId },
}

enum FetchPurpose {
    /// A contributions-store log entry block.
    LogEntry,
    /// The root block of a contribution's data file.
    DataRoot { data_cid: Cid },
    /// A chunk of a chunked data file.
    DataChunk { root: Cid },
}

/// Windowed multi-block file fetch (a bitswap "session"): at most
/// `chunk_window` chunk requests outstanding per file, so large files on
/// slow links do not overrun the per-request timeout (the retry storm a
/// naive want-burst causes). Under the striped schedulers the window is
/// spread across `providers` instead of pinned to one `source`.
struct DataFetch {
    pending: Vec<Cid>,
    /// Chunk → the provider it is currently assigned to.
    in_flight: HashMap<Cid, PeerId>,
    /// Known providers of this file (order-preserving, deduped, never
    /// contains self). Grows as the stripe lookup and served blocks
    /// reveal more holders.
    providers: Vec<PeerId>,
    /// Per chunk: providers that already failed it (striped modes only;
    /// reassignment never retries a peer that failed the same chunk).
    tried: HashMap<Cid, Vec<PeerId>>,
    /// Rotation cursor for [`ChunkScheduler::RoundRobin`].
    rr_next: usize,
    /// Legacy single-source peer (the peer that most recently served a
    /// block of this file).
    source: PeerId,
}

impl DataFetch {
    fn new(source: PeerId) -> DataFetch {
        DataFetch {
            pending: Vec::new(),
            in_flight: HashMap::new(),
            providers: Vec::new(),
            tried: HashMap::new(),
            rr_next: 0,
            source,
        }
    }
}

/// Pick the cheapest provider in `avail` by observed [`PeerQuality`]
/// cost, weighting each peer's cost by the load it already carries for
/// this fetch (`(load + 1) · cost`), ties to provider order. A free
/// function — not a method — so callers can hold a mutable borrow of
/// the fetch entry alongside the shared quality table.
fn pick_quality(
    quality: &PeerQuality,
    avail: &[PeerId],
    in_flight: &HashMap<Cid, PeerId>,
) -> PeerId {
    let mut best = avail[0];
    let mut best_cost = f64::INFINITY;
    for &p in avail {
        let load = in_flight.values().filter(|q| **q == p).count();
        let cost = (load as f64 + 1.0) * quality.cost(&p);
        if cost < best_cost {
            best_cost = cost;
            best = p;
        }
    }
    best
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Bootstrap {
    /// Root node (no bootstrap peer): immediately operational.
    Root,
    Joining { started: Nanos },
    /// Admitted; syncing DHT + store.
    Syncing { started: Nanos, lookup_done: bool },
    Done,
}

const TICK: u64 = 0;

/// The PeersDB node. See module docs.
pub struct Node {
    id: PeerId,
    pub cfg: NodeConfig,
    gate: Gate,
    rng: Rng,
    pub bs: BlockStore,
    pub dht: dht::Engine,
    bitswap: bitswap::Engine,
    pubsub: pubsub::Engine,
    pub contributions: ContributionsStore,
    pub validations: ValidationsStore,
    pub kv: KvStore,
    validator: Box<dyn Validator>,
    batch_queue: BatchQueue,
    last_enqueue: Nanos,

    topic: Topic,
    bootstrap: Bootstrap,
    next_req: u64,

    // Replication bookkeeping.
    fetch_purpose: HashMap<FetchId, FetchPurpose>,
    entry_fetches: HashMap<Cid, FetchId>,
    data_fetches: HashMap<Cid, DataFetch>,
    /// DHT provider lookups for block fetches: lookup → (cid, fetch).
    provider_lookups: HashMap<LookupId, (Cid, Option<FetchId>)>,
    /// Provider-set widening lookups for striped fetches: lookup → root.
    stripe_lookups: HashMap<LookupId, Cid>,
    /// Observed per-peer transfer quality, fed unconditionally from
    /// bitswap outcomes (pure bookkeeping — replay-inert) and consulted
    /// by [`ChunkScheduler::Quality`].
    quality: PeerQuality,
    /// DHT lookups that exist to announce a provider record.
    provide_lookups: HashMap<LookupId, Key>,
    /// Bootstrap self-lookup.
    bootstrap_lookup: Option<LookupId>,
    /// data root CID → (author, created_at) while replication in flight.
    contribution_meta: HashMap<Cid, (PeerId, u64)>,

    /// Purposes remembered across provider-lookup retries.
    retry_purposes: HashMap<Cid, FetchPurpose>,

    // Availability-repair bookkeeping (the §III-B maintenance loop).
    /// Runtime kill-switch for the repair loop (scenario fault
    /// `SetRepair`); the loop runs only when this is set *and*
    /// `repair_interval` is nonzero.
    repair_enabled: bool,
    /// When the last repair cycle started.
    last_repair: Nanos,
    /// Outstanding provider-count probes: lookup → data root.
    repair_probes: HashMap<LookupId, Cid>,
    /// Data roots with a probe in flight (so back-to-back cycles never
    /// stack probes for one contribution).
    probing: BTreeSet<Cid>,
    /// Data roots being re-fetched *by the repair loop*; their
    /// completion announces a provider record unconditionally — a
    /// repaired replica nobody can discover raises no availability.
    repair_fetches: BTreeSet<Cid>,
    /// Data roots this node deliberately dropped (unpin + GC). Repair
    /// must never resurrect these locally: the operator decided this
    /// node stops holding them, and re-replication is the *other*
    /// nodes' job. A later explicit [`Node::fetch_cid`] clears the mark.
    dropped: BTreeSet<Cid>,
    /// Provider-record withdrawals in flight: lookup → key.
    withdraw_lookups: HashMap<LookupId, Key>,

    // Validation bookkeeping. Votes are swept by expiry time — ordered
    // map so the sweep (and everything it triggers) is deterministic.
    votes: BTreeMap<Cid, VoteState>,
    val_req_index: HashMap<u64, Cid>,
    /// Data roots whose *current* verdict was adopted from the network
    /// (quorum vote) rather than computed locally. Ground-truth-aware
    /// harnesses read this to tell a lie this node swallowed from a lie
    /// it merely relayed an opinion about.
    network_verdicts: BTreeSet<Cid>,

    pub events: Vec<NodeEvent>,
    pub metrics: Metrics,
    tick_count: u32,
    /// ValQueries parked while blocking_validation holds them back.
    deferred_val_replies: Vec<(PeerId, u64, Cid)>,
    /// When validation began per CID (for the verdict-latency metric).
    validation_started: HashMap<Cid, Nanos>,
    /// Contributions whose data files are not yet fully local
    /// (incremental — the anti-entropy sweep iterates only this; ordered
    /// so retry order, and thus RNG consumption, is reproducible).
    incomplete_data: BTreeMap<Cid, PeerId>,
}

impl Node {
    pub fn new(id: PeerId, cfg: NodeConfig, seed: u64) -> Node {
        Node::with_validator(id, cfg, seed, Box::new(IdentityValidator))
    }

    pub fn with_validator(
        id: PeerId,
        cfg: NodeConfig,
        seed: u64,
        validator: Box<dyn Validator>,
    ) -> Node {
        let gate = Gate::new(&cfg.passphrase);
        let topic = StoreAddress(cfg.store_name.clone()).topic();
        let batch = BatchQueue::new(cfg.batch_size);
        // Repair-phase jitter: a pure FxHash of the peer id modulo the
        // jitter span — deterministic per node, zero RNG draws (drawing
        // from `rng` here would shift every subsequent draw and break
        // replay comparisons against unjittered recordings). Seeding
        // `last_repair` with the phase delays only the *first* cycle;
        // the cadence afterwards is exactly `repair_interval`.
        let repair_phase = {
            let span =
                (cfg.repair_interval.0 as f64 * cfg.repair_jitter.clamp(0.0, 1.0)) as u64;
            if span == 0 {
                0
            } else {
                use std::hash::Hasher;
                let mut h = crate::util::fxhash::FxHasher::default();
                h.write(&id.0);
                h.finish() % span
            }
        };
        Node {
            id,
            gate,
            rng: Rng::new(seed),
            bs: BlockStore::new(),
            dht: dht::Engine::new(id, cfg.dht.clone()),
            bitswap: bitswap::Engine::new(cfg.bitswap.clone()),
            pubsub: {
                let mut ps = pubsub::Engine::new(id);
                if let Some(mesh) = &cfg.mesh {
                    ps.enable_mesh(mesh.clone());
                }
                ps
            },
            contributions: ContributionsStore::new(),
            validations: ValidationsStore::new(),
            kv: KvStore::new(),
            validator,
            batch_queue: batch,
            last_enqueue: Nanos::ZERO,
            topic,
            bootstrap: if cfg.bootstrap.is_some() {
                Bootstrap::Joining { started: Nanos::ZERO }
            } else {
                Bootstrap::Root
            },
            next_req: 1,
            fetch_purpose: HashMap::new(),
            entry_fetches: HashMap::new(),
            data_fetches: HashMap::new(),
            provider_lookups: HashMap::new(),
            stripe_lookups: HashMap::new(),
            quality: PeerQuality::new(),
            provide_lookups: HashMap::new(),
            bootstrap_lookup: None,
            contribution_meta: HashMap::new(),
            retry_purposes: HashMap::new(),
            repair_enabled: true,
            last_repair: Nanos(repair_phase),
            repair_probes: HashMap::new(),
            probing: BTreeSet::new(),
            repair_fetches: BTreeSet::new(),
            dropped: BTreeSet::new(),
            withdraw_lookups: HashMap::new(),
            votes: BTreeMap::new(),
            val_req_index: HashMap::new(),
            network_verdicts: BTreeSet::new(),
            events: Vec::new(),
            metrics: Metrics::new(),
            tick_count: 0,
            deferred_val_replies: Vec::new(),
            validation_started: HashMap::new(),
            incomplete_data: BTreeMap::new(),
            cfg,
        }
    }

    pub fn peer_id(&self) -> PeerId {
        self.id
    }

    pub fn is_bootstrapped(&self) -> bool {
        matches!(self.bootstrap, Bootstrap::Root | Bootstrap::Done)
    }

    /// Drop quality-table entries for peers this node no longer tracks
    /// anywhere — neither in its routing table nor as a provider (or
    /// assigned peer, or legacy source) of any active data fetch. Runs
    /// on the anti-entropy cadence so churn can't leak one entry per
    /// departed peer; pure bookkeeping (no sends, no RNG draws), hence
    /// replay-inert for every recorded schedule.
    fn prune_quality(&mut self) {
        if self.quality.is_empty() {
            return;
        }
        let mut known: BTreeSet<PeerId> = self.dht.table.peers().into_iter().collect();
        for f in self.data_fetches.values() {
            known.extend(f.providers.iter().copied());
            known.extend(f.in_flight.values().copied());
            known.insert(f.source);
        }
        self.quality.retain_known(&known);
    }

    /// Pubsub counters `(published, forwarded, delivered, duplicates)`.
    /// `forwarded` counts `Publish` frames actually pushed onto links
    /// (fan-out, relays, IWANT serves); `delivered` counts first-copy
    /// local deliveries. `benches/sim_scale.rs` folds these into each
    /// record: `duplicates / delivered` is the redundancy factor
    /// (wasted frames per useful delivery) the gossip mesh is chartered
    /// to collapse versus flood.
    pub fn pubsub_stats(&self) -> (u64, u64, u64, u64) {
        (
            self.pubsub.published,
            self.pubsub.forwarded,
            self.pubsub.delivered,
            self.pubsub.duplicates,
        )
    }

    /// Gossip-mesh telemetry `(ihave_sent, iwant_served, grafts,
    /// prunes)` — all zero in flood mode.
    pub fn pubsub_mesh_stats(&self) -> (u64, u64, u64, u64) {
        self.pubsub.mesh_stats()
    }

    /// Number of pubsub messages this node originated (seqs `1..=n`).
    pub fn pubsub_published_count(&self) -> u64 {
        self.pubsub.published_count()
    }

    /// Whether pubsub message `(origin, seq)` was delivered locally —
    /// the per-node half of the full-delivery invariant.
    pub fn pubsub_has_delivered(&self, origin: PeerId, seq: u64) -> bool {
        self.pubsub.has_delivered(origin, seq)
    }

    // ======================================================================
    // Public API (called by the HTTP/shell layer and experiment harnesses
    // through `Cluster::with_node` / `TcpNode::call`)
    // ======================================================================

    /// POST a contribution (§III-E): store the file content-addressed,
    /// append a reference to the contributions store, announce it.
    /// Returns the data root CID.
    #[allow(clippy::too_many_arguments)]
    pub fn contribute(
        &mut self,
        now: Nanos,
        data: &[u8],
        workload: &str,
        platform: &str,
        out: &mut Outbox<Message>,
    ) -> Cid {
        let added = chunker::add_file(&mut self.bs, data);
        for b in &added.blocks {
            self.bs.pin(b, Pin::Local);
        }
        let c = Contribution {
            data_cid: added.root,
            author: self.id,
            workload: workload.into(),
            platform: platform.into(),
            size_bytes: data.len() as u64,
            created_at: now.0,
        };
        let (entry_cid, entry) = self.contributions.add(self.id, &c);
        // The log entry itself is a block other peers will fetch.
        let entry_bytes = crate::codec::to_bytes(&entry);
        let stored = self.bs.put(Codec::LogEntry, entry_bytes);
        debug_assert_eq!(stored, entry_cid);
        self.bs.pin(&entry_cid, Pin::Local);
        self.metrics.inc("contributions_added");
        // Announce new heads over pubsub.
        let heads = self.contributions.heads();
        let payload = crate::codec::to_bytes(&heads);
        let mut ps_out = pubsub::Sends::new();
        self.pubsub.publish(now, self.topic, payload, &mut ps_out);
        self.wrap_pubsub(ps_out, out);
        // Provider records for the data root.
        if self.cfg.announce_providers {
            self.start_provide(now, Key::from_cid(&added.root), out);
        }
        added.root
    }

    /// Store a private (never shared) file: strong privacy per §III-B.
    pub fn put_private(&mut self, data: &[u8]) -> Cid {
        let added = chunker::add_file(&mut self.bs, data);
        for b in &added.blocks {
            self.bs.pin(b, Pin::Local);
            self.bs.set_private(b, true);
        }
        self.metrics.inc("private_files_added");
        added.root
    }

    /// GET a file by root CID from the local blockstore.
    pub fn get_file(&self, cid: &Cid) -> Option<Vec<u8>> {
        chunker::get_file(&self.bs, cid)
    }

    /// Query the contributions store (§III-D pre-filtering).
    pub fn query_contributions(&self, pred: impl Fn(&Contribution) -> bool) -> Vec<Contribution> {
        self.contributions.filter(pred)
    }

    /// Stored validation verdict, if any.
    pub fn verdict(&self, cid: &Cid) -> Option<Verdict> {
        self.validations.verdict(cid)
    }

    /// Whether this node fully holds the data file rooted at `cid` —
    /// root block plus every chunk, not marked private. The holder
    /// predicate behind the availability invariant and the per-peer
    /// `holds` column of `sim::parity`'s convergence report.
    pub fn holds_data(&self, cid: &Cid) -> bool {
        chunker::has_file(&self.bs, cid) && !self.bs.is_private(cid)
    }

    /// Digest of the contribution log — the cross-replica convergence
    /// fingerprint (equal digests ⇒ identical logs).
    pub fn log_digest(&self) -> [u8; 32] {
        self.contributions.digest()
    }

    /// Current contribution-log heads, sorted, for timing-free head-set
    /// comparison across peers.
    pub fn log_heads(&self) -> Vec<Cid> {
        let mut heads = self.contributions.heads();
        heads.sort();
        heads
    }

    /// Manually trigger validation of a replicated contribution.
    pub fn validate(&mut self, now: Nanos, data_cid: Cid, out: &mut Outbox<Message>) {
        self.begin_validation(now, data_cid, out);
    }

    /// Swap the local validation routine. Used by fault-injection
    /// scenarios to turn a peer byzantine mid-run; affects only verdicts
    /// computed after the swap.
    pub fn set_validator(&mut self, v: Box<dyn Validator>) {
        self.validator = v;
    }

    /// Install (or with `None` clear) adversarial DHT reply forging:
    /// while set, every `FindNodeReply`/`GetProvidersReply` this node
    /// serves lists exactly `colluders` instead of its honest view. The
    /// wire-wrapping hook behind `sim::scenario`'s eclipse faults; all
    /// other protocol behaviour (replication, validation, pubsub) stays
    /// honest.
    pub fn set_dht_forgery(&mut self, colluders: Option<Vec<PeerId>>) {
        self.dht.set_forgery(colluders);
    }

    /// Ask a specific peer for its heads (anti-entropy).
    pub fn sync_with(&mut self, peer: PeerId, out: &mut Outbox<Message>) {
        out.send(peer, Message::HeadsRequest);
    }

    /// Fetch an arbitrary block by CID (e.g. one whose CID was learned out
    /// of band). Replicated data lands in the blockstore as a root fetch.
    /// An explicit fetch overrides an earlier deliberate drop: the
    /// operator asking for the data again is the one way a node rejoins
    /// the holder set for something it unpinned.
    pub fn fetch_cid(
        &mut self,
        now: Nanos,
        cid: Cid,
        candidates: Vec<PeerId>,
        out: &mut Outbox<Message>,
    ) {
        self.dropped.remove(&cid);
        self.fetch_data(now, cid, candidates, out);
    }

    /// Enable or disable the availability-repair loop at runtime (the
    /// `Fault::SetRepair` hook; config-level gating is
    /// [`NodeConfig::repair_interval`]).
    pub fn set_repair(&mut self, on: bool) {
        self.repair_enabled = on;
    }

    /// Whether the repair loop is currently armed.
    pub fn repair_active(&self) -> bool {
        self.repair_enabled && self.cfg.repair_interval.0 > 0
    }

    /// Deliberately unpin every contribution data file held locally —
    /// own contributions included — and withdraw the matching DHT
    /// provider records. This models an operator freeing disk under GC
    /// pressure: the files become collectible by the next
    /// [`Node::collect_garbage`], while log *entry* blocks stay pinned
    /// so history remains servable to late joiners. Each dropped root is
    /// remembered so this node's repair loop never resurrects it; other
    /// nodes observe the shrunken provider count and re-replicate.
    /// Returns the number of files unpinned.
    pub fn unpin_contribution_data(&mut self, now: Nanos, out: &mut Outbox<Message>) -> usize {
        let roots: Vec<Cid> = self.contributions.data_cids().iter().copied().collect();
        let mut files = 0;
        for root in roots {
            if !self.bs.has(&root) {
                continue;
            }
            chunker::unpin_file(&mut self.bs, &root);
            self.dropped.insert(root);
            // Abandon any in-flight replication of the file…
            self.incomplete_data.remove(&root);
            self.data_fetches.remove(&root);
            self.repair_fetches.remove(&root);
            // …and retract our provider record so repair probes see the
            // true holder count instead of a stale record aging out.
            self.start_withdraw(now, Key::from_cid(&root), out);
            files += 1;
        }
        self.metrics.add("files_unpinned", files as u64);
        files
    }

    /// Run blockstore garbage collection now, recording the
    /// `blocks_gcd` / `bytes_gcd` metrics. Returns `(blocks, bytes)`
    /// collected.
    pub fn collect_garbage(&mut self) -> (usize, usize) {
        let (blocks, bytes) = self.bs.gc();
        self.metrics.add("blocks_gcd", blocks as u64);
        self.metrics.add("bytes_gcd", bytes as u64);
        (blocks, bytes)
    }

    /// Ask one specific peer for its stored verdict on a CID (a raw
    /// validation query outside the quorum machinery; replies are counted
    /// in the `val_replies_received` metric).
    pub fn query_verdict_remote(&mut self, peer: PeerId, cid: Cid, out: &mut Outbox<Message>) {
        let req_id = self.fresh_req();
        out.send(peer, Message::ValQuery { req_id, cid });
    }

    // ======================================================================
    // Engine plumbing
    // ======================================================================

    fn wrap_dht(&mut self, sends: dht::engine::Sends, out: &mut Outbox<Message>) {
        for (to, rpc) in sends {
            out.send(to, Message::Dht(rpc));
        }
    }

    fn wrap_bitswap(&mut self, sends: bitswap::Sends, out: &mut Outbox<Message>) {
        for (to, m) in sends {
            out.send(to, Message::Bitswap(m));
        }
    }

    fn wrap_pubsub(&mut self, sends: pubsub::Sends, out: &mut Outbox<Message>) {
        for (to, m) in sends {
            out.send(to, Message::Pubsub(m));
        }
    }

    fn fresh_req(&mut self) -> u64 {
        let id = self.next_req;
        self.next_req += 1;
        id
    }

    fn start_provide(&mut self, now: Nanos, key: Key, out: &mut Outbox<Message>) {
        let mut sends = dht::engine::Sends::new();
        let lid = self.dht.provide(now, key, &mut sends);
        self.provide_lookups.insert(lid, key);
        self.wrap_dht(sends, out);
        self.drain_engines(now, out);
    }

    fn start_withdraw(&mut self, now: Nanos, key: Key, out: &mut Outbox<Message>) {
        let mut sends = dht::engine::Sends::new();
        let lid = self.dht.withdraw(now, key, &mut sends);
        self.withdraw_lookups.insert(lid, key);
        self.wrap_dht(sends, out);
        self.drain_engines(now, out);
    }

    // ======================================================================
    // Replication (§III-B / §III-D auto-pinning)
    // ======================================================================

    /// Begin fetching a log entry we do not have.
    fn fetch_entry(
        &mut self,
        now: Nanos,
        cid: Cid,
        candidates: Vec<PeerId>,
        out: &mut Outbox<Message>,
    ) {
        if self.contributions.contains_entry(&cid) || self.entry_fetches.contains_key(&cid) {
            return;
        }
        let mut sends = bitswap::Sends::new();
        let fid = self.bitswap.fetch(now, cid, candidates, &mut sends);
        self.fetch_purpose.insert(fid, FetchPurpose::LogEntry);
        self.entry_fetches.insert(cid, fid);
        self.wrap_bitswap(sends, out);
        self.metrics.inc("entry_fetches_started");
    }

    /// Order-preserving dedup of a provider candidate list, excluding
    /// this node itself (a node never Wants from itself).
    fn dedup_providers(&self, candidates: &[PeerId]) -> Vec<PeerId> {
        let mut provs = Vec::with_capacity(candidates.len());
        for p in candidates {
            if *p != self.id && !provs.contains(p) {
                provs.push(*p);
            }
        }
        provs
    }

    /// Begin fetching a contribution's data file.
    fn fetch_data(
        &mut self,
        now: Nanos,
        data_cid: Cid,
        candidates: Vec<PeerId>,
        out: &mut Outbox<Message>,
    ) {
        if chunker::has_file(&self.bs, &data_cid) || self.data_fetches.contains_key(&data_cid) {
            return;
        }
        self.metrics.inc("data_fetches_started");
        let providers = self.dedup_providers(&candidates);
        if self.bs.has(&data_cid) {
            // Root block already here (e.g. earlier partial fetch):
            // go straight to chunk scheduling — or, with no usable
            // candidate, to a provider lookup. (The old code defaulted
            // the source to *ourselves* here: every chunk was Want'ed
            // from self, a guaranteed DontHave → Exhausted → per-chunk
            // DHT lookup storm.)
            if providers.is_empty() {
                self.begin_chunk_provider_lookup(now, data_cid, out);
            } else {
                self.schedule_chunks(now, data_cid, providers, out);
            }
            return;
        }
        let mut sends = bitswap::Sends::new();
        let fid = self.bitswap.fetch(now, data_cid, candidates, &mut sends);
        self.fetch_purpose.insert(fid, FetchPurpose::DataRoot { data_cid });
        let mut df = DataFetch::new(self.id);
        df.providers = providers;
        self.data_fetches.insert(data_cid, df);
        self.wrap_bitswap(sends, out);
    }

    /// The root block is local but no chunk source is known: run one
    /// provider lookup on the file's root key (chunk keys are never
    /// announced — only roots are). Its completion re-enters chunk
    /// scheduling with real providers via the `DataRoot` retry purpose.
    fn begin_chunk_provider_lookup(&mut self, now: Nanos, root: Cid, out: &mut Outbox<Message>) {
        self.metrics.inc("chunk_provider_lookups");
        // Placeholder marks the file fetch live (dedup + bootstrap
        // gating) while the lookup runs.
        self.data_fetches.insert(root, DataFetch::new(self.id));
        let mut sends = dht::engine::Sends::new();
        let lid = self.dht.find_providers(now, Key::from_cid(&root), &mut sends);
        self.provider_lookups.insert(lid, (root, None));
        self.retry_purposes.insert(root, FetchPurpose::DataRoot { data_cid: root });
        self.wrap_dht(sends, out);
    }

    /// Set up the chunk window for a file whose root block is local.
    /// `providers` is deduped and self-free; providers remembered on an
    /// existing fetch entry for the root are merged in behind it.
    fn schedule_chunks(
        &mut self,
        now: Nanos,
        root: Cid,
        providers: Vec<PeerId>,
        out: &mut Outbox<Message>,
    ) {
        let children = chunker::child_blocks(self.bs.get(&root).expect("root present"));
        let pending: Vec<Cid> = children.into_iter().filter(|c| !self.bs.has(c)).collect();
        let mut merged = providers;
        if let Some(old) = self.data_fetches.remove(&root) {
            for p in old.providers {
                if p != self.id && !merged.contains(&p) {
                    merged.push(p);
                }
            }
        }
        if pending.is_empty() {
            self.finish_replication(now, root, out);
            return;
        }
        let mut df = DataFetch::new(merged.first().copied().unwrap_or(self.id));
        df.pending = pending;
        df.providers = merged;
        self.data_fetches.insert(root, df);
        if self.cfg.chunk_scheduler != ChunkScheduler::Single {
            self.start_stripe_lookup(now, root, out);
        }
        self.pump_chunks(now, root, out);
    }

    /// Striped fetches widen their provider set beyond whoever served
    /// the root block: one provider lookup on the root key per fetch.
    fn start_stripe_lookup(&mut self, now: Nanos, root: Cid, out: &mut Outbox<Message>) {
        let mut sends = dht::engine::Sends::new();
        let lid = self.dht.find_providers(now, Key::from_cid(&root), &mut sends);
        self.stripe_lookups.insert(lid, root);
        self.wrap_dht(sends, out);
    }

    /// Stripe-lookup completion: grow the provider set, then pump so
    /// newly discovered providers pick up window slots immediately.
    fn on_stripe_providers(
        &mut self,
        now: Nanos,
        root: Cid,
        providers: Vec<PeerId>,
        out: &mut Outbox<Message>,
    ) {
        let my_id = self.id;
        let Some(df) = self.data_fetches.get_mut(&root) else { return };
        let mut grew = false;
        for p in providers {
            if p != my_id && !df.providers.contains(&p) {
                df.providers.push(p);
                grew = true;
            }
        }
        if grew {
            self.pump_chunks(now, root, out);
        }
    }

    /// Issue chunk requests up to the window limit, assigning each
    /// chunk a provider per the configured [`ChunkScheduler`].
    fn pump_chunks(&mut self, now: Nanos, root: Cid, out: &mut Outbox<Message>) {
        let window = self.cfg.chunk_window.max(1);
        let sched = self.cfg.chunk_scheduler;
        let quality = &self.quality;
        let Some(df) = self.data_fetches.get_mut(&root) else { return };
        let mut to_issue: Vec<(Cid, PeerId)> = Vec::new();
        while df.in_flight.len() < window {
            let Some(chunk) = df.pending.pop() else { break };
            let peer = match sched {
                ChunkScheduler::Single => df.source,
                ChunkScheduler::RoundRobin | ChunkScheduler::Quality
                    if df.providers.is_empty() =>
                {
                    // No provider yet (stripe lookup still running):
                    // hold the chunk rather than Want it from nobody.
                    df.pending.push(chunk);
                    break;
                }
                ChunkScheduler::RoundRobin => {
                    let p = df.providers[df.rr_next % df.providers.len()];
                    df.rr_next = df.rr_next.wrapping_add(1);
                    p
                }
                ChunkScheduler::Quality => pick_quality(quality, &df.providers, &df.in_flight),
            };
            df.in_flight.insert(chunk, peer);
            to_issue.push((chunk, peer));
        }
        let complete = df.pending.is_empty() && df.in_flight.is_empty();
        if complete {
            self.data_fetches.remove(&root);
            self.finish_replication(now, root, out);
            return;
        }
        if sched != ChunkScheduler::Single && !to_issue.is_empty() {
            self.metrics.add("chunks_striped", to_issue.len() as u64);
        }
        let mut sends = bitswap::Sends::new();
        for (chunk, peer) in to_issue {
            let fid = self.bitswap.fetch(now, chunk, vec![peer], &mut sends);
            self.fetch_purpose.insert(fid, FetchPurpose::DataChunk { root });
        }
        self.wrap_bitswap(sends, out);
    }

    /// A striped chunk ran out of its assigned provider (timeout,
    /// `DontHave`, or departure): reassign it to the next-best provider
    /// that has not already failed it, or give up on the whole file —
    /// cancelling live siblings — when every provider has.
    fn on_chunk_exhausted(&mut self, now: Nanos, root: Cid, chunk: Cid, out: &mut Outbox<Message>) {
        let sched = self.cfg.chunk_scheduler;
        let quality = &self.quality;
        let Some(df) = self.data_fetches.get_mut(&root) else {
            return; // file fetch already cancelled or completed
        };
        if let Some(failed) = df.in_flight.remove(&chunk) {
            let tried = df.tried.entry(chunk).or_default();
            if !tried.contains(&failed) {
                tried.push(failed);
            }
        }
        let tried = df.tried.get(&chunk);
        let avail: Vec<PeerId> = df
            .providers
            .iter()
            .copied()
            .filter(|p| tried.map_or(true, |t| !t.contains(p)))
            .collect();
        if avail.is_empty() {
            // Every known provider failed this chunk: the file cannot
            // complete from here. Kill the fetch and its live siblings;
            // the anti-entropy sweep retries the whole root later.
            self.cancel_file_fetch(root);
            self.metrics.inc("fetch_failed");
            return;
        }
        let peer = match sched {
            ChunkScheduler::RoundRobin => {
                let p = avail[df.rr_next % avail.len()];
                df.rr_next = df.rr_next.wrapping_add(1);
                p
            }
            _ => pick_quality(quality, &avail, &df.in_flight),
        };
        df.in_flight.insert(chunk, peer);
        self.metrics.inc("transfer_reassignments");
        let mut sends = bitswap::Sends::new();
        let fid = self.bitswap.fetch(now, chunk, vec![peer], &mut sends);
        self.fetch_purpose.insert(fid, FetchPurpose::DataChunk { root });
        self.wrap_bitswap(sends, out);
    }

    /// Abandon a whole file fetch: drop the window bookkeeping AND
    /// cancel every live sibling block fetch in the bitswap engine.
    /// Without the sweep, siblings stay live until they independently
    /// exhaust, leaking `fetch_purpose` entries and spraying doomed
    /// retries in the meantime.
    fn cancel_file_fetch(&mut self, root: Cid) {
        self.data_fetches.remove(&root);
        self.repair_fetches.remove(&root);
        let mut doomed: Vec<FetchId> = self
            .fetch_purpose
            .iter()
            .filter(|(_, p)| match p {
                FetchPurpose::DataChunk { root: r } => *r == root,
                FetchPurpose::DataRoot { data_cid } => *data_cid == root,
                FetchPurpose::LogEntry => false,
            })
            .map(|(id, _)| *id)
            .collect();
        // `fetch_purpose` is a HashMap; cancel in FetchId order so the
        // sweep's side effects are reproducible.
        doomed.sort();
        for fid in doomed {
            self.fetch_purpose.remove(&fid);
            self.bitswap.cancel(fid);
            self.metrics.inc("sibling_fetches_cancelled");
        }
    }

    fn on_entry_fetched(
        &mut self,
        now: Nanos,
        cid: Cid,
        data: Blob,
        from: PeerId,
        out: &mut Outbox<Message>,
    ) {
        self.entry_fetches.remove(&cid);
        let Ok(entry) = crate::codec::from_bytes::<Entry>(&data) else {
            self.metrics.inc("entry_decode_failures");
            return;
        };
        // Store + pin the entry block so we can serve it onward. The
        // bitswap engine already verified the content against the CID,
        // so the store can adopt the wire allocation as-is.
        self.bs.put_trusted(cid, data);
        self.bs.pin(&cid, Pin::Replica);
        let parents = entry.next.clone();
        if self.contributions.join_entry(cid, entry) != Join::Added {
            return;
        }
        self.metrics.inc("entries_replicated");
        // Chase missing parents from the same source.
        for p in parents {
            if !self.contributions.contains_entry(&p) {
                self.fetch_entry(now, p, vec![from], out);
            }
        }
        // Interpret the payload as a contribution and replicate its data.
        if let Some(e) = self.contributions.entry(&cid) {
            if let Ok(c) = crate::codec::from_bytes::<Contribution>(&e.payload) {
                self.contribution_meta.insert(c.data_cid, (c.author, c.created_at));
                if self.cfg.auto_pin && !self.bs.has(&c.data_cid) {
                    self.incomplete_data.insert(c.data_cid, c.author);
                    let mut cands = vec![from];
                    if c.author != self.id && c.author != from {
                        cands.push(c.author);
                    }
                    self.fetch_data(now, c.data_cid, cands, out);
                } else if self.bs.has(&c.data_cid) {
                    self.finish_replication(now, c.data_cid, out);
                }
            }
        }
    }

    fn on_data_block_fetched(
        &mut self,
        now: Nanos,
        purpose: FetchPurpose,
        cid: Cid,
        data: Blob,
        from: PeerId,
        out: &mut Outbox<Message>,
    ) {
        // A block of a file this node deliberately dropped (the fetch
        // raced the unpin): store it unpinned — the next GC sweeps it —
        // and do not resume the file's replication.
        let root = match &purpose {
            FetchPurpose::DataRoot { data_cid } => *data_cid,
            FetchPurpose::DataChunk { root } => *root,
            FetchPurpose::LogEntry => unreachable!("routed in on_bitswap_event"),
        };
        if self.dropped.contains(&root) {
            self.bs.put_trusted(cid, data);
            return;
        }
        // Verified upstream by the bitswap engine; adopt the allocation.
        self.bs.put_trusted(cid, data);
        self.bs.pin(&cid, Pin::Replica);
        match purpose {
            FetchPurpose::DataRoot { data_cid } => {
                let provs = self.dedup_providers(&[from]);
                self.schedule_chunks(now, data_cid, provs, out);
            }
            FetchPurpose::DataChunk { root } => {
                let my_id = self.id;
                if let Some(df) = self.data_fetches.get_mut(&root) {
                    df.in_flight.remove(&cid);
                    df.tried.remove(&cid);
                    df.source = from;
                    // A peer serving chunks is a provider, whether or
                    // not the DHT has caught up with that fact.
                    if from != my_id && !df.providers.contains(&from) {
                        df.providers.push(from);
                    }
                }
                self.pump_chunks(now, root, out);
            }
            FetchPurpose::LogEntry => unreachable!("routed in on_bitswap_event"),
        }
    }

    /// A contribution's data is fully local: record metrics, serve it
    /// onward, start validation.
    fn finish_replication(&mut self, now: Nanos, data_cid: Cid, out: &mut Outbox<Message>) {
        self.incomplete_data.remove(&data_cid);
        let (author, created_at) = self
            .contribution_meta
            .remove(&data_cid)
            .unwrap_or((self.id, now.0));
        self.metrics.inc("contributions_replicated");
        let latency_ms = (now.0.saturating_sub(created_at)) as f64 / 1e6;
        self.metrics.observe("replication_ms", latency_ms);
        self.events.push(NodeEvent::ContributionReplicated {
            data_cid,
            author,
            created_at,
            completed_at: now,
        });
        // Repair-driven replicas announce *unconditionally*: the whole
        // point of re-replication is restoring the provider count, and a
        // copy the DHT cannot discover restores nothing. Ordinary
        // replicas keep the kubo-faithful batching default
        // (`announce_replicas: false` — anti-entropy covers discovery).
        let repair_driven = self.repair_fetches.remove(&data_cid);
        if repair_driven || (self.cfg.announce_providers && self.cfg.announce_replicas) {
            self.start_provide(now, Key::from_cid(&data_cid), out);
        }
        if self.cfg.auto_validate {
            self.begin_validation(now, data_cid, out);
        }
    }

    // ======================================================================
    // Availability repair (§III-B replication maintenance)
    //
    // Replication in the base protocol is fire-and-forget: data spreads
    // when entries arrive, and nothing ever notices that holders have
    // since unpinned, garbage-collected, or vanished. The repair loop
    // closes that gap. Every `repair_interval` it walks the known
    // contributions and probes the DHT for each one's provider count
    // (an exhaustive `GetProviders`, so the count does not saturate at
    // the fetch-oriented `providers_needed` early exit). When a count
    // has fallen below `replication_target`:
    //
    // * a node still holding the file re-announces its provider record
    //   (refreshing the TTL and repairing records lost to churn);
    // * a node not holding it volunteers to re-fetch and re-pin
    //   (`Pin::Replica`) from the surviving providers — damped by a
    //   seeded coin so the expected number of volunteers per cycle
    //   matches the deficit instead of the whole cluster stampeding;
    // * a node that *deliberately* dropped the file (unpin + GC) does
    //   neither: repair distinguishes "lost in flight" from "operator
    //   said no" and never resurrects removed data on the remover.
    // ======================================================================

    /// One repair cycle: launch provider-count probes for every known
    /// contribution that has neither a probe nor a re-fetch in flight.
    /// Deliberately dropped roots are skipped outright — this node can
    /// never act on their probes, so walking the DHT for them every
    /// cycle would be pure noise.
    fn run_repair(&mut self, now: Nanos, out: &mut Outbox<Message>) {
        let roots: Vec<Cid> = self.contributions.data_cids().iter().copied().collect();
        for cid in roots {
            if self.dropped.contains(&cid)
                || self.probing.contains(&cid)
                || self.data_fetches.contains_key(&cid)
            {
                continue;
            }
            self.metrics.inc("repair_probes");
            self.probing.insert(cid);
            let mut sends = dht::engine::Sends::new();
            let lid = self.dht.find_providers_full(now, Key::from_cid(&cid), &mut sends);
            self.repair_probes.insert(lid, cid);
            self.wrap_dht(sends, out);
        }
    }

    /// A provider-count probe finished: decide whether (and how) to
    /// repair `data_cid`.
    fn on_repair_probe(
        &mut self,
        now: Nanos,
        data_cid: Cid,
        providers: Vec<PeerId>,
        out: &mut Outbox<Message>,
    ) {
        // Every probe records how many providers the exhaustive DHT walk
        // actually returned. The eclipse scenarios read this trace: an
        // attack that forges records inflates the count rather than
        // zeroing it, so "never zero" documents that the availability
        // view degrades to attacker-poisoned — not dark — mid-attack.
        self.metrics.observe("repair_providers_found", providers.len() as f64);
        let target = self.cfg.replication_target.max(1);
        let holds = chunker::has_file(&self.bs, &data_cid);
        // Our own announce is stored on the key's closest peers like
        // anyone else's, so the reply normally counts us already; add
        // ourselves only when we hold unannounced (a lost record —
        // exactly what the re-announce below repairs).
        let mut count = providers.len();
        if holds && !providers.contains(&self.id) {
            count += 1;
        }
        if count >= target {
            return;
        }
        if holds {
            self.metrics.inc("repairs_triggered");
            self.metrics.inc("repair_reannounces");
            self.start_provide(now, Key::from_cid(&data_cid), out);
            return;
        }
        if self.dropped.contains(&data_cid) {
            return; // deliberately removed here — never resurrected here
        }
        let mut candidates = providers;
        candidates.retain(|p| *p != self.id);
        if candidates.is_empty() {
            return; // nobody left to fetch from; retry next cycle
        }
        // Damped volunteering: with ~`deficit` missing replicas and
        // every non-holder probing, accept with deficit/peers so the
        // expected volunteers per cycle ≈ the deficit. The floor keeps
        // sparse tables from stalling repair indefinitely.
        let peers = self.dht.table.peers().len().max(1);
        let chance = ((target - count) as f64 / peers as f64).clamp(0.15, 1.0);
        if !self.rng.chance(chance) {
            return;
        }
        self.metrics.inc("repairs_triggered");
        self.metrics.inc("repair_refetches");
        if !self.contribution_meta.contains_key(&data_cid) {
            if let Some(c) =
                self.contributions.iter().into_iter().find(|c| c.data_cid == data_cid)
            {
                self.contribution_meta.insert(data_cid, (c.author, c.created_at));
            }
        }
        self.repair_fetches.insert(data_cid);
        self.fetch_data(now, data_cid, candidates, out);
    }

    // ======================================================================
    // Validation (§III-C)
    // ======================================================================

    fn begin_validation(&mut self, now: Nanos, data_cid: Cid, out: &mut Outbox<Message>) {
        if self.validations.get(&data_cid).is_some() || self.votes.contains_key(&data_cid) {
            return;
        }
        self.validation_started.entry(data_cid).or_insert(now);
        // Opportunistic: ask the network first.
        let mut candidates: Vec<PeerId> = self.pubsub.neighbors().iter().copied().collect();
        if candidates.is_empty() {
            candidates = self.dht.table.peers();
        }
        candidates.retain(|p| *p != self.id);
        self.rng.shuffle(&mut candidates);
        candidates.truncate(self.cfg.quorum.fanout);
        if candidates.is_empty() {
            self.enqueue_local_validation(now, data_cid, out);
            return;
        }
        let vote = VoteState::new(now, candidates.clone());
        for peer in candidates {
            let req_id = self.fresh_req();
            self.val_req_index.insert(req_id, data_cid);
            out.send(peer, Message::ValQuery { req_id, cid: data_cid });
        }
        self.metrics.inc("validation_votes_started");
        self.votes.insert(data_cid, vote);
    }

    fn enqueue_local_validation(&mut self, now: Nanos, data_cid: Cid, out: &mut Outbox<Message>) {
        let size = self
            .bs
            .get(&data_cid)
            .map(|d| d.len() as u64)
            .unwrap_or(0);
        self.batch_queue.enqueue(Task { data_cid, size_bytes: size });
        self.last_enqueue = now;
        self.metrics.inc("local_validations_enqueued");
        self.maybe_start_batch(now, false, out);
    }

    fn maybe_start_batch(&mut self, now: Nanos, force: bool, out: &mut Outbox<Message>) {
        while let Some((batch_id, delay)) =
            self.batch_queue.maybe_start(now, &self.cfg.cost_model, force)
        {
            // The async background task: completion arrives as a timer.
            out.timer(token::pack(token::VALIDATION, batch_id), delay);
            if force {
                break;
            }
        }
    }

    fn on_validation_batch_done(&mut self, now: Nanos, batch_id: u64, out: &mut Outbox<Message>) {
        let Some((tasks, started)) = self.batch_queue.complete(batch_id) else {
            return;
        };
        let cost_ns = now.0.saturating_sub(started.0);
        for t in tasks {
            let data = chunker::get_file(&self.bs, &t.data_cid).unwrap_or_default();
            let (verdict, score) = self.validator.validate(&data);
            self.store_verdict(now, t.data_cid, verdict, score, cost_ns, ValidationSource::Local);
        }
        // Blocking ablation: release parked validation queries.
        if self.batch_queue.in_flight_len() == 0 {
            for (peer, req_id, cid) in std::mem::take(&mut self.deferred_val_replies) {
                let record = self.validations.get(&cid).cloned();
                self.metrics.inc("val_queries_served");
                out.send(peer, Message::ValReply { req_id, cid, record });
            }
        }
        // More work may be waiting.
        self.maybe_start_batch(now, false, out);
    }

    fn store_verdict(
        &mut self,
        now: Nanos,
        data_cid: Cid,
        verdict: Verdict,
        score: f64,
        cost_ns: u64,
        source: ValidationSource,
    ) {
        self.validations.put(ValidationRecord {
            data_cid,
            verdict,
            score,
            validator: self.id,
            validated_at: now.0,
            cost_ns,
        });
        self.metrics.inc(match source {
            ValidationSource::Local => "validations_local",
            ValidationSource::Network => "validations_network",
        });
        match source {
            ValidationSource::Local => {
                self.network_verdicts.remove(&data_cid);
            }
            ValidationSource::Network => {
                self.network_verdicts.insert(data_cid);
            }
        }
        self.metrics
            .observe("validation_cost_ms", cost_ns as f64 / 1e6);
        if let Some(started) = self.validation_started.remove(&data_cid) {
            self.metrics
                .observe("verdict_latency_ms", now.saturating_sub(started).as_millis_f64());
        }
        self.events.push(NodeEvent::ValidationDone { data_cid, verdict, score, source });
    }

    fn on_val_reply(
        &mut self,
        now: Nanos,
        from: PeerId,
        req_id: u64,
        cid: Cid,
        record: Option<ValidationRecord>,
        out: &mut Outbox<Message>,
    ) {
        if self.val_req_index.remove(&req_id).is_none() {
            return;
        }
        let Some(vote) = self.votes.get_mut(&cid) else { return };
        vote.record(from, record.map(|r| (r.verdict, r.score)));
        if let Some(outcome) = vote.tally(&self.cfg.quorum, false) {
            if vote.is_extended() {
                // A late reply completed the quorum inside the grace
                // window — exactly what the extension exists for.
                self.metrics.inc("votes_rescued_by_grace");
            }
            self.votes.remove(&cid);
            match outcome {
                VoteOutcome::Decided { verdict, mean_score, .. } => {
                    self.store_verdict(now, cid, verdict, mean_score, 0, ValidationSource::Network);
                }
                VoteOutcome::Inconclusive { .. } => {
                    self.enqueue_local_validation(now, cid, out);
                }
            }
        }
    }

    fn expire_votes(&mut self, now: Nanos, out: &mut Outbox<Message>) {
        let timeout = self.cfg.quorum.timeout;
        let grace = self.cfg.quorum.timeout_grace;
        let expired: Vec<Cid> = self
            .votes
            .iter()
            .filter(|(_, v)| {
                let deadline = if v.is_extended() { timeout + grace } else { timeout };
                now.saturating_sub(v.started_at) >= deadline
            })
            .map(|(c, _)| *c)
            .collect();
        for cid in expired {
            // Grace extension: a vote that timed out short of its quorum
            // while asked peers are still outstanding gets one more
            // window before the force tally — their verdicts may merely
            // be *late* (slow links), not lost, and adopting whatever
            // the prompt subset of the sample said is exactly the delay
            // attack `timeout_grace` exists to close.
            if grace > Duration::ZERO {
                let vote = self.votes.get_mut(&cid).unwrap();
                if !vote.is_extended()
                    && vote.verdict_count() < self.cfg.quorum.responses_needed
                    && vote.outstanding() > 0
                {
                    vote.mark_extended();
                    self.metrics.inc("votes_extended");
                    continue;
                }
            }
            let vote = self.votes.remove(&cid).unwrap();
            self.metrics.inc("votes_forced");
            let outcome = vote.tally(&self.cfg.quorum, true);
            if vote.is_extended()
                && matches!(outcome, Some(VoteOutcome::Inconclusive { .. }))
                && matches!(
                    vote.forced_outcome_at_legacy_floor(&self.cfg.quorum),
                    Some(VoteOutcome::Decided { .. })
                )
            {
                // The stricter extended floor blocked a verdict the
                // legacy timeout tally would have adopted from the
                // prompt (attacker-majority) subset — a rescue, degraded
                // to local validation instead of a swallowed lie.
                self.metrics.inc("votes_rescued_by_grace");
            }
            match outcome {
                Some(VoteOutcome::Decided { verdict, mean_score, .. }) => {
                    self.store_verdict(now, cid, verdict, mean_score, 0, ValidationSource::Network);
                }
                _ => self.enqueue_local_validation(now, cid, out),
            }
        }
    }

    /// Whether this node's verdict for `cid` (if any) was adopted from
    /// the network rather than computed locally.
    pub fn network_adopted(&self, cid: &Cid) -> bool {
        self.network_verdicts.contains(cid)
    }

    // ======================================================================
    // Event draining from sub-engines
    // ======================================================================

    fn drain_engines(&mut self, now: Nanos, out: &mut Outbox<Message>) {
        // DHT events.
        let dht_events: Vec<DhtEvent> = self.dht.events.drain(..).collect();
        for ev in dht_events {
            match ev {
                DhtEvent::LookupDone { id, target, closest } => {
                    if self.bootstrap_lookup == Some(id) {
                        self.bootstrap_lookup = None;
                        if let Bootstrap::Syncing { started, .. } = self.bootstrap {
                            self.bootstrap = Bootstrap::Syncing { started, lookup_done: true };
                        }
                    }
                    if let Some(key) = self.provide_lookups.remove(&id) {
                        let mut sends = dht::engine::Sends::new();
                        self.dht.announce_provider(key, &closest, &mut sends);
                        self.wrap_dht(sends, out);
                    }
                    if let Some(key) = self.withdraw_lookups.remove(&id) {
                        let mut sends = dht::engine::Sends::new();
                        self.dht.announce_withdrawal(key, &closest, &mut sends);
                        self.wrap_dht(sends, out);
                    }
                    let _ = target;
                }
                DhtEvent::ProvidersDone { id, key, providers, .. } => {
                    if let Some(cid) = self.repair_probes.remove(&id) {
                        self.probing.remove(&cid);
                        self.on_repair_probe(now, cid, providers, out);
                    } else if let Some(root) = self.stripe_lookups.remove(&id) {
                        self.on_stripe_providers(now, root, providers, out);
                    } else if let Some((cid, fetch)) = self.provider_lookups.remove(&id) {
                        debug_assert_eq!(Key::from_cid(&cid).0, key.0);
                        if providers.is_empty() {
                            self.metrics.inc("provider_lookup_empty");
                            // A failed chunk kills the whole file fetch
                            // — including its still-live sibling chunk
                            // fetches; the anti-entropy sweep will
                            // retry the root.
                            if let Some(FetchPurpose::DataChunk { root }) =
                                self.retry_purposes.remove(&cid)
                            {
                                self.cancel_file_fetch(root);
                            }
                            self.fetch_failed(cid, fetch);
                        } else {
                            match self.purpose_for_retry(cid) {
                                FetchPurpose::DataChunk { root }
                                    if !self.data_fetches.contains_key(&root) =>
                                {
                                    // The file fetch this chunk served is
                                    // gone (cancelled or completed): a
                                    // retry would orphan the chunk.
                                    self.metrics.inc("orphan_chunk_lookups_dropped");
                                }
                                FetchPurpose::DataRoot { data_cid }
                                    if self.bs.has(&data_cid) =>
                                {
                                    // Root already local (the fetch was
                                    // parked on this lookup): schedule
                                    // chunks straight from the
                                    // discovered providers.
                                    let provs = self.dedup_providers(&providers);
                                    if provs.is_empty() {
                                        self.fetch_failed(data_cid, fetch);
                                    } else {
                                        self.schedule_chunks(now, data_cid, provs, out);
                                    }
                                }
                                purpose => {
                                    let mut sends = bitswap::Sends::new();
                                    let is_entry =
                                        matches!(purpose, FetchPurpose::LogEntry);
                                    let fid =
                                        self.bitswap.fetch(now, cid, providers, &mut sends);
                                    self.fetch_purpose.insert(fid, purpose);
                                    if is_entry {
                                        self.entry_fetches.insert(cid, fid);
                                    }
                                    self.wrap_bitswap(sends, out);
                                }
                            }
                        }
                    }
                }
            }
        }
        // Per-request outcomes feed the peer-quality table. Pure local
        // bookkeeping — no RNG, no sends — so draining unconditionally
        // (even with the striping knob off) cannot perturb replay.
        for o in std::mem::take(&mut self.bitswap.outcomes) {
            match o {
                Outcome::Block { peer, latency } => {
                    self.quality.observe_block(peer, latency.as_millis_f64())
                }
                Outcome::DontHave { peer } => self.quality.observe_dont_have(peer),
                Outcome::Timeout { peer } => self.quality.observe_timeout(peer),
            }
        }
        // Bitswap events.
        let bs_events: Vec<BitswapEvent> = self.bitswap.events.drain(..).collect();
        for ev in bs_events {
            match ev {
                BitswapEvent::Fetched { id, cid, data, from } => {
                    self.dht.table.touch(from, now);
                    match self.fetch_purpose.remove(&id) {
                        Some(FetchPurpose::LogEntry) | None => {
                            self.on_entry_fetched(now, cid, data, from, out)
                        }
                        Some(p) => self.on_data_block_fetched(now, p, cid, data, from, out),
                    }
                }
                BitswapEvent::Exhausted { id, cid } => {
                    let purpose = self.fetch_purpose.remove(&id);
                    if self.cfg.chunk_scheduler != ChunkScheduler::Single {
                        if let Some(FetchPurpose::DataChunk { root }) = &purpose {
                            // Striped modes reassign within the known
                            // provider set instead of asking the DHT:
                            // chunk keys are never announced (only file
                            // roots are), so a chunk lookup can only
                            // ever come back empty.
                            self.on_chunk_exhausted(now, *root, cid, out);
                            continue;
                        }
                    }
                    // Last resort: look up providers in the DHT. Clear the
                    // in-flight marker so later announcements/anti-entropy
                    // can retry the fetch independently.
                    self.entry_fetches.remove(&cid);
                    self.metrics.inc("fetch_exhausted");
                    let key = Key::from_cid(&cid);
                    let mut sends = dht::engine::Sends::new();
                    let lid = self.dht.find_providers(now, key, &mut sends);
                    self.provider_lookups.insert(lid, (cid, Some(id)));
                    // Remember intent for the retry.
                    if let Some(p) = purpose {
                        self.retry_purposes.insert(cid, p);
                    }
                    self.wrap_dht(sends, out);
                }
            }
        }
        // Pubsub deliveries: heads announcements.
        let deliveries: Vec<pubsub::Delivery> = self.pubsub.deliveries.drain(..).collect();
        for d in deliveries {
            if d.topic != self.topic {
                continue;
            }
            if let Ok(heads) = crate::codec::from_bytes::<Vec<Cid>>(&d.data) {
                for h in heads {
                    if !self.contributions.contains_entry(&h) {
                        self.fetch_entry(now, h, vec![d.origin], out);
                    }
                }
            }
        }
        // Nested engine work may have produced more events.
        if !self.dht.events.is_empty()
            || !self.bitswap.events.is_empty()
            || !self.bitswap.outcomes.is_empty()
            || !self.pubsub.deliveries.is_empty()
        {
            self.drain_engines(now, out);
        }
    }

    fn purpose_for_retry(&mut self, cid: Cid) -> FetchPurpose {
        self.retry_purposes
            .remove(&cid)
            .unwrap_or(FetchPurpose::LogEntry)
    }

    fn fetch_failed(&mut self, cid: Cid, _fetch: Option<FetchId>) {
        self.entry_fetches.remove(&cid);
        self.data_fetches.remove(&cid);
        // A dead repair fetch loses its announce-unconditionally mark:
        // the next repair cycle re-volunteers (and re-marks) if the file
        // is still under-replicated, and an *ordinary* replication that
        // completes later must not inherit the repair announce.
        self.repair_fetches.remove(&cid);
        self.metrics.inc("fetch_failed");
    }

    /// Anti-entropy sweep: retry log entries referenced but absent
    /// (failed parent fetches) and data files that never completed.
    fn retry_missing_data(&mut self, now: Nanos, out: &mut Outbox<Message>) {
        // Missing log parents: re-fetch from a random peer (heads-based
        // anti-entropy only covers heads, not interior gaps).
        let missing_entries: Vec<Cid> = self
            .contributions
            .missing()
            .into_iter()
            .filter(|c| !self.entry_fetches.contains_key(c))
            .collect();
        if !missing_entries.is_empty() {
            let peers = self.dht.table.peers();
            for cid in missing_entries {
                let mut cands = Vec::new();
                if let Some(p) = self.rng.choose(&peers) {
                    cands.push(*p);
                }
                if let Some(p) = self.rng.choose(&peers) {
                    if !cands.contains(p) {
                        cands.push(*p);
                    }
                }
                self.metrics.inc("entry_refetches");
                self.fetch_entry(now, cid, cands, out);
            }
        }
        if !self.cfg.auto_pin {
            return;
        }
        let missing: Vec<(Cid, PeerId)> = self
            .incomplete_data
            .iter()
            .filter(|(cid, author)| {
                **author != self.id && !self.data_fetches.contains_key(*cid)
            })
            .map(|(cid, author)| (*cid, *author))
            .collect();
        for (cid, author) in missing {
            let mut cands = vec![author];
            let peers = self.dht.table.peers();
            if let Some(extra) = self.rng.choose(&peers) {
                if *extra != author && *extra != self.id {
                    cands.push(*extra);
                }
            }
            self.contribution_meta.entry(cid).or_insert((author, now.0));
            self.metrics.inc("data_refetches");
            self.fetch_data(now, cid, cands, out);
        }
    }

    fn refresh_neighbors(&mut self, out: &mut Outbox<Message>) {
        let mut peers = self.dht.table.peers();
        self.rng.shuffle(&mut peers);
        peers.truncate(self.cfg.neighbor_degree);
        if let Some(b) = self.cfg.bootstrap {
            if !peers.contains(&b) {
                peers.push(b);
            }
        }
        let mut sends = pubsub::Sends::new();
        self.pubsub.set_neighbors(peers, &mut sends);
        self.wrap_pubsub(sends, out);
    }

    /// Roots of data fetches that owe chunks but have NO forward driver:
    /// no live bitswap fetch referencing the file, no provider / stripe
    /// lookup in flight for it, nothing that will ever issue another
    /// request. The sim's stall invariant asserts no such fetch exists
    /// while another live node still holds the data — a fetch must
    /// either be making progress or have been abandoned outright.
    pub fn stalled_data_fetches(&self) -> Vec<Cid> {
        fn refs(p: &FetchPurpose, root: &Cid) -> bool {
            match p {
                FetchPurpose::DataChunk { root: r } => r == root,
                FetchPurpose::DataRoot { data_cid } => data_cid == root,
                FetchPurpose::LogEntry => false,
            }
        }
        let mut stalled: Vec<Cid> = Vec::new();
        for (root, df) in &self.data_fetches {
            if df.pending.is_empty() && df.in_flight.is_empty() {
                // Placeholder (root fetch or provider lookup running);
                // nothing owed yet.
                continue;
            }
            let driven = self.fetch_purpose.values().any(|p| refs(p, root))
                || self.provider_lookups.values().any(|(c, _)| {
                    c == root
                        || self.retry_purposes.get(c).map_or(false, |p| refs(p, root))
                })
                || self.stripe_lookups.values().any(|r| r == root);
            if !driven {
                stalled.push(*root);
            }
        }
        stalled.sort();
        stalled
    }

    /// Number of live fetch-purpose entries (leak diagnostics).
    pub fn fetch_purposes_len(&self) -> usize {
        self.fetch_purpose.len()
    }

    /// Number of active bitswap fetch sessions (leak diagnostics).
    pub fn bitswap_active_fetches(&self) -> usize {
        self.bitswap.active_fetches()
    }

    /// Live bitswap request-index entries (leak diagnostics).
    pub fn bitswap_req_index_len(&self) -> usize {
        self.bitswap.req_index_len()
    }

    fn check_bootstrap_done(&mut self, now: Nanos) {
        if let Bootstrap::Syncing { started, lookup_done } = self.bootstrap {
            if lookup_done
                && self.contributions.log().missing_is_empty()
                && self.entry_fetches.is_empty()
                && self.data_fetches.is_empty()
            {
                self.bootstrap = Bootstrap::Done;
                let dur_ms = (now.0 - started.0) as f64 / 1e6;
                self.metrics.observe("bootstrap_ms", dur_ms);
                self.events.push(NodeEvent::BootstrapDone {
                    started,
                    completed: now,
                    entries_synced: self.contributions.len(),
                });
            }
        }
    }
}

impl Runner for Node {
    type Msg = Message;

    fn id(&self) -> PeerId {
        self.id
    }

    fn on_start(&mut self, now: Nanos, out: &mut Outbox<Message>) {
        out.timer(token::pack(token::PEERSDB, TICK), self.cfg.tick_interval);
        // Subscribe to the store topic.
        let mut ps = pubsub::Sends::new();
        self.pubsub.subscribe(self.topic, &mut ps);
        self.wrap_pubsub(ps, out);
        match self.cfg.bootstrap {
            Some(root) => {
                self.bootstrap = Bootstrap::Joining { started: now };
                out.send(root, Message::Join { passphrase: self.gate.presentation() });
            }
            None => {
                self.bootstrap = Bootstrap::Root;
            }
        }
    }

    fn on_message(&mut self, now: Nanos, from: PeerId, msg: Message, out: &mut Outbox<Message>) {
        match msg {
            Message::Dht(rpc) => {
                let mut sends = dht::engine::Sends::new();
                self.dht.on_rpc(now, from, rpc, &mut sends);
                self.wrap_dht(sends, out);
            }
            Message::Bitswap(bitswap::Msg::Want { req_id, cid }) => {
                // Server side: access-controlled blockstore read. The
                // reply carries the stored allocation by refcount — no
                // payload copy between store and wire.
                match self.bs.get_public_blob(&cid) {
                    Some(data) => {
                        self.metrics.inc("blocks_served");
                        self.metrics.add("bytes_served", data.len() as u64);
                        out.send(from, Message::Bitswap(bitswap::Msg::Block { req_id, cid, data }));
                    }
                    None => {
                        if self.bs.has(&cid) {
                            // Present but private: the §III-B middleware.
                            self.metrics.inc("private_denied");
                            self.events.push(NodeEvent::PrivateDenied { cid, peer: from });
                        }
                        out.send(from, Message::Bitswap(bitswap::Msg::DontHave { req_id, cid }));
                    }
                }
            }
            Message::Bitswap(m) => {
                let mut sends = bitswap::Sends::new();
                self.bitswap.on_msg(now, from, m, &mut sends);
                self.wrap_bitswap(sends, out);
            }
            Message::Pubsub(m) => {
                let mut sends = pubsub::Sends::new();
                self.pubsub.on_msg(now, from, m, &mut sends);
                self.wrap_pubsub(sends, out);
            }
            Message::Join { passphrase } => {
                let accepted = self.gate.check(&passphrase);
                self.metrics.inc(if accepted { "joins_accepted" } else { "joins_rejected" });
                let (peers, heads) = if accepted {
                    self.dht.add_seed(now, from);
                    let mut sample = self.dht.table.closest(&Key::from_peer(from), 16);
                    sample.retain(|p| *p != from);
                    (sample, self.contributions.heads())
                } else {
                    (Vec::new(), Vec::new())
                };
                out.send(from, Message::JoinAck { accepted, peers, heads });
                if accepted {
                    // Tell the joiner our subscriptions right away so it
                    // can flood announcements to us without waiting a tick.
                    self.refresh_neighbors(out);
                }
            }
            Message::JoinAck { accepted, peers, heads } => {
                if !accepted {
                    self.metrics.inc("join_rejected_by_root");
                    return;
                }
                // Under peer verification, an unsolicited ack — from
                // anyone but the bootstrap peer we actually joined
                // through — is a one-message table-stuffing channel and
                // is refused outright. (Gated on `verify_peers` so
                // pre-hardening schedules replay bit-identically.)
                if self.cfg.dht.verify_peers && Some(from) != self.cfg.bootstrap {
                    self.metrics.inc("join_acks_refused");
                    return;
                }
                let started = match self.bootstrap {
                    Bootstrap::Joining { started } => started,
                    _ => now,
                };
                self.bootstrap = Bootstrap::Syncing { started, lookup_done: false };
                self.dht.add_seed(now, from);
                // The sample list is the root's hearsay: seeded directly
                // in the classic configuration, quarantined for a
                // verification ping under `verify_peers`.
                for p in peers {
                    self.dht.add_hearsay(now, p);
                }
                // Populate the table around our own id.
                let mut sends = dht::engine::Sends::new();
                let lid = self.dht.find_node(now, Key::from_peer(self.id), &mut sends);
                self.bootstrap_lookup = Some(lid);
                self.wrap_dht(sends, out);
                self.refresh_neighbors(out);
                // Sync the store from the root's heads.
                for h in heads {
                    self.fetch_entry(now, h, vec![from], out);
                }
                self.check_bootstrap_done(now);
            }
            Message::HeadsRequest => {
                out.send(from, Message::HeadsReply { heads: self.contributions.heads() });
            }
            Message::HeadsReply { heads } => {
                for h in heads {
                    if !self.contributions.contains_entry(&h) {
                        self.fetch_entry(now, h, vec![from], out);
                    }
                }
            }
            Message::ValQuery { req_id, cid } => {
                if self.cfg.blocking_validation && self.batch_queue.in_flight_len() > 0 {
                    // Ablation: the blocking design parks the query until
                    // current validation work completes.
                    self.deferred_val_replies.push((from, req_id, cid));
                    self.metrics.inc("val_queries_deferred");
                } else {
                    // Answer immediately from the validations store — the
                    // paper's learning: never block on in-flight validations.
                    let record = self.validations.get(&cid).cloned();
                    self.metrics.inc("val_queries_served");
                    out.send(from, Message::ValReply { req_id, cid, record });
                }
            }
            Message::ValReply { req_id, cid, record } => {
                self.metrics.inc("val_replies_received");
                self.on_val_reply(now, from, req_id, cid, record, out);
            }
        }
        self.drain_engines(now, out);
        self.check_bootstrap_done(now);
    }

    fn on_timer(&mut self, now: Nanos, tok: u64, out: &mut Outbox<Message>) {
        match token::proto(tok) {
            token::PEERSDB => {
                // The periodic service tick.
                out.timer(token::pack(token::PEERSDB, TICK), self.cfg.tick_interval);
                let mut dht_sends = dht::engine::Sends::new();
                self.dht.tick(now, &mut dht_sends);
                self.wrap_dht(dht_sends, out);
                let mut bs_sends = bitswap::Sends::new();
                self.bitswap.tick(now, &mut bs_sends);
                self.wrap_bitswap(bs_sends, out);
                // Flood mode: seen-cache expiry only, never a send. Mesh
                // mode: this also drives the gossip heartbeat (mesh
                // repair, IHAVE batching, cache rotation).
                let mut ps_sends = pubsub::Sends::new();
                self.pubsub.tick(now, &mut ps_sends);
                self.wrap_pubsub(ps_sends, out);
                // Neighbor resampling is an O(table) shuffle + gossip —
                // once a second is plenty (ticks are 100 ms).
                if self.tick_count % 10 == 0 {
                    self.refresh_neighbors(out);
                }
                self.expire_votes(now, out);
                // Join-handshake retry: the initial Join (or its Ack) may
                // be lost on an unreliable network.
                if let (Bootstrap::Joining { started }, Some(root)) =
                    (self.bootstrap, self.cfg.bootstrap)
                {
                    if now.saturating_sub(started) >= Duration::from_secs(2) {
                        self.bootstrap = Bootstrap::Joining { started: now };
                        self.metrics.inc("join_retries");
                        out.send(root, Message::Join { passphrase: self.gate.presentation() });
                    }
                }
                // Periodic anti-entropy heads exchange.
                self.tick_count = self.tick_count.wrapping_add(1);
                let every = self.cfg.anti_entropy_every_ticks;
                if every > 0 && self.tick_count % every == 0 {
                    let peers = self.dht.table.peers();
                    if let Some(peer) = self.rng.choose(&peers) {
                        out.send(*peer, Message::HeadsRequest);
                        self.metrics.inc("anti_entropy_syncs");
                    }
                    self.retry_missing_data(now, out);
                    // Quality-table sweep rides the same cadence: pure
                    // bookkeeping (no sends, no RNG), so it is
                    // replay-inert for every recorded schedule.
                    self.prune_quality();
                }
                // Availability repair: probe provider counts and mend
                // under-replication (no-op until bootstrapped — a
                // half-synced store would probe a half-known world).
                if self.repair_active()
                    && self.is_bootstrapped()
                    && now.saturating_sub(self.last_repair) >= self.cfg.repair_interval
                {
                    self.last_repair = now;
                    self.run_repair(now, out);
                }
                // Flush stale partial validation batches.
                if self.batch_queue.pending_len() > 0
                    && now.saturating_sub(self.last_enqueue) >= self.cfg.batch_flush
                {
                    self.maybe_start_batch(now, true, out);
                }
                self.drain_engines(now, out);
                self.check_bootstrap_done(now);
            }
            token::VALIDATION => {
                let batch_id = token::inner(tok);
                self.on_validation_batch_done(now, batch_id, out);
            }
            _ => {}
        }
    }

    fn processing_cost(&self, msg: &Message) -> Duration {
        let kb = crate::net::WireSize::wire_size(msg) as u64 / 1024;
        self.cfg.proc_cost_per_msg + Duration(self.cfg.proc_cost_per_kb.0 * kb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pid(n: u64) -> PeerId {
        let mut rng = Rng::new(n);
        PeerId::from_rng(&mut rng)
    }

    #[test]
    fn repair_phase_defaults_to_zero() {
        // Jitter off (the default) must leave `last_repair` at the
        // epoch — bit-identical to every pre-jitter recording.
        let node = Node::new(pid(1), NodeConfig::default(), 7);
        assert_eq!(node.last_repair, Nanos::ZERO);
        // Jitter with no repair interval is also a no-op (span 0).
        let cfg = NodeConfig { repair_jitter: 0.5, ..NodeConfig::default() };
        let node = Node::new(pid(1), cfg, 7);
        assert_eq!(node.last_repair, Nanos::ZERO);
    }

    #[test]
    fn repair_phase_is_deterministic_and_spread() {
        let cfg = NodeConfig {
            repair_interval: Duration::from_secs(60),
            repair_jitter: 0.5,
            ..NodeConfig::default()
        };
        let span = (cfg.repair_interval.0 as f64 * cfg.repair_jitter) as u64;
        let a = Node::new(pid(1), cfg.clone(), 7);
        let a2 = Node::new(pid(1), cfg.clone(), 999);
        let b = Node::new(pid(2), cfg.clone(), 7);
        // Pure function of the peer id: seed-independent, id-sensitive.
        assert_eq!(a.last_repair, a2.last_repair, "phase must not consume the RNG");
        assert_ne!(a.last_repair, b.last_repair, "distinct ids spread phases");
        for n in [&a, &b] {
            assert!(n.last_repair.0 < span, "phase {} outside span {span}", n.last_repair.0);
        }
    }

    #[test]
    fn prune_quality_keeps_routing_table_and_fetch_peers() {
        let mut node = Node::new(pid(1), NodeConfig::default(), 7);
        let (routed, provider, departed) = (pid(2), pid(3), pid(4));
        node.dht.table.touch(routed, Nanos::ZERO);
        let mut fetch = DataFetch::new(provider);
        fetch.providers.push(provider);
        let root = crate::cid::Cid::of_raw(b"root");
        node.data_fetches.insert(root, fetch);
        node.quality.observe_block(routed, 10.0);
        node.quality.observe_block(provider, 20.0);
        node.quality.observe_block(departed, 30.0);
        assert_eq!(node.quality.len(), 3);
        node.prune_quality();
        assert_eq!(node.quality.len(), 2, "only the departed peer is dropped");
        assert_eq!(node.quality.cost(&routed), 10.0);
        assert_eq!(node.quality.cost(&provider), 20.0);
    }
}

//! Content-addressed block storage.
//!
//! Every peer runs its own blockstore (the paper: "each peer runs its own
//! instance of IPFS for data storage"). Blocks are immutable byte strings
//! keyed by [`Cid`]; large files are split by the [`chunker`] into a chunk
//! list + manifest so that transfers can be pipelined block-wise. Pinning
//! protects replicated data from garbage collection and marks it for
//! serving to other peers (§III-D: "marked as qualifying for IPFS
//! pinning").

pub mod chunker;

use crate::cid::{Cid, Codec};
use crate::util::{Blob, FxHashMap};
use std::collections::hash_map::Entry;
use std::collections::BTreeSet;

/// Why a block is pinned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pin {
    /// Added locally by the user (never collected).
    Local,
    /// Replicated from the network and pinned for re-serving.
    Replica,
}

#[derive(Clone, Debug)]
struct BlockMeta {
    /// Shared with every protocol layer currently holding this block —
    /// see [`crate::util::bytes`] for the ownership model.
    data: Blob,
    pin: Option<Pin>,
    /// True if the block must not be served to remote peers (§III-B
    /// "a middleware can be employed that denies external CID requests").
    private: bool,
}

/// In-memory content-addressed store with pinning and privacy flags.
///
/// Durability is out of scope for the reproduction (the paper's
/// experiments are likewise on ephemeral pods); the interface mirrors what
/// a disk-backed implementation would expose.
#[derive(Default)]
pub struct BlockStore {
    blocks: FxHashMap<Cid, BlockMeta>,
    bytes_stored: usize,
}

impl BlockStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Single-lookup deduplicating insert shared by every `put` flavor.
    fn insert_new(&mut self, cid: Cid, data: Blob) {
        if let Entry::Vacant(slot) = self.blocks.entry(cid) {
            self.bytes_stored += data.len();
            slot.insert(BlockMeta {
                data,
                pin: None,
                private: false,
            });
        }
    }

    /// Insert a block, returning its CID. Idempotent (deduplicating).
    /// The content is hashed exactly once, by `Cid::of`.
    pub fn put(&mut self, codec: Codec, data: impl Into<Blob>) -> Cid {
        let data = data.into();
        let cid = Cid::of(codec, &data);
        self.insert_new(cid, data);
        cid
    }

    /// Insert a block under a claimed CID, verifying the content against
    /// it. Returns `false` (and stores nothing) if verification fails.
    pub fn put_verified(&mut self, cid: Cid, data: impl Into<Blob>) -> bool {
        let data = data.into();
        if !cid.verifies(&data) {
            return false;
        }
        self.insert_new(cid, data);
        true
    }

    /// Insert a block whose content the *caller* has already verified
    /// against `cid` (the bitswap engine checks every received block
    /// before surfacing it). Skips the redundant re-hash so a fetched
    /// block is hashed once per transfer, not twice.
    pub fn put_trusted(&mut self, cid: Cid, data: Blob) {
        debug_assert!(cid.verifies(&data), "put_trusted with unverified content");
        self.insert_new(cid, data);
    }

    pub fn get(&self, cid: &Cid) -> Option<&[u8]> {
        self.blocks.get(cid).map(|b| &b.data[..])
    }

    /// Refcounted handle to a block's bytes (O(1), no copy).
    pub fn get_blob(&self, cid: &Cid) -> Option<Blob> {
        self.blocks.get(cid).map(|b| b.data.clone())
    }

    pub fn has(&self, cid: &Cid) -> bool {
        self.blocks.contains_key(cid)
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn bytes_stored(&self) -> usize {
        self.bytes_stored
    }

    // ----- pinning -------------------------------------------------------

    pub fn pin(&mut self, cid: &Cid, pin: Pin) -> bool {
        if let Some(b) = self.blocks.get_mut(cid) {
            // Local pins are stronger than replica pins.
            if b.pin != Some(Pin::Local) {
                b.pin = Some(pin);
            }
            true
        } else {
            false
        }
    }

    /// Remove the pin (any class) from a block. Returns `true` if the
    /// block existed *and* carried a pin — i.e. whether the next
    /// [`BlockStore::gc`] now collects something it previously kept.
    pub fn unpin(&mut self, cid: &Cid) -> bool {
        if let Some(b) = self.blocks.get_mut(cid) {
            b.pin.take().is_some()
        } else {
            false
        }
    }

    pub fn pin_of(&self, cid: &Cid) -> Option<Pin> {
        self.blocks.get(cid).and_then(|b| b.pin)
    }

    /// All pinned CIDs (these are what we announce as provider records).
    pub fn pinned(&self) -> BTreeSet<Cid> {
        self.blocks
            .iter()
            .filter(|(_, b)| b.pin.is_some())
            .map(|(c, _)| *c)
            .collect()
    }

    /// Drop all unpinned blocks; returns (blocks, bytes) collected.
    pub fn gc(&mut self) -> (usize, usize) {
        let before_blocks = self.blocks.len();
        let before_bytes = self.bytes_stored();
        self.blocks.retain(|_, b| b.pin.is_some());
        self.bytes_stored = self.blocks.values().map(|b| b.data.len()).sum();
        (
            before_blocks - self.blocks.len(),
            before_bytes - self.bytes_stored,
        )
    }

    // ----- privacy ---------------------------------------------------------

    /// Mark a block as private: stored locally, never served remotely.
    pub fn set_private(&mut self, cid: &Cid, private: bool) -> bool {
        if let Some(b) = self.blocks.get_mut(cid) {
            b.private = private;
            true
        } else {
            false
        }
    }

    pub fn is_private(&self, cid: &Cid) -> bool {
        self.blocks.get(cid).map(|b| b.private).unwrap_or(false)
    }

    /// Fetch for a *remote* peer: refuses private blocks. This is the
    /// access-control middleware of §III-B.
    pub fn get_public(&self, cid: &Cid) -> Option<&[u8]> {
        match self.blocks.get(cid) {
            Some(b) if !b.private => Some(&b.data[..]),
            _ => None,
        }
    }

    /// [`BlockStore::get_public`], but returning a refcounted handle the
    /// bitswap server can move straight onto the wire without copying.
    pub fn get_public_blob(&self, cid: &Cid) -> Option<Blob> {
        match self.blocks.get(cid) {
            Some(b) if !b.private => Some(b.data.clone()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_dedup() {
        let mut bs = BlockStore::new();
        let c1 = bs.put(Codec::Raw, b"hello".to_vec());
        let c2 = bs.put(Codec::Raw, b"hello".to_vec());
        assert_eq!(c1, c2);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs.get(&c1), Some(&b"hello"[..]));
    }

    #[test]
    fn put_verified_rejects_tampered() {
        let mut bs = BlockStore::new();
        let cid = Cid::of_raw(b"good");
        assert!(!bs.put_verified(cid, b"evil".to_vec()));
        assert!(!bs.has(&cid));
        assert!(bs.put_verified(cid, b"good".to_vec()));
        assert!(bs.has(&cid));
    }

    #[test]
    fn gc_respects_pins() {
        let mut bs = BlockStore::new();
        let keep = bs.put(Codec::Raw, b"keep".to_vec());
        let drop_ = bs.put(Codec::Raw, b"drop".to_vec());
        bs.pin(&keep, Pin::Replica);
        let (n, bytes) = bs.gc();
        assert_eq!(n, 1);
        assert_eq!(bytes, 4);
        assert!(bs.has(&keep));
        assert!(!bs.has(&drop_));
    }

    #[test]
    fn local_pin_not_downgraded() {
        let mut bs = BlockStore::new();
        let c = bs.put(Codec::Raw, b"x".to_vec());
        bs.pin(&c, Pin::Local);
        bs.pin(&c, Pin::Replica);
        assert_eq!(bs.pin_of(&c), Some(Pin::Local));
    }

    #[test]
    fn privacy_middleware() {
        let mut bs = BlockStore::new();
        let c = bs.put(Codec::Raw, b"secret".to_vec());
        bs.set_private(&c, true);
        assert!(bs.get(&c).is_some()); // local access fine
        assert!(bs.get_public(&c).is_none()); // remote access denied
        bs.set_private(&c, false);
        assert!(bs.get_public(&c).is_some());
    }

    #[test]
    fn put_trusted_shares_the_allocation() {
        use crate::util::Blob;
        let mut bs = BlockStore::new();
        let data = Blob::from(&b"verified upstream"[..]);
        let cid = Cid::of_raw(&data);
        bs.put_trusted(cid, data.clone());
        assert!(bs.has(&cid));
        // The store holds the same allocation, not a copy.
        let held = bs.get_blob(&cid).unwrap();
        assert!(Blob::ptr_eq(&held, &data));
        assert_eq!(bs.bytes_stored(), data.len());
    }

    #[test]
    fn bytes_accounting() {
        let mut bs = BlockStore::new();
        bs.put(Codec::Raw, vec![0; 100]);
        bs.put(Codec::Raw, vec![1; 50]);
        assert_eq!(bs.bytes_stored(), 150);
    }
}

//! Fixed-size chunking with a manifest block.
//!
//! Files larger than [`CHUNK_SIZE`] are split into chunks; a manifest
//! block (list of chunk CIDs + total length) is what the file's public CID
//! refers to. Small files are stored as a single raw block with no
//! manifest, which is the common case for performance-data contributions
//! (≈9 KB in the paper's corpus).

use crate::blockstore::BlockStore;
use crate::cid::{Cid, Codec};
use crate::codec::bin::{Decode, DecodeError, Encode, Reader, Writer};

/// 256 KiB, matching IPFS's default block size.
pub const CHUNK_SIZE: usize = 256 * 1024;

/// Magic prefix distinguishing manifest blocks from raw single blocks.
const MANIFEST_MAGIC: &[u8; 4] = b"PDM1";

/// Manifest describing a chunked file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub total_len: u64,
    pub chunks: Vec<Cid>,
}

impl Encode for Manifest {
    fn encode(&self, w: &mut Writer) {
        w.put_raw(MANIFEST_MAGIC);
        w.put_varint(self.total_len);
        self.chunks.encode(w);
    }
}

impl Decode for Manifest {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let magic = r.get_raw(4)?;
        if magic != MANIFEST_MAGIC {
            return Err(DecodeError("bad manifest magic"));
        }
        Ok(Manifest {
            total_len: r.get_varint()?,
            chunks: Vec::<Cid>::decode(r)?,
        })
    }
}

/// Result of adding a file: its root CID and every block CID written
/// (root first), e.g. for pinning or provider announcement.
#[derive(Clone, Debug)]
pub struct AddResult {
    pub root: Cid,
    pub blocks: Vec<Cid>,
}

/// Add a file to the blockstore, chunking when necessary.
pub fn add_file(bs: &mut BlockStore, data: &[u8]) -> AddResult {
    if data.len() <= CHUNK_SIZE {
        let root = bs.put(Codec::Raw, data);
        return AddResult {
            root,
            blocks: vec![root],
        };
    }
    let mut chunks = Vec::new();
    for chunk in data.chunks(CHUNK_SIZE) {
        chunks.push(bs.put(Codec::Raw, chunk));
    }
    let manifest = Manifest {
        total_len: data.len() as u64,
        chunks: chunks.clone(),
    };
    let root = bs.put(Codec::Raw, crate::codec::to_bytes(&manifest));
    let mut blocks = vec![root];
    blocks.extend(chunks);
    AddResult { root, blocks }
}

/// Interpret a root block: either a manifest or a plain single block.
pub fn parse_root(data: &[u8]) -> Option<Manifest> {
    if data.len() >= 4 && &data[..4] == MANIFEST_MAGIC {
        crate::codec::from_bytes::<Manifest>(data).ok()
    } else {
        None
    }
}

/// Reassemble a file from its root CID. `None` if any block is missing
/// or the manifest is inconsistent.
pub fn get_file(bs: &BlockStore, root: &Cid) -> Option<Vec<u8>> {
    let root_data = bs.get(root)?;
    match parse_root(root_data) {
        None => Some(root_data.to_vec()),
        Some(manifest) => {
            let mut out = Vec::with_capacity(manifest.total_len as usize);
            for cid in &manifest.chunks {
                out.extend_from_slice(bs.get(cid)?);
            }
            if out.len() as u64 != manifest.total_len {
                return None;
            }
            Some(out)
        }
    }
}

/// All block CIDs a fetcher must retrieve for `root` given the root block
/// contents (root itself excluded).
pub fn child_blocks(root_data: &[u8]) -> Vec<Cid> {
    parse_root(root_data).map(|m| m.chunks).unwrap_or_default()
}

/// Unpin the file rooted at `root`: the root block and, for chunked
/// files, every chunk listed in its manifest. Blocks stay in the store
/// until the next [`BlockStore::gc`]; returns how many blocks actually
/// lost a pin. This is the "unpin" half of the deliberate unpin+GC
/// workflow the availability-repair scenarios exercise.
///
/// Caveat: chunks are content-addressed and may be *shared* with other
/// files (deduplication), and pins carry no reference count — unpinning
/// file A releases any chunk it shares with a still-wanted file B, and
/// the next GC then punches a hole in B. Callers dropping a subset of
/// their files must re-pin survivors afterwards; the GC-pressure
/// workflow (`peersdb::Node::unpin_contribution_data`) drops every
/// contribution file at once, where the hazard cannot arise.
pub fn unpin_file(bs: &mut BlockStore, root: &Cid) -> usize {
    let children = bs.get(root).map(child_blocks).unwrap_or_default();
    let mut unpinned = 0;
    for cid in std::iter::once(*root).chain(children) {
        if bs.unpin(&cid) {
            unpinned += 1;
        }
    }
    unpinned
}

/// True when the file rooted at `root` is *fully* present (root block and
/// every chunk). Cheaper than [`get_file`]: no reassembly.
pub fn has_file(bs: &BlockStore, root: &Cid) -> bool {
    match bs.get(root) {
        None => false,
        Some(data) => match parse_root(data) {
            None => true,
            Some(m) => m.chunks.iter().all(|c| bs.has(c)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn small_file_single_block() {
        let mut bs = BlockStore::new();
        let res = add_file(&mut bs, b"tiny");
        assert_eq!(res.blocks.len(), 1);
        assert_eq!(get_file(&bs, &res.root).unwrap(), b"tiny");
    }

    #[test]
    fn large_file_chunked_roundtrip() {
        let mut bs = BlockStore::new();
        let mut rng = Rng::new(1);
        let mut data = vec![0u8; CHUNK_SIZE * 3 + 1234];
        rng.fill_bytes(&mut data);
        let res = add_file(&mut bs, &data);
        assert_eq!(res.blocks.len(), 5); // manifest + 4 chunks
        assert_eq!(get_file(&bs, &res.root).unwrap(), data);
    }

    #[test]
    fn exact_chunk_boundary() {
        let mut bs = BlockStore::new();
        let data = vec![7u8; CHUNK_SIZE * 2];
        let res = add_file(&mut bs, &data);
        assert_eq!(res.blocks.len(), 3);
        assert_eq!(get_file(&bs, &res.root).unwrap(), data);
    }

    #[test]
    fn missing_chunk_detected() {
        let mut bs = BlockStore::new();
        let data = vec![1u8; CHUNK_SIZE + 1];
        let res = add_file(&mut bs, &data);
        // Remove one chunk by gc'ing without pins, keeping only the root.
        let root = res.root;
        let chunk = res.blocks[1];
        bs.pin(&root, crate::blockstore::Pin::Local);
        bs.gc();
        assert!(!bs.has(&chunk));
        assert!(get_file(&bs, &root).is_none());
    }

    #[test]
    fn child_blocks_listing() {
        let mut bs = BlockStore::new();
        let data = vec![2u8; CHUNK_SIZE * 2 + 5];
        let res = add_file(&mut bs, &data);
        let children = child_blocks(bs.get(&res.root).unwrap());
        assert_eq!(children.len(), 3);
        assert_eq!(&res.blocks[1..], &children[..]);
    }

    #[test]
    fn unpin_file_releases_every_block() {
        let mut bs = BlockStore::new();
        let data = vec![3u8; CHUNK_SIZE * 2 + 9];
        let res = add_file(&mut bs, &data);
        for b in &res.blocks {
            bs.pin(b, crate::blockstore::Pin::Replica);
        }
        assert_eq!(unpin_file(&mut bs, &res.root), res.blocks.len());
        let (n, _) = bs.gc();
        assert_eq!(n, res.blocks.len());
        assert!(!has_file(&bs, &res.root));
        // Idempotent: nothing left to unpin.
        assert_eq!(unpin_file(&mut bs, &res.root), 0);
    }

    #[test]
    fn dedup_across_files() {
        let mut bs = BlockStore::new();
        let shared = vec![9u8; CHUNK_SIZE];
        let mut a = shared.clone();
        a.extend_from_slice(b"tail-a");
        let mut b = shared.clone();
        b.extend_from_slice(b"tail-b");
        add_file(&mut bs, &a);
        let before = bs.len();
        add_file(&mut bs, &b);
        // The shared first chunk is deduplicated.
        assert_eq!(bs.len(), before + 2); // new tail chunk + new manifest
    }
}

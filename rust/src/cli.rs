//! Tiny command-line argument parser (no clap in the offline crate set).
//!
//! Supports `command [positional...] [--flag] [--key value]` shapes.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Parse an argument list (excluding argv[0]). Options take a value
/// unless listed in `boolean_flags`.
pub fn parse(args: &[String], boolean_flags: &[&str]) -> Result<Args, String> {
    let mut out = Args::default();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            if boolean_flags.contains(&name) {
                out.flags.push(name.to_string());
            } else {
                let v = it
                    .next()
                    .ok_or_else(|| format!("option --{name} requires a value"))?;
                out.options.insert(name.to_string(), v.clone());
            }
        } else if out.command.is_none() {
            out.command = Some(a.clone());
        } else {
            out.positional.push(a.clone());
        }
    }
    Ok(out)
}

impl Args {
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&v(&["node", "--seed", "42", "--http", "pos1"]), &["http"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("node"));
        assert_eq!(a.opt("seed"), Some("42"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 42);
        assert!(a.flag("http"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&v(&["node", "--seed"]), &[]).is_err());
        let a = parse(&v(&["x"]), &[]).unwrap();
        assert!(a.opt_u64("seed", 7).unwrap() == 7);
        assert!(parse(&v(&["x", "--seed", "nope"]), &[]).unwrap().opt_u64("seed", 0).is_err());
    }
}

//! Property-testing mini-framework (no `proptest` in the offline crate
//! set).
//!
//! A property is a function from a generated case to `Result<(), String>`.
//! [`check`] runs many cases from a seeded generator; on failure it
//! reports the case's seed and prints a ready-to-paste replay command
//! (`PEERSDB_PROP_SEED=<seed> PEERSDB_PROP_CASES=1 cargo test <name>`)
//! that re-executes exactly the failing case. No shrinking — cases are
//! kept small by construction instead.

use crate::util::Rng;

/// Number of cases per property (override with `PEERSDB_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PEERSDB_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` generated inputs. Panics with the failing
/// seed on the first counterexample.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let base: u64 = std::env::var("PEERSDB_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_BA5E);
    let cases = default_cases();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property '{name}' failed (case {i}, PEERSDB_PROP_SEED={seed}):\n  \
                 {msg}\n  case: {case:?}\n  \
                 replay: PEERSDB_PROP_SEED={seed} PEERSDB_PROP_CASES=1 cargo test {name}"
            );
        }
    }
}

/// Like [`check`] but the property receives its own RNG fork (for
/// randomized execution inside the property).
pub fn check_with_rng<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    check(name, |rng| (gen(rng), rng.next_u64()), |(case, prop_seed)| {
        let mut prng = Rng::new(*prop_seed);
        prop(case, &mut prng)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum-commutes", |r| (r.gen_range(100), r.gen_range(100)), |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", |r| r.gen_range(10), |_| Err("nope".into()));
    }
}

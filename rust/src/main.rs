//! PeersDB command-line entrypoint.
//!
//! ```text
//! peersdb node [--config cfg.json] [--http] [--interactive] [--seed N]
//!     Run a live TCP node (optionally with the HTTP API and a shell REPL).
//!
//! peersdb demo [--peers N] [--contributions M] [--seed N]
//!     Run a self-contained simulated cluster and print summary metrics.
//!
//! peersdb help
//! ```

use peersdb::api::http::HttpServer;
use peersdb::api::shell;
use peersdb::api::{dispatch, ApiResponse};
use peersdb::net::tcp::{Directory, TcpNode};
use peersdb::net::PeerId;
use peersdb::peersdb::{Node, NodeConfig};
use peersdb::sim::harness;
use peersdb::util::time::Duration;
use peersdb::util::Rng;
use std::io::BufRead;
use std::sync::Arc;

const HELP: &str = "\
peersdb — peer-to-peer data distribution layer for collaborative
performance modeling of distributed dataflow applications.

USAGE:
  peersdb node [--config cfg.json] [--http] [--interactive] [--seed N]
  peersdb demo [--peers N] [--contributions M] [--seed N]
  peersdb help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match peersdb::cli::parse(&argv, &["http", "interactive"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("node") => cmd_node(&args),
        Some("demo") => cmd_demo(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => Err(format!("unknown command: {other}\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_node(args: &peersdb::cli::Args) -> Result<(), String> {
    let cfg = match args.opt("config") {
        Some(path) => peersdb::config::load_node_config(path)?,
        None => NodeConfig::default(),
    };
    let seed = args.opt_u64("seed", 42)?;
    let mut rng = Rng::new(seed);
    let id = PeerId::from_rng(&mut rng);
    println!("starting node {id}");
    let node = Node::new(id, cfg, rng.next_u64());
    let dir = Directory::new();
    let tcp = Arc::new(TcpNode::start(node, dir).map_err(|e| e.to_string())?);
    println!("p2p listening on {}", tcp.addr);

    let server = if args.flag("http") {
        let s = HttpServer::start(tcp.clone()).map_err(|e| e.to_string())?;
        println!("http api on http://{}", s.addr);
        Some(s)
    } else {
        None
    };

    if args.flag("interactive") {
        println!("shell ready (status | contribute | get | query | verdict | metrics; ^D to exit)");
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            match shell::parse_line(&line) {
                Err(e) => println!("error: {e}"),
                Ok(req) => {
                    let resp: ApiResponse =
                        tcp.call_sync(move |n, now, out| dispatch(n, now, req, out));
                    println!("{}", shell::render(&resp));
                }
            }
        }
    } else {
        println!("running; ^C to exit");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    if let Some(s) = server {
        s.stop();
    }
    Ok(())
}

fn cmd_demo(args: &peersdb::cli::Args) -> Result<(), String> {
    let peers = args.opt_u64("peers", 8)? as usize;
    let contributions = args.opt_u64("contributions", 20)? as usize;
    let seed = args.opt_u64("seed", 1)?;
    println!("simulating {peers} peers, {contributions} contributions (seed {seed})");
    let mut cluster = harness::paper_cluster(seed, peers, Duration::from_millis(500), |_| {
        NodeConfig::default()
    });
    cluster.run_for(Duration::from_secs(30));
    let mut rng = Rng::new(seed ^ 99);
    for i in 0..contributions {
        let wl = (i % 6) as u32;
        let (data, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, wl, 80);
        let idx = 1 + (i % (peers - 1));
        let workload = peersdb::modeling::datagen::WORKLOADS[wl as usize];
        harness::contribute(&mut cluster, idx, &data, workload);
        cluster.run_for(Duration::from_millis(700));
    }
    cluster.run_for(Duration::from_secs(60));
    harness::assert_converged(&mut cluster);
    println!(
        "\nall {} stores converged ({} contributions each)",
        peers,
        cluster.node(0).contributions.len()
    );
    let repl = cluster
        .node(1)
        .metrics
        .summary("replication_ms")
        .map(|s| s.mean())
        .unwrap_or(f64::NAN);
    println!("node-1 mean replication latency: {repl:.1} ms");
    println!(
        "transport: {} msgs, {:.1} MiB",
        cluster.stats.msgs_delivered,
        cluster.stats.bytes_sent as f64 / 1048576.0
    );
    Ok(())
}

//! Publish/subscribe: floodsub by default, gossipsub-style epidemic
//! mesh behind a knob.
//!
//! Used by the replication layer to announce new store heads (OrbitDB
//! does the same over libp2p pubsub). Two dissemination modes share one
//! engine:
//!
//! - **Flood** (default, [`Engine::new`]): peers exchange subscriptions
//!   with their neighbors; published messages flood along subscribed
//!   links with a seen-cache for deduplication and a hop limit as a
//!   safety valve. Every pre-mesh schedule replays bit-identically on
//!   this path.
//! - **Mesh** ([`Engine::enable_mesh`], the gossipsub/radicle-link
//!   shape): each subscribed topic maintains a bounded-degree mesh
//!   ([`MeshConfig::degree`] with low/high watermarks) repaired on a
//!   heartbeat. Full [`Msg::Publish`] frames are pushed eagerly only to
//!   mesh members; up to [`MeshConfig::lazy_degree`] other subscribers
//!   get lazy, batched [`Msg::IHave`] digests once per heartbeat and
//!   pull what they miss with [`Msg::IWant`], answered from a bounded
//!   message cache. Mesh membership is negotiated with explicit
//!   [`Msg::Graft`] / [`Msg::Prune`] control frames; candidate choice
//!   is a deterministic FxHash ranking (no extra RNG draws, mirroring
//!   the repair-jitter discipline in `peersdb::node`).
//!
//!   Because neighbor sampling is asymmetric *and* resampled
//!   continuously (`peersdb` draws a fresh random sample from the
//!   routing table about once a second), the mesh cannot build its
//!   edges on "peers I currently sample" — the intersection of two
//!   nodes' samples is usually empty and never stable. Instead each
//!   node re-announces its subscriptions every heartbeat to its
//!   sampled neighbors and mesh members; the *received* announcement
//!   records (expiring a few heartbeats after the sender falls
//!   silent) are what make a peer a graft candidate and a lazy-digest
//!   target. Every live subscriber is therefore always held as a
//!   candidate by the ~`neighbor_degree` peers it announces to,
//!   whatever either side's sample currently looks like.
//!
//! Payloads are refcounted [`Blob`]s: forwarding a message to N peers
//! clones a pointer, never the bytes.

use crate::codec::bin::{bytes_len, varint_len, Decode, DecodeError, Encode, Reader, Writer};
use crate::net::{PeerId, WireSize};
use crate::util::bytes::Blob;
use crate::util::time::{Duration, Nanos};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet, VecDeque};

/// A topic is the hash of its name (store address).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Topic(pub u64);

impl Topic {
    pub fn named(name: &str) -> Topic {
        use sha2::{Digest, Sha256};
        let d: [u8; 32] = Sha256::digest(name.as_bytes()).into();
        Topic(u64::from_le_bytes(d[..8].try_into().unwrap()))
    }
}

impl Encode for Topic {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.0);
    }
}
impl Decode for Topic {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Topic(r.get_u64()?))
    }
}

pub const MAX_HOPS: u8 = 16;

/// Identity of a published message: `(origin, per-origin sequence)`.
/// The same pair keys the seen-cache and the mesh message cache; on the
/// wire the seq is a fixed 8-byte word so `IHave`/`IWant` sizes stay
/// O(1)-computable from the id count alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MsgId {
    pub origin: PeerId,
    pub seq: u64,
}

impl Encode for MsgId {
    fn encode(&self, w: &mut Writer) {
        self.origin.encode(w);
        w.put_u64(self.seq);
    }
}
impl Decode for MsgId {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(MsgId { origin: PeerId::decode(r)?, seq: r.get_u64()? })
    }
}

/// Encoded length of one [`MsgId`]: 32-byte peer id + fixed u64 seq.
const MSG_ID_WIRE: usize = 32 + 8;

/// Pubsub wire messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Announce our subscriptions to a neighbor.
    Subscriptions { topics: Vec<Topic> },
    /// Application message, pushed eagerly (flooded, or mesh-routed).
    Publish {
        topic: Topic,
        origin: PeerId,
        seq: u64,
        hops: u8,
        data: Blob,
    },
    /// Lazy advertisement: ids cached this heartbeat window, batched to
    /// subscribed non-mesh neighbors. Mesh mode only.
    IHave { topic: Topic, ids: Vec<MsgId> },
    /// Pull request for advertised messages we have not seen.
    IWant { ids: Vec<MsgId> },
    /// Ask the receiver to add us to its mesh for `topic`.
    Graft { topic: Topic },
    /// Tell the receiver we removed it from our mesh for `topic`.
    Prune { topic: Topic },
}

impl Encode for Msg {
    fn encode(&self, w: &mut Writer) {
        match self {
            Msg::Subscriptions { topics } => {
                w.put_u8(0);
                topics.encode(w);
            }
            Msg::Publish { topic, origin, seq, hops, data } => {
                w.put_u8(1);
                topic.encode(w);
                origin.encode(w);
                w.put_varint(*seq);
                w.put_u8(*hops);
                w.put_bytes(data);
            }
            Msg::IHave { topic, ids } => {
                w.put_u8(2);
                topic.encode(w);
                ids.encode(w);
            }
            Msg::IWant { ids } => {
                w.put_u8(3);
                ids.encode(w);
            }
            Msg::Graft { topic } => {
                w.put_u8(4);
                topic.encode(w);
            }
            Msg::Prune { topic } => {
                w.put_u8(5);
                topic.encode(w);
            }
        }
    }
}

impl Decode for Msg {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(match r.get_u8()? {
            0 => Msg::Subscriptions { topics: Vec::decode(r)? },
            1 => Msg::Publish {
                topic: Topic::decode(r)?,
                origin: PeerId::decode(r)?,
                seq: r.get_varint()?,
                hops: r.get_u8()?,
                data: r.get_bytes()?.into(),
            },
            2 => Msg::IHave { topic: Topic::decode(r)?, ids: Vec::decode(r)? },
            3 => Msg::IWant { ids: Vec::decode(r)? },
            4 => Msg::Graft { topic: Topic::decode(r)? },
            5 => Msg::Prune { topic: Topic::decode(r)? },
            _ => return Err(DecodeError("bad pubsub tag")),
        })
    }
}

impl WireSize for Msg {
    /// Exact encoded length in O(1) (topics are fixed 8-byte hashes,
    /// message ids fixed 40-byte pairs; `Publish` adds origin, varint
    /// seq, hop byte and the payload). Property-tested against the real
    /// encoding in `tests/prop.rs`.
    fn wire_size(&self) -> usize {
        match self {
            Msg::Subscriptions { topics } => 1 + varint_len(topics.len() as u64) + topics.len() * 8,
            Msg::Publish { seq, data, .. } => {
                1 + 8 + 32 + varint_len(*seq) + 1 + bytes_len(data.len())
            }
            Msg::IHave { ids, .. } => {
                1 + 8 + varint_len(ids.len() as u64) + ids.len() * MSG_ID_WIRE
            }
            Msg::IWant { ids } => 1 + varint_len(ids.len() as u64) + ids.len() * MSG_ID_WIRE,
            Msg::Graft { .. } | Msg::Prune { .. } => 1 + 8,
        }
    }
}

/// Message delivered to the local node. The payload is the shared
/// refcounted allocation — delivering does not copy.
#[derive(Clone, Debug)]
pub struct Delivery {
    pub topic: Topic,
    pub origin: PeerId,
    pub data: Blob,
}

/// Gossip-mesh knobs. Defaults follow the gossipsub shape scaled to
/// this crate's neighbor sample size (`NodeConfig::neighbor_degree`,
/// default 8): a target degree well below the sample keeps eager-push
/// amplification bounded while the low/high watermarks absorb churn.
#[derive(Clone, Debug, PartialEq)]
pub struct MeshConfig {
    /// Target mesh degree D: grafted up to this many members per topic.
    pub degree: usize,
    /// Repair threshold: below this the heartbeat grafts back to D.
    pub degree_low: usize,
    /// Prune threshold: above this the heartbeat prunes back to D.
    pub degree_high: usize,
    /// Lazy fan-out bound: at most this many non-mesh subscribers
    /// (rank-preferred) receive each heartbeat's `IHave` digest per
    /// topic, so a dense announcement-record set cannot turn the lazy
    /// tier into a second flood.
    pub lazy_degree: usize,
    /// Heartbeat cadence: mesh repair, subscription re-announcement,
    /// IHAVE batching, cache rotation.
    pub heartbeat: Duration,
    /// Message-cache depth in heartbeat windows: how long an id can be
    /// advertised and its payload served to `IWant` pulls.
    pub history_windows: usize,
    /// Liveness lease for mesh members grafted by the remote side (we
    /// may never have sampled them as neighbors ourselves). Refreshed
    /// by any frame from the peer; an expired non-neighbor member is
    /// swept at the next heartbeat — this is what finally unsticks a
    /// crashed peer from the mesh.
    pub graft_lease: Duration,
}

impl Default for MeshConfig {
    fn default() -> Self {
        MeshConfig {
            degree: 3,
            degree_low: 2,
            degree_high: 6,
            lazy_degree: 6,
            heartbeat: Duration::from_secs(1),
            history_windows: 5,
            graft_lease: Duration::from_secs(60),
        }
    }
}

/// Deterministic mesh preference: FxHash of `(own, peer)`. Every node
/// ranks its candidate set differently (so meshes don't all collapse
/// onto the same hubs) but identically across runs and heartbeats —
/// zero RNG draws, mirroring the repair-jitter discipline.
fn mesh_rank(own: PeerId, peer: PeerId) -> u64 {
    use std::hash::Hasher;
    let mut h = crate::util::fxhash::FxHasher::default();
    h.write(&own.0);
    h.write(&peer.0);
    h.finish()
}

/// Pubsub engine (flood or mesh). One per node.
pub struct Engine {
    own: PeerId,
    subscriptions: BTreeSet<Topic>,
    /// Known subscriber records, fed by received `Subscriptions`
    /// frames. Flood mode prunes them to the neighbor sample on every
    /// refresh; mesh mode instead holds records for *any* announcer
    /// (that is what makes the asymmetric sample workable — see the
    /// module docs) and expires them via [`Engine::subs_heard`] a few
    /// heartbeats after the sender falls silent. Ordered map: the
    /// heartbeat's graft/IHAVE target iteration must be deterministic.
    neighbor_topics: BTreeMap<PeerId, BTreeSet<Topic>>,
    /// Mesh mode: when each subscriber record was last refreshed by an
    /// announcement (its freshness clock; never read in flood mode).
    subs_heard: HashMap<PeerId, Nanos>,
    neighbors: BTreeSet<PeerId>,
    seen: HashMap<(PeerId, u64), Nanos>,
    seen_ttl: Duration,
    next_seq: u64,
    pub deliveries: Vec<Delivery>,
    /// Messages this node originated.
    pub published: u64,
    /// `Publish` frames this node pushed onto links — publish fan-out,
    /// relays and `IWant` serves alike. Actual sends, not "messages we
    /// decided to forward": a relay with no eligible receivers counts
    /// zero, so the bench's redundancy denominator is honest.
    pub forwarded: u64,
    /// First-copy deliveries to the local subscriber.
    pub delivered: u64,
    /// Duplicate `Publish` frames received (suppressed).
    pub duplicates: u64,

    // --- mesh state (inert unless `mesh_cfg` is set) ---
    mesh_cfg: Option<MeshConfig>,
    /// Per-topic mesh members (eager-push targets).
    mesh: BTreeMap<Topic, BTreeSet<PeerId>>,
    /// Last frame seen from each mesh member (liveness lease).
    mesh_lease: HashMap<PeerId, Nanos>,
    /// Bounded message cache: id → (topic, hops-to-serve, payload).
    mcache: HashMap<(PeerId, u64), (Topic, u8, Blob)>,
    /// Cache rotation: ids admitted per heartbeat window, oldest first.
    mcache_windows: VecDeque<Vec<(PeerId, u64)>>,
    /// Ids cached since the last heartbeat, batched into `IHave`s.
    pending_ihave: BTreeMap<Topic, Vec<MsgId>>,
    /// Ids already pulled this heartbeat (don't re-request from every
    /// `IHave` sender at once); cleared on heartbeat.
    iwant_requested: HashSet<(PeerId, u64)>,
    last_heartbeat: Nanos,
    /// Every id ever delivered locally — the ground-truth record behind
    /// the full-delivery invariant (`sim::scenario`). Bounded by the
    /// number of messages published cluster-wide, which for this crate
    /// is the contribution count: a handful per scenario.
    delivered_ids: BTreeSet<(PeerId, u64)>,
    /// Mesh telemetry: `IHave` frames sent, `Publish` frames served to
    /// `IWant` pulls, mesh additions, mesh removals.
    pub ihave_sent: u64,
    pub iwant_served: u64,
    pub grafts: u64,
    pub prunes: u64,
}

pub type Sends = Vec<(PeerId, Msg)>;

impl Engine {
    pub fn new(own: PeerId) -> Self {
        Engine {
            own,
            subscriptions: BTreeSet::new(),
            neighbor_topics: BTreeMap::new(),
            subs_heard: HashMap::new(),
            neighbors: BTreeSet::new(),
            seen: HashMap::new(),
            seen_ttl: Duration::from_secs(120),
            next_seq: 1,
            deliveries: Vec::new(),
            published: 0,
            forwarded: 0,
            delivered: 0,
            duplicates: 0,
            mesh_cfg: None,
            mesh: BTreeMap::new(),
            mesh_lease: HashMap::new(),
            mcache: HashMap::new(),
            mcache_windows: VecDeque::new(),
            pending_ihave: BTreeMap::new(),
            iwant_requested: HashSet::new(),
            last_heartbeat: Nanos::ZERO,
            delivered_ids: BTreeSet::new(),
            ihave_sent: 0,
            iwant_served: 0,
            grafts: 0,
            prunes: 0,
        }
    }

    /// Switch this engine from flood to gossip-mesh dissemination.
    /// Call before any traffic flows (node construction time).
    pub fn enable_mesh(&mut self, cfg: MeshConfig) {
        self.mesh_cfg = Some(cfg);
    }

    pub fn mesh_enabled(&self) -> bool {
        self.mesh_cfg.is_some()
    }

    /// Mesh telemetry `(ihave_sent, iwant_served, grafts, prunes)`.
    pub fn mesh_stats(&self) -> (u64, u64, u64, u64) {
        (self.ihave_sent, self.iwant_served, self.grafts, self.prunes)
    }

    /// Number of messages this engine has published (seqs `1..=n`).
    pub fn published_count(&self) -> u64 {
        self.published
    }

    /// Whether `(origin, seq)` was ever delivered to the local
    /// subscriber — the per-node half of the full-delivery invariant.
    pub fn has_delivered(&self, origin: PeerId, seq: u64) -> bool {
        self.delivered_ids.contains(&(origin, seq))
    }

    /// Current mesh members for `topic` (empty in flood mode).
    pub fn mesh_members(&self, topic: Topic) -> Vec<PeerId> {
        self.mesh.get(&topic).map(|m| m.iter().copied().collect()).unwrap_or_default()
    }

    pub fn subscribe(&mut self, topic: Topic, out: &mut Sends) {
        if self.subscriptions.insert(topic) {
            self.broadcast_subscriptions(out);
        }
    }

    pub fn subscriptions(&self) -> Vec<Topic> {
        self.subscriptions.iter().copied().collect()
    }

    /// Update the neighbor set (fed from the DHT routing table). New
    /// neighbors get our subscription list.
    pub fn set_neighbors(&mut self, peers: Vec<PeerId>, out: &mut Sends) {
        let new: Vec<PeerId> = peers
            .iter()
            .filter(|p| !self.neighbors.contains(*p) && **p != self.own)
            .copied()
            .collect();
        self.neighbors = peers.into_iter().filter(|p| *p != self.own).collect();
        if self.mesh_cfg.is_none() {
            // Flood mode scopes subscriber records to the sample: the
            // broadcast set is exactly `neighbors ∩ records`. Mesh mode
            // keeps records across refreshes (they expire on their own
            // freshness clock instead) because its graft candidates and
            // lazy digests deliberately outlive any one sample.
            self.neighbor_topics.retain(|p, _| self.neighbors.contains(p));
        }
        if !self.subscriptions.is_empty() {
            for p in new {
                out.push((
                    p,
                    Msg::Subscriptions { topics: self.subscriptions() },
                ));
            }
        }
    }

    pub fn neighbors(&self) -> &BTreeSet<PeerId> {
        &self.neighbors
    }

    fn broadcast_subscriptions(&mut self, out: &mut Sends) {
        let topics = self.subscriptions();
        for p in &self.neighbors {
            out.push((*p, Msg::Subscriptions { topics: topics.clone() }));
        }
    }

    /// Publish `data` on `topic`: flood to subscribed neighbors, or
    /// (mesh mode) eager-push to mesh members and advertise lazily to
    /// the rest on the next heartbeat.
    pub fn publish(&mut self, now: Nanos, topic: Topic, data: impl Into<Blob>, out: &mut Sends) {
        let data: Blob = data.into();
        let seq = self.next_seq;
        self.next_seq += 1;
        self.published += 1;
        self.seen.insert((self.own, seq), now);
        if self.mesh_cfg.is_some() {
            self.remember(topic, (self.own, seq), 0, &data);
        }
        let msg = Msg::Publish { topic, origin: self.own, seq, hops: 0, data };
        let sent = if self.mesh_cfg.is_some() {
            self.eager_push(&msg, None, out)
        } else {
            self.flood(&msg, None, out)
        };
        self.forwarded += sent;
    }

    /// Flood `msg` to every subscribed neighbor except `skip`; returns
    /// the number of frames actually pushed.
    fn flood(&mut self, msg: &Msg, skip: Option<PeerId>, out: &mut Sends) -> u64 {
        let Msg::Publish { topic, .. } = msg else { return 0 };
        let mut sent = 0;
        for p in &self.neighbors {
            if Some(*p) == skip {
                continue;
            }
            let subscribed = self
                .neighbor_topics
                .get(p)
                .map(|t| t.contains(topic))
                .unwrap_or(false);
            if subscribed {
                out.push((*p, msg.clone()));
                sent += 1;
            }
        }
        sent
    }

    /// Push `msg` to the topic's mesh members except `skip`; returns
    /// the number of frames pushed. Grafting is the subscription
    /// assertion, so no per-member topic check is needed.
    fn eager_push(&mut self, msg: &Msg, skip: Option<PeerId>, out: &mut Sends) -> u64 {
        let Msg::Publish { topic, .. } = msg else { return 0 };
        let Some(members) = self.mesh.get(topic) else { return 0 };
        let mut sent = 0;
        for p in members {
            if Some(*p) == skip {
                continue;
            }
            out.push((*p, msg.clone()));
            sent += 1;
        }
        sent
    }

    /// Admit an id into the message cache and the pending-IHAVE batch.
    fn remember(&mut self, topic: Topic, id: (PeerId, u64), hops: u8, data: &Blob) {
        if self.mcache.insert(id, (topic, hops, data.clone())).is_none() {
            self.pending_ihave
                .entry(topic)
                .or_default()
                .push(MsgId { origin: id.0, seq: id.1 });
        }
    }

    pub fn on_msg(&mut self, now: Nanos, from: PeerId, msg: Msg, out: &mut Sends) {
        let mesh_on = self.mesh_cfg.is_some();
        if mesh_on && self.mesh_lease.contains_key(&from) {
            // Any frame is a liveness proof for a mesh member.
            self.mesh_lease.insert(from, now);
        }
        match msg {
            Msg::Subscriptions { topics } => {
                // Flood mode keeps the legacy unilateral insert: neighbor
                // sampling is asymmetric (A samples B; B first hears of A
                // through this very frame), so the insert is the only
                // channel that makes the B→A flood edge exist. The
                // resurrection hazard it carries — a late frame from a
                // departed peer re-adding it past `set_neighbors`
                // pruning — is bounded by the next neighbor refresh and,
                // in the DES, suppressed entirely by the crash-epoch
                // plane. Mesh mode drops the hack: an announcement earns
                // no broadcast edge, only an expiring subscriber record
                // (graft candidacy plus at most a few heartbeats of lazy
                // digests); eager links are negotiated explicitly with
                // `Graft` and leased, so a departed peer's late frame
                // cannot resurrect it into anyone's forwarding set.
                if mesh_on {
                    self.subs_heard.insert(from, now);
                } else {
                    self.neighbors.insert(from);
                }
                self.neighbor_topics.insert(from, topics.into_iter().collect());
            }
            Msg::Publish { topic, origin, seq, hops, data } => {
                let id = (origin, seq);
                // Mesh mode also dedups against the message cache: the
                // cache outlives a seen-cache expiry within its window
                // span, so an expiry-driven redelivery is suppressed
                // instead of double-counted.
                if self.seen.contains_key(&id) || (mesh_on && self.mcache.contains_key(&id)) {
                    self.duplicates += 1;
                    return;
                }
                self.seen.insert(id, now);
                if self.subscriptions.contains(&topic) {
                    self.delivered += 1;
                    self.delivered_ids.insert(id);
                    self.deliveries.push(Delivery { topic, origin, data: data.clone() });
                }
                if mesh_on {
                    // Cache even at the hop limit: IWANT serves reset the
                    // hop budget at the cache holder, they don't extend a
                    // single flood path.
                    self.remember(topic, id, hops.saturating_add(1), &data);
                }
                if hops < MAX_HOPS {
                    let fwd = Msg::Publish { topic, origin, seq, hops: hops + 1, data };
                    let sent = if mesh_on {
                        self.eager_push(&fwd, Some(from), out)
                    } else {
                        self.flood(&fwd, Some(from), out)
                    };
                    self.forwarded += sent;
                }
            }
            Msg::IHave { topic, ids } => {
                if !mesh_on || !self.subscriptions.contains(&topic) {
                    return;
                }
                let mut want = Vec::new();
                for id in ids {
                    let key = (id.origin, id.seq);
                    if self.seen.contains_key(&key)
                        || self.mcache.contains_key(&key)
                        || self.iwant_requested.contains(&key)
                    {
                        continue;
                    }
                    self.iwant_requested.insert(key);
                    want.push(id);
                }
                if !want.is_empty() {
                    out.push((from, Msg::IWant { ids: want }));
                }
            }
            Msg::IWant { ids } => {
                if !mesh_on {
                    return;
                }
                for id in ids {
                    if let Some((topic, hops, data)) = self.mcache.get(&(id.origin, id.seq)) {
                        out.push((
                            from,
                            Msg::Publish {
                                topic: *topic,
                                origin: id.origin,
                                seq: id.seq,
                                hops: *hops,
                                data: data.clone(),
                            },
                        ));
                        self.iwant_served += 1;
                        self.forwarded += 1;
                    }
                }
            }
            Msg::Graft { topic } => {
                if !mesh_on {
                    return;
                }
                if self.subscriptions.contains(&topic) {
                    if self.mesh.entry(topic).or_default().insert(from) {
                        self.grafts += 1;
                    }
                    self.mesh_lease.insert(from, now);
                } else {
                    out.push((from, Msg::Prune { topic }));
                }
            }
            Msg::Prune { topic } => {
                if !mesh_on {
                    return;
                }
                if let Some(m) = self.mesh.get_mut(&topic) {
                    if m.remove(&from) {
                        self.prunes += 1;
                    }
                }
            }
        }
    }

    /// Periodic service: expire the seen-cache, and in mesh mode drive
    /// the heartbeat (mesh repair, IHAVE batching, cache rotation).
    /// Flood mode never pushes a send here, so pre-mesh schedules
    /// replay bit-identically through the widened signature.
    pub fn tick(&mut self, now: Nanos, out: &mut Sends) {
        let ttl = self.seen_ttl;
        self.seen.retain(|_, t| now.saturating_sub(*t) < ttl);
        let Some(cfg) = self.mesh_cfg.clone() else { return };
        if now.saturating_sub(self.last_heartbeat) < cfg.heartbeat {
            return;
        }
        self.last_heartbeat = now;
        self.heartbeat(now, &cfg, out);
    }

    /// Subscriber records expire this many heartbeats after the last
    /// announcement from their holder: long enough to ride out frame
    /// reordering, short enough that a departed peer stops drawing
    /// grafts and digests within a few seconds.
    const RECORD_TTL_HEARTBEATS: u64 = 3;

    fn heartbeat(&mut self, now: Nanos, cfg: &MeshConfig, out: &mut Sends) {
        // 0. Re-announce our subscriptions to the sampled neighbors and
        //    every mesh member. This is the record-refresh channel: the
        //    ~`neighbor_degree` peers we announce to each hold our
        //    subscriber record for the next few heartbeats, which is
        //    exactly what keeps us graftable and a lazy-digest target
        //    under continuous resampling (module docs). Announcing to
        //    mesh members doubles as a mutual lease refresh, so a live
        //    mesh edge never cycles through lease expiry.
        if !self.subscriptions.is_empty() {
            let mut targets = self.neighbors.clone();
            for members in self.mesh.values() {
                targets.extend(members.iter().copied());
            }
            let topics = self.subscriptions();
            for p in targets {
                out.push((p, Msg::Subscriptions { topics: topics.clone() }));
            }
        }

        // 1. Expire subscriber records whose holder fell silent, then
        //    sweep departed mesh members: not in the current neighbor
        //    sample and lease expired (no frame within the lease).
        let record_ttl = Duration(cfg.heartbeat.0.saturating_mul(Self::RECORD_TTL_HEARTBEATS));
        let heard = &self.subs_heard;
        self.neighbor_topics
            .retain(|p, _| heard.get(p).is_some_and(|t| now.saturating_sub(*t) < record_ttl));
        let records = &self.neighbor_topics;
        self.subs_heard.retain(|p, _| records.contains_key(p));
        let mut dead: Vec<(Topic, PeerId)> = Vec::new();
        for (t, members) in &self.mesh {
            for p in members {
                if self.neighbors.contains(p) {
                    continue;
                }
                let fresh = self
                    .mesh_lease
                    .get(p)
                    .map(|l| now.saturating_sub(*l) < cfg.graft_lease)
                    .unwrap_or(false);
                if !fresh {
                    dead.push((*t, *p));
                }
            }
        }
        for (t, p) in dead {
            if let Some(m) = self.mesh.get_mut(&t) {
                if m.remove(&p) {
                    self.prunes += 1;
                    out.push((p, Msg::Prune { topic: t }));
                }
            }
        }
        let mesh = &self.mesh;
        self.mesh_lease.retain(|p, _| mesh.values().any(|m| m.contains(p)));

        // 2. Degree maintenance per subscribed topic: graft back up to D
        //    below the low watermark, prune back down to D above the
        //    high one. Candidates are the fresh subscriber records —
        //    peers that announced *to us* recently, whether or not we
        //    happen to sample them — preferred by the deterministic
        //    rank. (Requiring candidates to sit in our own sample would
        //    starve the mesh: two nodes' random samples rarely
        //    intersect, and never for long.)
        let topics: Vec<Topic> = self.subscriptions.iter().copied().collect();
        for topic in topics {
            let members = self.mesh.entry(topic).or_default().clone();
            if members.len() < cfg.degree_low {
                let mut cands: Vec<PeerId> = self
                    .neighbor_topics
                    .iter()
                    .filter(|(p, t)| {
                        **p != self.own && !members.contains(*p) && t.contains(&topic)
                    })
                    .map(|(p, _)| *p)
                    .collect();
                cands.sort_by_key(|p| mesh_rank(self.own, *p));
                let need = cfg.degree.saturating_sub(members.len());
                for p in cands.into_iter().take(need) {
                    if self.mesh.entry(topic).or_default().insert(p) {
                        self.grafts += 1;
                        self.mesh_lease.entry(p).or_insert(now);
                        out.push((p, Msg::Graft { topic }));
                    }
                }
            } else if members.len() > cfg.degree_high {
                let mut ranked: Vec<PeerId> = members.iter().copied().collect();
                ranked.sort_by_key(|p| mesh_rank(self.own, *p));
                for p in ranked.into_iter().skip(cfg.degree) {
                    if self.mesh.entry(topic).or_default().remove(&p) {
                        self.prunes += 1;
                        out.push((p, Msg::Prune { topic }));
                    }
                }
            }
        }

        // 3. Flush the batched IHAVE digests to subscribed record
        //    holders outside the mesh (mesh members got the full
        //    frames), capped at `lazy_degree` rank-preferred targets
        //    per topic. The records are refreshed by step 0's
        //    re-announcements, so every live subscriber keeps drawing
        //    digests from the peers it announces to; what one
        //    heartbeat's digest misses, the next hop's re-advertisement
        //    of a pulled id covers — the lazy wave crosses the cluster
        //    one heartbeat per hop.
        let pending = std::mem::take(&mut self.pending_ihave);
        let mut window: Vec<(PeerId, u64)> = Vec::new();
        for (topic, ids) in pending {
            window.extend(ids.iter().map(|id| (id.origin, id.seq)));
            let members = self.mesh.get(&topic).cloned().unwrap_or_default();
            let mut lazy: Vec<PeerId> = self
                .neighbor_topics
                .iter()
                .filter(|(p, t)| **p != self.own && !members.contains(*p) && t.contains(&topic))
                .map(|(p, _)| *p)
                .collect();
            lazy.sort_by_key(|p| mesh_rank(self.own, *p));
            lazy.truncate(cfg.lazy_degree);
            for p in lazy {
                out.push((p, Msg::IHave { topic, ids: ids.clone() }));
                self.ihave_sent += 1;
            }
        }

        // 4. Rotate the message cache: admit this window, drop payloads
        //    past the history horizon.
        self.mcache_windows.push_back(window);
        while self.mcache_windows.len() > cfg.history_windows {
            if let Some(old) = self.mcache_windows.pop_front() {
                for id in old {
                    self.mcache.remove(&id);
                }
            }
        }

        // 5. A fresh heartbeat may re-request ids still missing.
        self.iwant_requested.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ids(n: usize, seed: u64) -> Vec<PeerId> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    /// Deliver messages synchronously until quiet.
    fn settle(engines: &mut HashMap<PeerId, Engine>, mut queue: Vec<(PeerId, PeerId, Msg)>) {
        let mut hops = 0;
        while let Some((from, to, msg)) = queue.pop() {
            hops += 1;
            assert!(hops < 100_000);
            let mut out = Sends::new();
            if let Some(e) = engines.get_mut(&to) {
                e.on_msg(Nanos(0), from, msg, &mut out);
            }
            for (t, m) in out {
                queue.push((to, t, m));
            }
        }
    }

    fn line_topology(n: usize, seed: u64) -> (Vec<PeerId>, HashMap<PeerId, Engine>) {
        let ps = ids(n, seed);
        let mut engines: HashMap<PeerId, Engine> =
            ps.iter().map(|p| (*p, Engine::new(*p))).collect();
        let topic = Topic::named("contrib");
        let mut queue = Vec::new();
        // Each node neighbors its line adjacents; all subscribe.
        for (i, p) in ps.iter().enumerate() {
            let mut nbrs = Vec::new();
            if i > 0 {
                nbrs.push(ps[i - 1]);
            }
            if i + 1 < ps.len() {
                nbrs.push(ps[i + 1]);
            }
            let e = engines.get_mut(p).unwrap();
            let mut out = Sends::new();
            e.subscribe(topic, &mut out);
            e.set_neighbors(nbrs, &mut out);
            for (t, m) in out {
                queue.push((*p, t, m));
            }
        }
        settle(&mut engines, queue);
        (ps, engines)
    }

    #[test]
    fn msg_roundtrip() {
        let mut rng = Rng::new(1);
        let origin = PeerId::from_rng(&mut rng);
        let peer = PeerId::from_rng(&mut rng);
        let cases = vec![
            Msg::Subscriptions { topics: vec![Topic::named("a"), Topic::named("b")] },
            Msg::Publish {
                topic: Topic::named("x"),
                origin,
                seq: 9,
                hops: 3,
                data: b"heads".into(),
            },
            Msg::IHave {
                topic: Topic::named("x"),
                ids: vec![MsgId { origin, seq: 1 }, MsgId { origin: peer, seq: 300 }],
            },
            Msg::IWant { ids: vec![MsgId { origin, seq: u64::MAX }] },
            Msg::Graft { topic: Topic::named("g") },
            Msg::Prune { topic: Topic::named("p") },
        ];
        for m in cases {
            let b = crate::codec::to_bytes(&m);
            assert_eq!(crate::codec::from_bytes::<Msg>(&b).unwrap(), m);
            assert_eq!(m.wire_size(), b.len(), "wire_size must be exact for {m:?}");
        }
    }

    #[test]
    fn flood_reaches_line_within_hop_limit() {
        let (ps, mut engines) = line_topology(10, 2);
        let topic = Topic::named("contrib");
        let origin = ps[0];
        let mut out = Sends::new();
        engines
            .get_mut(&origin)
            .unwrap()
            .publish(Nanos(0), topic, b"new-head".to_vec(), &mut out);
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (origin, t, m)).collect();
        settle(&mut engines, queue);
        for p in &ps[1..] {
            let e = engines.get(p).unwrap();
            assert_eq!(e.deliveries.len(), 1, "peer did not receive");
            assert_eq!(&e.deliveries[0].data[..], &b"new-head"[..]);
        }
    }

    #[test]
    fn hop_limit_bounds_line() {
        let (ps, mut engines) = line_topology(MAX_HOPS as usize + 5, 3);
        let topic = Topic::named("contrib");
        let origin = ps[0];
        let mut out = Sends::new();
        engines.get_mut(&origin).unwrap().publish(Nanos(0), topic, b"x".to_vec(), &mut out);
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (origin, t, m)).collect();
        settle(&mut engines, queue);
        // The peer beyond the hop limit never hears the message.
        let last = ps.last().unwrap();
        assert_eq!(engines.get(last).unwrap().deliveries.len(), 0);
        // But a peer within the limit does.
        assert_eq!(engines.get(&ps[MAX_HOPS as usize]).unwrap().deliveries.len(), 1);
    }

    #[test]
    fn dedup_on_cyclic_topology() {
        let ps = ids(3, 4);
        let topic = Topic::named("t");
        let mut engines: HashMap<PeerId, Engine> =
            ps.iter().map(|p| (*p, Engine::new(*p))).collect();
        let mut queue = Vec::new();
        for p in &ps {
            let nbrs: Vec<PeerId> = ps.iter().filter(|q| *q != p).copied().collect();
            let e = engines.get_mut(p).unwrap();
            let mut out = Sends::new();
            e.subscribe(topic, &mut out);
            e.set_neighbors(nbrs, &mut out);
            for (t, m) in out {
                queue.push((*p, t, m));
            }
        }
        settle(&mut engines, queue);
        let mut out = Sends::new();
        engines.get_mut(&ps[0]).unwrap().publish(Nanos(0), topic, b"x".to_vec(), &mut out);
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (ps[0], t, m)).collect();
        settle(&mut engines, queue);
        // Each of the other two gets exactly one delivery despite the cycle.
        for p in &ps[1..] {
            assert_eq!(engines.get(p).unwrap().deliveries.len(), 1);
        }
        let dups: u64 = ps.iter().map(|p| engines.get(p).unwrap().duplicates).sum();
        assert!(dups > 0, "cycle should produce suppressed duplicates");
    }

    #[test]
    fn unsubscribed_topic_not_delivered() {
        let ps = ids(2, 5);
        let mut a = Engine::new(ps[0]);
        let mut b = Engine::new(ps[1]);
        let mut out = Sends::new();
        a.set_neighbors(vec![ps[1]], &mut out);
        b.set_neighbors(vec![ps[0]], &mut out);
        let t_sub = Topic::named("yes");
        let t_other = Topic::named("no");
        b.subscribe(t_sub, &mut out);
        // Simulate b's subscription reaching a.
        a.on_msg(Nanos(0), ps[1], Msg::Subscriptions { topics: vec![t_sub] }, &mut out);
        out.clear();
        a.publish(Nanos(0), t_other, b"m".to_vec(), &mut out);
        assert!(out.is_empty(), "b is not subscribed to t_other");
        assert_eq!(a.forwarded, 0, "zero-send publish must not count as forwarded");
        a.publish(Nanos(0), t_sub, b"m".to_vec(), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(a.forwarded, 1, "forwarded counts actual link sends");
    }

    #[test]
    fn forwarded_counts_actual_sends_on_relay() {
        // A relay with no subscribed neighbors forwards nothing and the
        // counter must say so (the redundancy denominator's honesty).
        let ps = ids(2, 51);
        let mut e = Engine::new(ps[0]);
        let mut out = Sends::new();
        let t = Topic::named("t");
        e.subscribe(t, &mut out);
        out.clear();
        let m = Msg::Publish { topic: t, origin: ps[1], seq: 1, hops: 0, data: b"x".into() };
        e.on_msg(Nanos(0), ps[1], m, &mut out);
        assert_eq!(e.deliveries.len(), 1);
        assert!(out.is_empty());
        assert_eq!(e.forwarded, 0, "no receivers → no forwards counted");
    }

    #[test]
    fn forwarding_shares_the_payload_allocation() {
        // Zero-copy: every frame flooded out carries the same refcounted
        // allocation as the frame that came in.
        let ps = ids(4, 52);
        let t = Topic::named("t");
        let mut e = Engine::new(ps[0]);
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        e.set_neighbors(vec![ps[1], ps[2], ps[3]], &mut out);
        for p in &ps[1..] {
            e.on_msg(Nanos(0), *p, Msg::Subscriptions { topics: vec![t] }, &mut out);
        }
        out.clear();
        let payload: Blob = b"shared-bytes".into();
        let m = Msg::Publish { topic: t, origin: ps[1], seq: 7, hops: 0, data: payload.clone() };
        e.on_msg(Nanos(0), ps[1], m, &mut out);
        assert_eq!(out.len(), 2, "forwarded to the two other subscribed neighbors");
        for (_, fwd) in &out {
            let Msg::Publish { data, .. } = fwd else { panic!("expected Publish") };
            assert!(Blob::ptr_eq(data, &payload), "forwarding must clone the pointer");
        }
        assert!(Blob::ptr_eq(&e.deliveries[0].data, &payload), "delivery shares it too");
    }

    #[test]
    fn seen_cache_expires() {
        // Flood mode: after the seen-cache TTL a redelivery is accepted
        // again (upper layers dedupe by content). The mesh replaces this
        // with mcache-backed suppression — see
        // `mesh_expiry_redelivery_deduped_by_mcache`.
        let ps = ids(2, 6);
        let mut e = Engine::new(ps[0]);
        let mut out = Sends::new();
        let t = Topic::named("t");
        e.subscribe(t, &mut out);
        let m = Msg::Publish { topic: t, origin: ps[1], seq: 1, hops: 0, data: Blob::empty() };
        e.on_msg(Nanos(0), ps[1], m.clone(), &mut out);
        assert_eq!(e.deliveries.len(), 1);
        e.tick(Nanos(200_000_000_000), &mut out); // 200 s later
        assert!(out.is_empty(), "flood-mode tick must stay send-free");
        e.on_msg(Nanos(200_000_000_000), ps[1], m, &mut out);
        // Cache expired → delivered again (upper layers dedupe by content).
        assert_eq!(e.deliveries.len(), 2);
    }

    // ------------------------------------------------------------------
    // Mesh mode
    // ------------------------------------------------------------------

    fn mesh_engine(own: PeerId) -> Engine {
        let mut e = Engine::new(own);
        e.enable_mesh(MeshConfig::default());
        e
    }

    /// A mesh engine with `n` subscribed neighbors and one heartbeat
    /// already run (mesh formed). Returns (engine, topic, neighbors).
    fn meshed(n: usize, seed: u64) -> (Engine, Topic, Vec<PeerId>) {
        let ps = ids(n + 1, seed);
        let mut e = mesh_engine(ps[0]);
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        e.set_neighbors(ps[1..].to_vec(), &mut out);
        for p in &ps[1..] {
            e.on_msg(Nanos(0), *p, Msg::Subscriptions { topics: vec![t] }, &mut out);
        }
        out.clear();
        e.tick(Nanos(1_000_000_000), &mut out); // first heartbeat: graft
        (e, t, ps[1..].to_vec())
    }

    #[test]
    fn heartbeat_grafts_to_target_degree() {
        let (e, t, _) = meshed(5, 7);
        let cfg = MeshConfig::default();
        assert_eq!(e.mesh_members(t).len(), cfg.degree, "mesh formed at target degree");
        assert_eq!(e.grafts, cfg.degree as u64);
    }

    #[test]
    fn publish_pushes_eagerly_only_to_mesh() {
        let (mut e, t, _) = meshed(5, 8);
        let members: BTreeSet<PeerId> = e.mesh_members(t).into_iter().collect();
        let mut out = Sends::new();
        e.publish(Nanos(2_000_000_000), t, b"head".to_vec(), &mut out);
        assert_eq!(out.len(), members.len(), "one eager frame per mesh member");
        for (to, m) in &out {
            assert!(members.contains(to), "eager push went outside the mesh");
            assert!(matches!(m, Msg::Publish { .. }));
        }
        assert_eq!(e.forwarded, members.len() as u64);
    }

    #[test]
    fn heartbeat_advertises_lazily_to_non_mesh_subscribers() {
        let (mut e, t, nbrs) = meshed(5, 9);
        let members: BTreeSet<PeerId> = e.mesh_members(t).into_iter().collect();
        let lazy: BTreeSet<PeerId> =
            nbrs.iter().filter(|p| !members.contains(*p)).copied().collect();
        assert!(!lazy.is_empty(), "test needs non-mesh subscribers");
        let mut out = Sends::new();
        // Publish and flush inside the record TTL (the fake neighbors
        // never re-announce, so their records expire three heartbeats
        // after the t=0 subscription exchange).
        e.publish(Nanos(1_500_000_000), t, b"head".to_vec(), &mut out);
        out.clear();
        e.tick(Nanos(2_500_000_000), &mut out);
        let ihaves: Vec<&PeerId> = out
            .iter()
            .filter(|(_, m)| matches!(m, Msg::IHave { .. }))
            .map(|(to, _)| to)
            .collect();
        assert_eq!(ihaves.len(), lazy.len(), "one IHave per lazy subscriber");
        for to in ihaves {
            assert!(lazy.contains(to));
        }
        assert_eq!(e.ihave_sent, lazy.len() as u64);
        // The batch drained: the next heartbeat advertises nothing new.
        out.clear();
        e.tick(Nanos(4_000_000_000), &mut out);
        assert!(out.iter().all(|(_, m)| !matches!(m, Msg::IHave { .. })));
    }

    #[test]
    fn iwant_pull_completes_delivery() {
        let ps = ids(2, 10);
        let (a, b) = (ps[0], ps[1]);
        let mut ea = mesh_engine(a);
        let mut eb = mesh_engine(b);
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        ea.subscribe(t, &mut out);
        eb.subscribe(t, &mut out);
        out.clear();
        // a publishes with an empty mesh: the frame only enters a's cache.
        ea.publish(Nanos(0), t, b"pulled".to_vec(), &mut out);
        assert!(out.is_empty(), "no mesh members yet — nothing pushed");
        // b hears the advertisement and pulls.
        let ihave =
            Msg::IHave { topic: t, ids: vec![MsgId { origin: a, seq: 1 }] };
        eb.on_msg(Nanos(0), a, ihave, &mut out);
        assert_eq!(out.len(), 1);
        let (to, iwant) = out.remove(0);
        assert_eq!(to, a);
        assert!(matches!(iwant, Msg::IWant { .. }));
        ea.on_msg(Nanos(0), b, iwant, &mut out);
        assert_eq!(out.len(), 1, "cache must serve the pull");
        assert_eq!(ea.iwant_served, 1);
        let (to, frame) = out.remove(0);
        assert_eq!(to, b);
        eb.on_msg(Nanos(0), a, frame, &mut out);
        assert_eq!(eb.deliveries.len(), 1);
        assert_eq!(&eb.deliveries[0].data[..], &b"pulled"[..]);
        assert!(eb.has_delivered(a, 1));
        // A second IHave for the same id draws no second pull.
        let ihave2 =
            Msg::IHave { topic: t, ids: vec![MsgId { origin: a, seq: 1 }] };
        eb.on_msg(Nanos(0), a, ihave2, &mut out);
        assert!(out.is_empty(), "already seen — no re-request");
    }

    #[test]
    fn heartbeat_prunes_above_high_watermark() {
        let ps = ids(9, 11);
        let mut e = mesh_engine(ps[0]);
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        e.set_neighbors(ps[1..].to_vec(), &mut out);
        // Every neighbor grafts us: mesh overshoots the high watermark.
        for p in &ps[1..] {
            e.on_msg(Nanos(0), *p, Msg::Graft { topic: t }, &mut out);
        }
        let cfg = MeshConfig::default();
        assert_eq!(e.mesh_members(t).len(), 8);
        assert!(e.mesh_members(t).len() > cfg.degree_high);
        out.clear();
        e.tick(Nanos(1_000_000_000), &mut out);
        assert_eq!(e.mesh_members(t).len(), cfg.degree, "pruned back to target degree");
        let prunes = out.iter().filter(|(_, m)| matches!(m, Msg::Prune { .. })).count();
        assert_eq!(prunes, 8 - cfg.degree, "a Prune frame per removed member");
        assert_eq!(e.prunes, (8 - cfg.degree) as u64);
    }

    #[test]
    fn graft_on_unsubscribed_topic_is_refused_with_prune() {
        let ps = ids(2, 12);
        let mut e = mesh_engine(ps[0]);
        let t = Topic::named("never-subscribed");
        let mut out = Sends::new();
        e.on_msg(Nanos(0), ps[1], Msg::Graft { topic: t }, &mut out);
        assert_eq!(out, vec![(ps[1], Msg::Prune { topic: t })]);
        assert!(e.mesh_members(t).is_empty());
    }

    #[test]
    fn mesh_expiry_redelivery_deduped_by_mcache() {
        // The satellite regression: in mesh mode a seen-cache expiry no
        // longer double-counts a redelivery — the message cache (still
        // inside its window horizon) suppresses it as a duplicate.
        let ps = ids(2, 13);
        let mut e = mesh_engine(ps[0]);
        let t = Topic::named("t");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        let m = Msg::Publish { topic: t, origin: ps[1], seq: 1, hops: 0, data: b"d".into() };
        e.on_msg(Nanos(0), ps[1], m.clone(), &mut out);
        assert_eq!(e.deliveries.len(), 1);
        assert_eq!(e.delivered, 1);
        // 200 s later the seen-cache entry is gone (TTL 120 s); one
        // heartbeat has rotated the cache a single window — well inside
        // the history horizon.
        out.clear();
        e.tick(Nanos(200_000_000_000), &mut out);
        e.on_msg(Nanos(200_000_000_000), ps[1], m, &mut out);
        assert_eq!(e.deliveries.len(), 1, "redelivery must be suppressed");
        assert_eq!(e.delivered, 1, "delivered must not double-count");
        assert_eq!(e.duplicates, 1, "suppression counts as a duplicate");
    }

    #[test]
    fn mcache_rotates_out_past_the_history_horizon() {
        let ps = ids(2, 14);
        let mut e = mesh_engine(ps[0]);
        let t = Topic::named("t");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        let m = Msg::Publish { topic: t, origin: ps[1], seq: 1, hops: 0, data: b"d".into() };
        e.on_msg(Nanos(0), ps[1], m, &mut out);
        // Run past `history_windows` heartbeats: the cached payload is
        // dropped and an IWant for it goes unanswered.
        for k in 1..=(MeshConfig::default().history_windows as u64 + 1) {
            e.tick(Nanos(k * 1_000_000_000), &mut out);
        }
        out.clear();
        let iwant = Msg::IWant { ids: vec![MsgId { origin: ps[1], seq: 1 }] };
        e.on_msg(Nanos(10_000_000_000), ps[0], iwant, &mut out);
        assert!(out.is_empty(), "rotated-out id must not be served");
        assert_eq!(e.iwant_served, 0);
    }

    #[test]
    fn subscription_from_unknown_peer_held_provisional_in_mesh_mode() {
        // The churn regression: a late Subscriptions frame from a
        // departed (sampled-out, table-evicted) peer must not resurrect
        // it into the broadcast set. Mesh mode holds it as an expiring
        // provisional record: worth at most a graft attempt and a few
        // heartbeats of digests at the dead address, never a flood
        // edge — and once the record expires and the lease sweeps any
        // dangling graft, nothing targets the peer at all.
        let ps = ids(3, 15);
        let (own, nbr, departed) = (ps[0], ps[1], ps[2]);
        let mut e = mesh_engine(own);
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        e.set_neighbors(vec![nbr], &mut out);
        e.on_msg(Nanos(0), nbr, Msg::Subscriptions { topics: vec![t] }, &mut out);
        // The departed peer's late frame arrives after pruning.
        e.on_msg(Nanos(0), departed, Msg::Subscriptions { topics: vec![t] }, &mut out);
        assert!(!e.neighbors().contains(&departed), "no resurrection");
        assert!(e.neighbor_topics.contains_key(&departed), "held as an expiring record");
        // With no further announcements the record dies on its
        // freshness clock within RECORD_TTL_HEARTBEATS.
        for k in 1..=4u64 {
            out.clear();
            e.tick(Nanos(k * 1_000_000_000), &mut out);
        }
        assert!(!e.neighbor_topics.contains_key(&departed), "silent record expired");
        // Past the graft lease the dead address is fully unstuck:
        // neither eager frames nor lazy digests go its way.
        out.clear();
        e.tick(Nanos(70_000_000_000), &mut out); // lease sweep
        out.clear();
        e.publish(Nanos(70_500_000_000), t, b"x".to_vec(), &mut out);
        e.tick(Nanos(71_500_000_000), &mut out); // flush IHAVEs too
        assert!(
            out.iter().all(|(to, _)| *to != departed),
            "departed peer must receive neither eager frames nor IHaves"
        );
        // Flood mode, by contrast, keeps the legacy discovery insert.
        let mut f = Engine::new(own);
        f.subscribe(t, &mut out);
        f.on_msg(Nanos(0), departed, Msg::Subscriptions { topics: vec![t] }, &mut out);
        assert!(f.neighbors().contains(&departed), "flood keeps the legacy edge");
    }

    #[test]
    fn graft_candidates_come_from_records_not_the_sample() {
        // The asymmetric-sampling liveness property: a peer that
        // samples *us* (we never sampled it) announces its
        // subscriptions, and that record alone must make it graftable
        // and a lazy-digest target — requiring candidates to sit in our
        // own continuously reshuffled sample would starve the mesh.
        let ps = ids(3, 18);
        let (own, r1, r2) = (ps[0], ps[1], ps[2]);
        let mut e = Engine::new(own);
        e.enable_mesh(MeshConfig {
            degree: 1,
            degree_low: 1,
            degree_high: 2,
            ..MeshConfig::default()
        });
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        assert!(e.neighbors().is_empty(), "we sample nobody in this test");
        e.on_msg(Nanos(0), r1, Msg::Subscriptions { topics: vec![t] }, &mut out);
        e.on_msg(Nanos(0), r2, Msg::Subscriptions { topics: vec![t] }, &mut out);
        out.clear();
        e.tick(Nanos(1_000_000_000), &mut out);
        let grafted: Vec<PeerId> = out
            .iter()
            .filter(|(_, m)| matches!(m, Msg::Graft { .. }))
            .map(|(to, _)| *to)
            .collect();
        let best = if mesh_rank(own, r1) <= mesh_rank(own, r2) { r1 } else { r2 };
        let other = if best == r1 { r2 } else { r1 };
        assert_eq!(grafted, vec![best], "rank-preferred record holder grafted");
        assert_eq!(e.mesh_members(t), vec![best]);
        // The ungrafted record holder is the lazy tier: it gets the digest.
        out.clear();
        e.publish(Nanos(1_500_000_000), t, b"head".to_vec(), &mut out);
        e.tick(Nanos(2_500_000_000), &mut out);
        assert!(
            out.iter().any(|(to, m)| *to == other && matches!(m, Msg::IHave { .. })),
            "record holder outside the mesh must draw the lazy digest"
        );
    }

    #[test]
    fn lazy_fanout_bounded_by_lazy_degree() {
        let ps = ids(9, 19);
        let mut e = Engine::new(ps[0]);
        e.enable_mesh(MeshConfig {
            degree: 1,
            degree_low: 1,
            degree_high: 2,
            lazy_degree: 4,
            ..MeshConfig::default()
        });
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        for p in &ps[1..] {
            e.on_msg(Nanos(0), *p, Msg::Subscriptions { topics: vec![t] }, &mut out);
        }
        out.clear();
        e.tick(Nanos(1_000_000_000), &mut out); // grafts 1 of the 8 records
        out.clear();
        e.publish(Nanos(1_500_000_000), t, b"head".to_vec(), &mut out);
        out.clear();
        e.tick(Nanos(2_500_000_000), &mut out);
        let ihave_to: BTreeSet<PeerId> = out
            .iter()
            .filter(|(_, m)| matches!(m, Msg::IHave { .. }))
            .map(|(to, _)| *to)
            .collect();
        assert_eq!(ihave_to.len(), 4, "digest fan-out capped at lazy_degree");
        assert_eq!(e.ihave_sent, 4);
        // The cap keeps the rank-preferred holders, deterministically.
        let members: BTreeSet<PeerId> = e.mesh_members(t).into_iter().collect();
        let mut expect: Vec<PeerId> =
            ps[1..].iter().filter(|p| !members.contains(*p)).copied().collect();
        expect.sort_by_key(|p| mesh_rank(ps[0], *p));
        expect.truncate(4);
        assert_eq!(ihave_to, expect.into_iter().collect::<BTreeSet<PeerId>>());
    }

    #[test]
    fn heartbeat_reannounces_subscriptions_each_beat() {
        // Step 0 of the heartbeat is the record-refresh channel:
        // without it every record would expire within
        // RECORD_TTL_HEARTBEATS of the initial exchange and the mesh
        // would starve as soon as the neighbor sample reshuffles.
        let (mut e, t, nbrs) = meshed(5, 20);
        let mut out = Sends::new();
        e.tick(Nanos(2_000_000_000), &mut out);
        for p in &nbrs {
            assert!(
                out.iter().any(|(to, m)| to == p
                    && matches!(m, Msg::Subscriptions { topics } if topics == &vec![t])),
                "every sampled neighbor must be re-announced to"
            );
        }
    }

    #[test]
    fn departed_mesh_member_is_swept_after_lease_expiry() {
        let ps = ids(2, 16);
        let (own, remote) = (ps[0], ps[1]);
        let mut e = mesh_engine(own);
        let t = Topic::named("contrib");
        let mut out = Sends::new();
        e.subscribe(t, &mut out);
        // A remote graft from a peer we never sampled: accepted on a lease.
        e.on_msg(Nanos(0), remote, Msg::Graft { topic: t }, &mut out);
        assert_eq!(e.mesh_members(t), vec![remote]);
        // Within the lease it survives heartbeats despite not being a
        // sampled neighbor.
        out.clear();
        e.tick(Nanos(1_000_000_000), &mut out);
        assert_eq!(e.mesh_members(t), vec![remote]);
        // Past the lease with no traffic it is swept (and Pruned).
        let later = Nanos(70_000_000_000); // > 60 s lease
        e.tick(later, &mut out);
        assert!(e.mesh_members(t).is_empty(), "dead member swept");
        assert!(out.iter().any(|(to, m)| *to == remote && matches!(m, Msg::Prune { .. })));
        out.clear();
        e.publish(Nanos(71_000_000_000), t, b"x".to_vec(), &mut out);
        assert!(out.iter().all(|(to, _)| *to != remote));
    }

    #[test]
    fn mesh_line_topology_delivers_end_to_end() {
        // Mesh engines on a 10-node line: after a heartbeat round the
        // meshes cover the line links and a publish reaches everyone.
        let ps = ids(10, 17);
        let mut engines: HashMap<PeerId, Engine> =
            ps.iter().map(|p| (*p, mesh_engine(*p))).collect();
        let topic = Topic::named("contrib");
        let mut queue = Vec::new();
        for (i, p) in ps.iter().enumerate() {
            let mut nbrs = Vec::new();
            if i > 0 {
                nbrs.push(ps[i - 1]);
            }
            if i + 1 < ps.len() {
                nbrs.push(ps[i + 1]);
            }
            let e = engines.get_mut(p).unwrap();
            let mut out = Sends::new();
            e.subscribe(topic, &mut out);
            e.set_neighbors(nbrs, &mut out);
            for (t, m) in out {
                queue.push((*p, t, m));
            }
        }
        settle(&mut engines, queue);
        // One heartbeat round: everyone grafts its line adjacents.
        let mut queue = Vec::new();
        for p in &ps {
            let e = engines.get_mut(p).unwrap();
            let mut out = Sends::new();
            e.tick(Nanos(1_000_000_000), &mut out);
            for (t, m) in out {
                queue.push((*p, t, m));
            }
        }
        settle(&mut engines, queue);
        let mut out = Sends::new();
        engines.get_mut(&ps[0]).unwrap().publish(
            Nanos(2_000_000_000),
            topic,
            b"head".to_vec(),
            &mut out,
        );
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (ps[0], t, m)).collect();
        settle(&mut engines, queue);
        for p in &ps[1..] {
            assert_eq!(engines.get(p).unwrap().deliveries.len(), 1, "line member missed");
        }
    }
}

//! Floodsub-style publish/subscribe.
//!
//! Used by the replication layer to announce new store heads (OrbitDB
//! does the same over libp2p pubsub). Peers exchange subscriptions with
//! their neighbors; published messages flood along subscribed links with
//! a seen-cache for deduplication and a hop limit as a safety valve.

use crate::codec::bin::{bytes_len, varint_len, Decode, DecodeError, Encode, Reader, Writer};
use crate::net::{PeerId, WireSize};
use crate::util::time::{Duration, Nanos};
use std::collections::{BTreeSet, HashMap};

/// A topic is the hash of its name (store address).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Topic(pub u64);

impl Topic {
    pub fn named(name: &str) -> Topic {
        use sha2::{Digest, Sha256};
        let d: [u8; 32] = Sha256::digest(name.as_bytes()).into();
        Topic(u64::from_le_bytes(d[..8].try_into().unwrap()))
    }
}

impl Encode for Topic {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.0);
    }
}
impl Decode for Topic {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Topic(r.get_u64()?))
    }
}

pub const MAX_HOPS: u8 = 16;

/// Pubsub wire messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Announce our subscriptions to a neighbor.
    Subscriptions { topics: Vec<Topic> },
    /// Flooded application message.
    Publish {
        topic: Topic,
        origin: PeerId,
        seq: u64,
        hops: u8,
        data: Vec<u8>,
    },
}

impl Encode for Msg {
    fn encode(&self, w: &mut Writer) {
        match self {
            Msg::Subscriptions { topics } => {
                w.put_u8(0);
                topics.encode(w);
            }
            Msg::Publish { topic, origin, seq, hops, data } => {
                w.put_u8(1);
                topic.encode(w);
                origin.encode(w);
                w.put_varint(*seq);
                w.put_u8(*hops);
                w.put_bytes(data);
            }
        }
    }
}

impl Decode for Msg {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(match r.get_u8()? {
            0 => Msg::Subscriptions { topics: Vec::decode(r)? },
            1 => Msg::Publish {
                topic: Topic::decode(r)?,
                origin: PeerId::decode(r)?,
                seq: r.get_varint()?,
                hops: r.get_u8()?,
                data: r.get_bytes()?.to_vec(),
            },
            _ => return Err(DecodeError("bad pubsub tag")),
        })
    }
}

impl WireSize for Msg {
    /// Exact encoded length in O(1) (topics are fixed 8-byte hashes;
    /// `Publish` adds origin, varint seq, hop byte and the payload).
    /// Property-tested against the real encoding in `tests/prop.rs`.
    fn wire_size(&self) -> usize {
        match self {
            Msg::Subscriptions { topics } => 1 + varint_len(topics.len() as u64) + topics.len() * 8,
            Msg::Publish { seq, data, .. } => {
                1 + 8 + 32 + varint_len(*seq) + 1 + bytes_len(data.len())
            }
        }
    }
}

/// Message delivered to the local node.
#[derive(Clone, Debug)]
pub struct Delivery {
    pub topic: Topic,
    pub origin: PeerId,
    pub data: Vec<u8>,
}

/// Floodsub engine. One per node.
pub struct Engine {
    own: PeerId,
    subscriptions: BTreeSet<Topic>,
    /// Known neighbor subscriptions.
    neighbor_topics: HashMap<PeerId, BTreeSet<Topic>>,
    neighbors: BTreeSet<PeerId>,
    seen: HashMap<(PeerId, u64), Nanos>,
    seen_ttl: Duration,
    next_seq: u64,
    pub deliveries: Vec<Delivery>,
    pub published: u64,
    pub forwarded: u64,
    pub duplicates: u64,
}

pub type Sends = Vec<(PeerId, Msg)>;

impl Engine {
    pub fn new(own: PeerId) -> Self {
        Engine {
            own,
            subscriptions: BTreeSet::new(),
            neighbor_topics: HashMap::new(),
            neighbors: BTreeSet::new(),
            seen: HashMap::new(),
            seen_ttl: Duration::from_secs(120),
            next_seq: 1,
            deliveries: Vec::new(),
            published: 0,
            forwarded: 0,
            duplicates: 0,
        }
    }

    pub fn subscribe(&mut self, topic: Topic, out: &mut Sends) {
        if self.subscriptions.insert(topic) {
            self.broadcast_subscriptions(out);
        }
    }

    pub fn subscriptions(&self) -> Vec<Topic> {
        self.subscriptions.iter().copied().collect()
    }

    /// Update the neighbor set (fed from the DHT routing table). New
    /// neighbors get our subscription list.
    pub fn set_neighbors(&mut self, peers: Vec<PeerId>, out: &mut Sends) {
        let new: Vec<PeerId> = peers
            .iter()
            .filter(|p| !self.neighbors.contains(*p) && **p != self.own)
            .copied()
            .collect();
        self.neighbors = peers.into_iter().filter(|p| *p != self.own).collect();
        self.neighbor_topics.retain(|p, _| self.neighbors.contains(p));
        if !self.subscriptions.is_empty() {
            for p in new {
                out.push((
                    p,
                    Msg::Subscriptions { topics: self.subscriptions() },
                ));
            }
        }
    }

    pub fn neighbors(&self) -> &BTreeSet<PeerId> {
        &self.neighbors
    }

    fn broadcast_subscriptions(&mut self, out: &mut Sends) {
        let topics = self.subscriptions();
        for p in &self.neighbors {
            out.push((*p, Msg::Subscriptions { topics: topics.clone() }));
        }
    }

    /// Publish `data` on `topic`, flooding to subscribed neighbors.
    pub fn publish(&mut self, now: Nanos, topic: Topic, data: Vec<u8>, out: &mut Sends) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.published += 1;
        self.seen.insert((self.own, seq), now);
        let msg = Msg::Publish { topic, origin: self.own, seq, hops: 0, data };
        self.flood(&msg, None, out);
    }

    fn flood(&mut self, msg: &Msg, skip: Option<PeerId>, out: &mut Sends) {
        let Msg::Publish { topic, .. } = msg else { return };
        for p in &self.neighbors {
            if Some(*p) == skip {
                continue;
            }
            let subscribed = self
                .neighbor_topics
                .get(p)
                .map(|t| t.contains(topic))
                .unwrap_or(false);
            if subscribed {
                out.push((*p, msg.clone()));
            }
        }
    }

    pub fn on_msg(&mut self, now: Nanos, from: PeerId, msg: Msg, out: &mut Sends) {
        match msg {
            Msg::Subscriptions { topics } => {
                self.neighbors.insert(from);
                self.neighbor_topics.insert(from, topics.into_iter().collect());
            }
            Msg::Publish { topic, origin, seq, hops, data } => {
                if self.seen.contains_key(&(origin, seq)) {
                    self.duplicates += 1;
                    return;
                }
                self.seen.insert((origin, seq), now);
                if self.subscriptions.contains(&topic) {
                    self.deliveries.push(Delivery { topic, origin, data: data.clone() });
                }
                if hops < MAX_HOPS {
                    self.forwarded += 1;
                    let fwd = Msg::Publish { topic, origin, seq, hops: hops + 1, data };
                    self.flood(&fwd, Some(from), out);
                }
            }
        }
    }

    /// Expire the seen-cache.
    pub fn tick(&mut self, now: Nanos) {
        let ttl = self.seen_ttl;
        self.seen.retain(|_, t| now.saturating_sub(*t) < ttl);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ids(n: usize, seed: u64) -> Vec<PeerId> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| PeerId::from_rng(&mut rng)).collect()
    }

    /// Deliver messages synchronously until quiet.
    fn settle(engines: &mut HashMap<PeerId, Engine>, mut queue: Vec<(PeerId, PeerId, Msg)>) {
        let mut hops = 0;
        while let Some((from, to, msg)) = queue.pop() {
            hops += 1;
            assert!(hops < 100_000);
            let mut out = Sends::new();
            if let Some(e) = engines.get_mut(&to) {
                e.on_msg(Nanos(0), from, msg, &mut out);
            }
            for (t, m) in out {
                queue.push((to, t, m));
            }
        }
    }

    fn line_topology(n: usize, seed: u64) -> (Vec<PeerId>, HashMap<PeerId, Engine>) {
        let ps = ids(n, seed);
        let mut engines: HashMap<PeerId, Engine> =
            ps.iter().map(|p| (*p, Engine::new(*p))).collect();
        let topic = Topic::named("contrib");
        let mut queue = Vec::new();
        // Each node neighbors its line adjacents; all subscribe.
        for (i, p) in ps.iter().enumerate() {
            let mut nbrs = Vec::new();
            if i > 0 {
                nbrs.push(ps[i - 1]);
            }
            if i + 1 < ps.len() {
                nbrs.push(ps[i + 1]);
            }
            let e = engines.get_mut(p).unwrap();
            let mut out = Sends::new();
            e.subscribe(topic, &mut out);
            e.set_neighbors(nbrs, &mut out);
            for (t, m) in out {
                queue.push((*p, t, m));
            }
        }
        settle(&mut engines, queue);
        (ps, engines)
    }

    #[test]
    fn msg_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Msg::Publish {
            topic: Topic::named("x"),
            origin: PeerId::from_rng(&mut rng),
            seq: 9,
            hops: 3,
            data: b"heads".to_vec(),
        };
        let b = crate::codec::to_bytes(&m);
        assert_eq!(crate::codec::from_bytes::<Msg>(&b).unwrap(), m);
        assert_eq!(m.wire_size(), b.len(), "wire_size must be exact");
    }

    #[test]
    fn flood_reaches_line_within_hop_limit() {
        let (ps, mut engines) = line_topology(10, 2);
        let topic = Topic::named("contrib");
        let origin = ps[0];
        let mut out = Sends::new();
        engines
            .get_mut(&origin)
            .unwrap()
            .publish(Nanos(0), topic, b"new-head".to_vec(), &mut out);
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (origin, t, m)).collect();
        settle(&mut engines, queue);
        for p in &ps[1..] {
            let e = engines.get(p).unwrap();
            assert_eq!(e.deliveries.len(), 1, "peer did not receive");
            assert_eq!(e.deliveries[0].data, b"new-head");
        }
    }

    #[test]
    fn hop_limit_bounds_line() {
        let (ps, mut engines) = line_topology(MAX_HOPS as usize + 5, 3);
        let topic = Topic::named("contrib");
        let origin = ps[0];
        let mut out = Sends::new();
        engines.get_mut(&origin).unwrap().publish(Nanos(0), topic, b"x".to_vec(), &mut out);
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (origin, t, m)).collect();
        settle(&mut engines, queue);
        // The peer beyond the hop limit never hears the message.
        let last = ps.last().unwrap();
        assert_eq!(engines.get(last).unwrap().deliveries.len(), 0);
        // But a peer within the limit does.
        assert_eq!(engines.get(&ps[MAX_HOPS as usize]).unwrap().deliveries.len(), 1);
    }

    #[test]
    fn dedup_on_cyclic_topology() {
        let ps = ids(3, 4);
        let topic = Topic::named("t");
        let mut engines: HashMap<PeerId, Engine> =
            ps.iter().map(|p| (*p, Engine::new(*p))).collect();
        let mut queue = Vec::new();
        for p in &ps {
            let nbrs: Vec<PeerId> = ps.iter().filter(|q| *q != p).copied().collect();
            let e = engines.get_mut(p).unwrap();
            let mut out = Sends::new();
            e.subscribe(topic, &mut out);
            e.set_neighbors(nbrs, &mut out);
            for (t, m) in out {
                queue.push((*p, t, m));
            }
        }
        settle(&mut engines, queue);
        let mut out = Sends::new();
        engines.get_mut(&ps[0]).unwrap().publish(Nanos(0), topic, b"x".to_vec(), &mut out);
        let queue: Vec<_> = out.into_iter().map(|(t, m)| (ps[0], t, m)).collect();
        settle(&mut engines, queue);
        // Each of the other two gets exactly one delivery despite the cycle.
        for p in &ps[1..] {
            assert_eq!(engines.get(p).unwrap().deliveries.len(), 1);
        }
        let dups: u64 = ps.iter().map(|p| engines.get(p).unwrap().duplicates).sum();
        assert!(dups > 0, "cycle should produce suppressed duplicates");
    }

    #[test]
    fn unsubscribed_topic_not_delivered() {
        let ps = ids(2, 5);
        let mut a = Engine::new(ps[0]);
        let mut b = Engine::new(ps[1]);
        let mut out = Sends::new();
        a.set_neighbors(vec![ps[1]], &mut out);
        b.set_neighbors(vec![ps[0]], &mut out);
        let t_sub = Topic::named("yes");
        let t_other = Topic::named("no");
        b.subscribe(t_sub, &mut out);
        // Simulate b's subscription reaching a.
        a.on_msg(Nanos(0), ps[1], Msg::Subscriptions { topics: vec![t_sub] }, &mut out);
        out.clear();
        a.publish(Nanos(0), t_other, b"m".to_vec(), &mut out);
        assert!(out.is_empty(), "b is not subscribed to t_other");
        a.publish(Nanos(0), t_sub, b"m".to_vec(), &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn seen_cache_expires() {
        let ps = ids(2, 6);
        let mut e = Engine::new(ps[0]);
        let mut out = Sends::new();
        let t = Topic::named("t");
        e.subscribe(t, &mut out);
        let m = Msg::Publish { topic: t, origin: ps[1], seq: 1, hops: 0, data: vec![] };
        e.on_msg(Nanos(0), ps[1], m.clone(), &mut out);
        assert_eq!(e.deliveries.len(), 1);
        e.tick(Nanos(200_000_000_000)); // 200 s later
        e.on_msg(Nanos(200_000_000_000), ps[1], m, &mut out);
        // Cache expired → delivered again (upper layers dedupe by content).
        assert_eq!(e.deliveries.len(), 2);
    }
}

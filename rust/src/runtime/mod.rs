//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs Python once; afterwards this module is the only
//! bridge to the compiled computations — the request path never touches
//! Python. Artifacts are HLO *text* (see `python/compile/aot.py` for why)
//! loaded via `HloModuleProto::from_text_file`, compiled on the PJRT CPU
//! client, and kept as loaded executables for repeated invocation.

use crate::codec::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Shape contract shared with `python/compile/aot.py` (meta.json).
#[derive(Clone, Debug, PartialEq)]
pub struct Meta {
    pub batch: usize,
    pub features: usize,
    pub hidden: usize,
    pub refset: usize,
    pub knn_k: usize,
}

impl Meta {
    fn from_json(j: &Json) -> Result<Meta> {
        let get = |k: &str| {
            j.path(k)
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("meta.json missing field {k}"))
        };
        Ok(Meta {
            batch: get("batch")?,
            features: get("features")?,
            hidden: get("hidden")?,
            refset: get("refset")?,
            knn_k: get("knn_k")?,
        })
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("loading HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

/// The collaborative performance model, loaded from artifacts and ready
/// to train/predict/score. Owns the current parameter literals.
pub struct PerfModel {
    pub meta: Meta,
    exe_init: xla::PjRtLoadedExecutable,
    exe_train: xla::PjRtLoadedExecutable,
    exe_predict: xla::PjRtLoadedExecutable,
    exe_knn: xla::PjRtLoadedExecutable,
    params: Vec<xla::Literal>,
}

impl PerfModel {
    /// Load + compile all artifacts from a directory (default
    /// `artifacts/`), then initialize parameters.
    pub fn load(dir: impl AsRef<Path>) -> Result<PerfModel> {
        let dir = dir.as_ref();
        let meta_text = std::fs::read_to_string(dir.join("meta.json")).with_context(|| {
            format!("reading {}/meta.json (run `make artifacts`)", dir.display())
        })?;
        let meta_json =
            Json::parse(&meta_text).map_err(|e| anyhow!("parsing meta.json: {e}"))?;
        let meta = Meta::from_json(&meta_json)?;
        let client = xla::PjRtClient::cpu()?;
        let p = |name: &str| -> PathBuf { dir.join(format!("{name}.hlo.txt")) };
        let exe_init = compile(&client, &p("init_params"))?;
        let exe_train = compile(&client, &p("train_step"))?;
        let exe_predict = compile(&client, &p("predict"))?;
        let exe_knn = compile(&client, &p("knn_score"))?;
        let mut model = PerfModel {
            meta,
            exe_init,
            exe_train,
            exe_predict,
            exe_knn,
            params: Vec::new(),
        };
        model.reset()?;
        Ok(model)
    }

    /// Re-initialize parameters (deterministic He init baked at AOT time).
    pub fn reset(&mut self) -> Result<()> {
        let result = self.exe_init.execute::<xla::Literal>(&[])?[0][0].to_literal_sync()?;
        self.params = result.to_tuple()?;
        if self.params.len() != 6 {
            bail!("init artifact returned {} params, want 6", self.params.len());
        }
        Ok(())
    }

    /// Number of trainable scalars (diagnostics).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.element_count()).sum()
    }

    fn check_batch(&self, xs: &[f32], ys_len: Option<usize>) -> Result<()> {
        let b = self.meta.batch;
        let d = self.meta.features;
        if xs.len() != b * d {
            bail!("x has {} values, compiled batch wants {}", xs.len(), b * d);
        }
        if let Some(n) = ys_len {
            if n != b {
                bail!("y/mask has {n} values, compiled batch wants {b}");
            }
        }
        Ok(())
    }

    /// One SGD step on a full (padded) batch; returns the masked loss.
    pub fn train_step(&mut self, xs: &[f32], ys: &[f32], mask: &[f32], lr: f32) -> Result<f32> {
        self.check_batch(xs, Some(ys.len()))?;
        let b = self.meta.batch as i64;
        let d = self.meta.features as i64;
        let x = xla::Literal::vec1(xs).reshape(&[b, d])?;
        let y = xla::Literal::vec1(ys);
        let m = xla::Literal::vec1(mask);
        let lr = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        inputs.push(&m);
        inputs.push(&lr);
        let result = self.exe_train.execute(&inputs)?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        if outs.len() != 7 {
            bail!("train artifact returned {} outputs, want 7", outs.len());
        }
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0];
        self.params = outs;
        Ok(loss)
    }

    /// Predict ln(runtime) for a full (padded) feature batch.
    pub fn predict(&self, xs: &[f32]) -> Result<Vec<f32>> {
        self.check_batch(xs, None)?;
        let b = self.meta.batch as i64;
        let d = self.meta.features as i64;
        let x = xla::Literal::vec1(xs).reshape(&[b, d])?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&x);
        let result = self.exe_predict.execute(&inputs)?[0][0].to_literal_sync()?;
        result.to_tuple1()?.to_vec::<f32>().map_err(Into::into)
    }

    /// k-NN novelty scores of a (padded) batch against a (padded)
    /// reference set — the validation scorer.
    pub fn knn_score(&self, xs: &[f32], refs: &[f32]) -> Result<Vec<f32>> {
        self.check_batch(xs, None)?;
        let (b, d, r) = (
            self.meta.batch as i64,
            self.meta.features as i64,
            self.meta.refset as i64,
        );
        if refs.len() != (r * d) as usize {
            bail!("refs has {} values, compiled refset wants {}", refs.len(), r * d);
        }
        let x = xla::Literal::vec1(xs).reshape(&[b, d])?;
        let rf = xla::Literal::vec1(refs).reshape(&[r, d])?;
        let result = self.exe_knn.execute::<xla::Literal>(&[x, rf])?[0][0].to_literal_sync()?;
        result.to_tuple1()?.to_vec::<f32>().map_err(Into::into)
    }

    /// Export current parameters (flattened) for checkpointing/sharing —
    /// collaborative *model* exchange, the paper's future-work extension.
    pub fn export_params(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(|p| p.to_vec::<f32>().map_err(Into::into)).collect()
    }
}

/// Padded-batch helpers shared by training workflows.
pub mod batching {
    /// Split rows into `(x, y, mask)` batches padded to `batch` rows.
    pub fn padded_batches(
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        batch: usize,
    ) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let n = ys.len();
        assert_eq!(xs.len(), n * dim);
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let take = batch.min(n - i);
            let mut bx = xs[i * dim..(i + take) * dim].to_vec();
            let mut by = ys[i..i + take].to_vec();
            let mut bm = vec![1.0f32; take];
            bx.resize(batch * dim, 0.0);
            by.resize(batch, 0.0);
            bm.resize(batch, 0.0);
            out.push((bx, by, bm));
            i += take;
        }
        out
    }

    #[cfg(test)]
    mod tests {
        #[test]
        fn pads_last_batch() {
            let xs: Vec<f32> = (0..10).map(|v| v as f32).collect();
            let ys: Vec<f32> = (0..5).map(|v| v as f32).collect();
            let batches = super::padded_batches(&xs, &ys, 2, 4);
            assert_eq!(batches.len(), 2);
            let (bx, by, bm) = &batches[1];
            assert_eq!(bx.len(), 8);
            assert_eq!(by.len(), 4);
            assert_eq!(bm, &vec![1.0, 0.0, 0.0, 0.0]);
        }
    }
}

//! JSON value model, parser and serializer.
//!
//! Used for config files, the HTTP API, and contribution metadata. The
//! parser is a straightforward recursive-descent over bytes with a depth
//! limit; numbers are f64 (adequate for config/metadata use).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so serialization is canonical.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

const MAX_DEPTH: usize = 128;

impl Json {
    // ----- constructors / accessors -------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(m) = &mut self {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch a nested field by dotted path, e.g. `"sim.latency_ms"`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ----- parse ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialize -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err("unexpected character"))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("max depth exceeded"));
        }
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf8"))?;
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path("d"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":1,"y":[true,false,"s\n"],"z":{"k":-2.5}}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::obj()
            .set("name", "peersdb")
            .set("peers", 32u64)
            .set("ok", true);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn dotted_path() {
        let j = Json::parse(r#"{"sim":{"latency_ms":42}}"#).unwrap();
        assert_eq!(j.path("sim.latency_ms").unwrap().as_u64(), Some(42));
        assert!(j.path("sim.missing").is_none());
    }
}

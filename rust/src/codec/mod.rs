//! Serialization: a canonical binary wire codec and a JSON implementation.
//!
//! The offline crate set has no `serde` facade, so both codecs are built
//! here. The binary codec ([`bin`]) is the wire + content-hash format —
//! it is *canonical* (one encoding per value), which matters because CIDs
//! are hashes of encoded bytes. JSON ([`json`]) is used for configuration
//! files, the HTTP API, and contribution payload metadata.

pub mod bin;
pub mod json;

pub use bin::{bytes_len, varint_len, Decode, Encode, Reader, Writer};
pub use json::Json;

/// Encode any `Encode` value to a fresh buffer.
pub fn to_bytes<T: Encode + ?Sized>(value: &T) -> Vec<u8> {
    let mut w = Writer::new();
    value.encode(&mut w);
    w.into_bytes()
}

/// Decode a value from a buffer, requiring full consumption.
pub fn from_bytes<T: Decode>(bytes: &[u8]) -> Result<T, bin::DecodeError> {
    let mut r = Reader::new(bytes);
    let v = T::decode(&mut r)?;
    r.finish()?;
    Ok(v)
}

//! Canonical binary codec: LEB128 varints, length-prefixed bytes/strings,
//! little-endian fixed floats. One valid encoding per value — encoded
//! bytes are safe to content-address.

/// Error produced when decoding malformed input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecodeError(pub &'static str);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "decode error: {}", self.0)
    }
}
impl std::error::Error for DecodeError {}

/// Append-only encode buffer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Unsigned LEB128.
    #[inline]
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                return;
            }
            self.buf.push(b | 0x80);
        }
    }

    #[inline]
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    #[inline]
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Raw bytes without a length prefix (fixed-size fields).
    #[inline]
    pub fn put_raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
}

/// Cursor over an encoded buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Error unless the buffer was fully consumed.
    pub fn finish(&self) -> Result<(), DecodeError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(DecodeError("trailing bytes"))
        }
    }

    #[inline]
    pub fn get_u8(&mut self) -> Result<u8, DecodeError> {
        if self.pos >= self.buf.len() {
            return Err(DecodeError("eof reading u8"));
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    #[inline]
    pub fn get_varint(&mut self) -> Result<u64, DecodeError> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.get_u8()?;
            if shift == 63 && b > 1 {
                return Err(DecodeError("varint overflow"));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                // canonical: no zero-padding continuation bytes
                if b == 0 && shift != 0 {
                    return Err(DecodeError("non-canonical varint"));
                }
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(DecodeError("varint too long"));
            }
        }
    }

    #[inline]
    pub fn get_u64(&mut self) -> Result<u64, DecodeError> {
        let raw = self.get_raw(8)?;
        Ok(u64::from_le_bytes(raw.try_into().unwrap()))
    }

    #[inline]
    pub fn get_f64(&mut self) -> Result<f64, DecodeError> {
        let raw = self.get_raw(8)?;
        Ok(f64::from_le_bytes(raw.try_into().unwrap()))
    }

    #[inline]
    pub fn get_f32(&mut self) -> Result<f32, DecodeError> {
        let raw = self.get_raw(4)?;
        Ok(f32::from_le_bytes(raw.try_into().unwrap()))
    }

    #[inline]
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError("eof reading raw"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    #[inline]
    pub fn get_bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() {
            return Err(DecodeError("length prefix beyond buffer"));
        }
        self.get_raw(n)
    }

    #[inline]
    pub fn get_str(&mut self) -> Result<&'a str, DecodeError> {
        std::str::from_utf8(self.get_bytes()?).map_err(|_| DecodeError("invalid utf8"))
    }
}

/// Exact encoded length of an unsigned LEB128 varint — the arithmetic
/// twin of [`Writer::put_varint`], used by the O(1) `WireSize`
/// implementations so the simulator's bandwidth model never has to
/// encode a message just to measure it.
#[inline]
pub fn varint_len(v: u64) -> usize {
    if v == 0 {
        1
    } else {
        (64 - v.leading_zeros() as usize + 6) / 7
    }
}

/// Exact encoded length of a length-prefixed byte string
/// ([`Writer::put_bytes`]).
#[inline]
pub fn bytes_len(n: usize) -> usize {
    varint_len(n as u64) + n
}

/// Types encodable to the canonical binary format.
pub trait Encode {
    fn encode(&self, w: &mut Writer);
}

/// Types decodable from the canonical binary format.
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError>;
}

impl Encode for u64 {
    fn encode(&self, w: &mut Writer) {
        w.put_varint(*self);
    }
}
impl Decode for u64 {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        r.get_varint()
    }
}

impl Encode for u32 {
    fn encode(&self, w: &mut Writer) {
        w.put_varint(*self as u64);
    }
}
impl Decode for u32 {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        u32::try_from(r.get_varint()?).map_err(|_| DecodeError("u32 overflow"))
    }
}

impl Encode for bool {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(*self as u8);
    }
}
impl Decode for bool {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match r.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(DecodeError("invalid bool")),
        }
    }
}

impl Encode for f64 {
    fn encode(&self, w: &mut Writer) {
        w.put_f64(*self);
    }
}
impl Decode for f64 {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        r.get_f64()
    }
}

impl Encode for String {
    fn encode(&self, w: &mut Writer) {
        w.put_str(self);
    }
}
impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(r.get_str()?.to_string())
    }
}

impl Encode for Vec<u8> {
    fn encode(&self, w: &mut Writer) {
        w.put_bytes(self);
    }
}
impl Decode for Vec<u8> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(r.get_bytes()?.to_vec())
    }
}

impl<T: Encode> Encode for [T] {
    fn encode(&self, w: &mut Writer) {
        w.put_varint(self.len() as u64);
        for item in self {
            item.encode(w);
        }
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, w: &mut Writer) {
        self.as_slice().encode(w);
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let n = r.get_varint()? as usize;
        // Defensive cap: each element consumes ≥1 byte.
        if n > r.remaining() {
            return Err(DecodeError("vec length beyond buffer"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::decode(r)?);
        }
        Ok(out)
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, w: &mut Writer) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.encode(w);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            _ => Err(DecodeError("invalid option tag")),
        }
    }
}

impl<const N: usize> Encode for [u8; N] {
    fn encode(&self, w: &mut Writer) {
        w.put_raw(self);
    }
}

impl<const N: usize> Decode for [u8; N] {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let raw = r.get_raw(N)?;
        Ok(raw.try_into().unwrap())
    }
}

impl<A: Encode, B: Encode> Encode for (A, B) {
    fn encode(&self, w: &mut Writer) {
        self.0.encode(w);
        self.1.encode(w);
    }
}

impl<A: Decode, B: Decode> Decode for (A, B) {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{from_bytes, to_bytes};

    #[test]
    fn varint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let b = to_bytes(&v);
            assert_eq!(from_bytes::<u64>(&b).unwrap(), v);
        }
    }

    #[test]
    fn varint_len_matches_encoding() {
        for v in [0u64, 1, 127, 128, 16383, 16384, 300, u32::MAX as u64, u64::MAX] {
            let mut w = Writer::new();
            w.put_varint(v);
            assert_eq!(varint_len(v), w.len(), "varint_len({v})");
        }
        assert_eq!(bytes_len(0), 1);
        assert_eq!(bytes_len(127), 128);
        assert_eq!(bytes_len(128), 130);
    }

    #[test]
    fn varint_canonical() {
        // 0x80 0x00 is a non-canonical encoding of 0.
        let mut r = Reader::new(&[0x80, 0x00]);
        assert!(r.get_varint().is_err());
    }

    #[test]
    fn compound_roundtrip() {
        let v: (String, Vec<u64>) = ("hello".into(), vec![1, 2, 3]);
        let b = to_bytes(&v);
        assert_eq!(from_bytes::<(String, Vec<u64>)>(&b).unwrap(), v);
    }

    #[test]
    fn option_roundtrip() {
        let some: Option<String> = Some("x".into());
        let none: Option<String> = None;
        assert_eq!(from_bytes::<Option<String>>(&to_bytes(&some)).unwrap(), some);
        assert_eq!(from_bytes::<Option<String>>(&to_bytes(&none)).unwrap(), none);
    }

    #[test]
    fn rejects_trailing() {
        let mut b = to_bytes(&7u64);
        b.push(0);
        assert!(from_bytes::<u64>(&b).is_err());
    }

    #[test]
    fn rejects_hostile_length() {
        // Length prefix claims 2^40 elements with a 3-byte body.
        let buf = [0xff, 0xff, 0xff, 0xff, 0xff, 0x1f, 1, 2, 3];
        assert!(from_bytes::<Vec<u8>>(&buf).is_err());
        assert!(from_bytes::<Vec<u64>>(&buf).is_err());
    }

    #[test]
    fn fixed_array() {
        let arr = [7u8; 32];
        assert_eq!(from_bytes::<[u8; 32]>(&to_bytes(&arr)).unwrap(), arr);
    }

    #[test]
    fn floats() {
        let v = -1234.5678f64;
        assert_eq!(from_bytes::<f64>(&to_bytes(&v)).unwrap(), v);
    }
}
